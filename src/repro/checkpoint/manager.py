"""Sharded, atomic, resharding-capable checkpointing (no external deps).

Layout:   <dir>/step_<N>.tmp/ -> (atomic rename) -> <dir>/step_<N>/
            manifest.json     tree structure + shapes/dtypes
            leaf_<i>.npy      one file per pytree leaf

Fault-tolerance properties:
  * atomic publish (tmp dir + rename) — a crash mid-save never corrupts the
    latest checkpoint;
  * ``restore`` takes a target sharding tree, so the same checkpoint restores
    onto a DIFFERENT mesh (elastic scaling: see runtime_ft/elastic.py);
  * ``keep_last`` garbage collection.

On a real multi-host pod each host writes only the shards it owns
(process-local addressable_shards); in this single-process container that
degenerates to full-array writes, but the API is the multi-host one.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any) -> Path:
        names, leaves, _ = _flatten_with_names(tree)
        tmp = self.dir / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(leaf)
            np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree: Any):
        """Non-blocking save: snapshots device arrays to host, then writes in
        a background thread (training continues; the atomic rename publishes
        only when complete).  Returns the Thread (join() to flush)."""
        import threading

        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        t = threading.Thread(target=self.save, args=(step, host_tree), daemon=True)
        t.start()
        return t

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp"):
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` is given
        the arrays are placed with those shardings (possibly a different mesh
        than the one that saved — elastic restore)."""
        src = self.dir / f"step_{step}"
        manifest = json.loads((src / "manifest.json").read_text())
        names, leaves, treedef = _flatten_with_names(like)
        assert len(names) == len(manifest["leaves"]), "tree structure mismatch"
        sh_leaves = (
            jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )
            if shardings is not None
            else [None] * len(names)
        )
        out = []
        for i, (name, rec) in enumerate(zip(names, manifest["leaves"])):
            assert name == rec["name"], f"leaf order mismatch: {name} != {rec['name']}"
            arr = np.load(src / f"leaf_{i}.npy")
            if sh_leaves[i] is not None:
                out.append(jax.device_put(arr, sh_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
