"""Jittable step builders shared by the launchers, the dry-run and tests.

``make_train_step`` supports gradient-accumulation microbatching (the
activation-memory knob recorded per-arch in configs as
``train_microbatches``): the global batch is split on its leading dim and
scanned, grads accumulated in fp32, then one AdamW update is applied.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..models.api import family_for
from ..optim import adamw


def opt_config_for(cfg) -> adamw.AdamWConfig:
    """Per-arch optimizer config (moment dtype follows the HBM budget)."""
    moment_dtype = (
        jnp.bfloat16 if getattr(cfg, "moment_dtype", "float32") == "bfloat16"
        else jnp.float32
    )
    return adamw.AdamWConfig(moment_dtype=moment_dtype)


def make_train_step(
    cfg, opt_cfg: adamw.AdamWConfig, *, microbatches: int = 1
) -> Callable:
    """-> step(params, opt_state, batch) -> (params, opt_state, metrics)
    with metrics = {"loss", "grad_norm"}."""
    fam = family_for(cfg)

    def loss_fn(params, batch):
        return fam.loss(cfg, params, batch)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0, (
                    f"global batch {B} not divisible by "
                    f"train_microbatches={microbatches}"
                )
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(carry, b):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, b)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + loss.astype(jnp.float32), g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, g_sum), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mb
            )
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = adamw.apply(
            opt_cfg, params, grads, opt_state
        )
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return step


def make_prefill_step(cfg) -> Callable:
    """-> step(params, batch) -> (last-position logits, kv cache)."""
    fam = family_for(cfg)

    def step(params, batch):
        return fam.prefill(cfg, params, batch)

    return step


def make_decode_step(cfg) -> Callable:
    """-> step(params, cache, batch) -> (greedy token int32[B], cache).

    Greedy sampling lives inside the compiled program so the serving loop
    moves one int per sequence per step off-device, not the logits.
    """
    fam = family_for(cfg)

    def step(params, cache, batch):
        logits, cache = fam.decode(cfg, params, cache, batch)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return step
