"""Jittable step builders shared by the launchers, the dry-run and tests.

``make_train_step`` supports gradient-accumulation microbatching (the
activation-memory knob recorded per-arch in configs as
``train_microbatches``): the global batch is split on its leading dim and
scanned, grads accumulated in fp32, then one AdamW update is applied.

``make_tm_train_step`` is the mesh-sharded Tsetlin Machine feedback step
(the Fig-8 training node scaled out): TA state shards its class dim over
``model``, the batch shards over the non-``model`` axes, per-sample
summed-delta feedback is computed locally and psum'd across the batch
axes.  Bit-identical to ``core.train.train_batch_parallel`` on any mesh
(integer deltas commute), which tests/test_recal.py asserts.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
try:  # jax >= 0.6 moved shard_map out of experimental
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.train import sample_class_delta, sample_keys
from ..models.api import family_for
from ..optim import adamw
from .sharding import _axis_sizes, batch_axes


def opt_config_for(cfg) -> adamw.AdamWConfig:
    """Per-arch optimizer config (moment dtype follows the HBM budget)."""
    moment_dtype = (
        jnp.bfloat16 if getattr(cfg, "moment_dtype", "float32") == "bfloat16"
        else jnp.float32
    )
    return adamw.AdamWConfig(moment_dtype=moment_dtype)


def make_train_step(
    cfg, opt_cfg: adamw.AdamWConfig, *, microbatches: int = 1
) -> Callable:
    """-> step(params, opt_state, batch) -> (params, opt_state, metrics)
    with metrics = {"loss", "grad_norm"}."""
    fam = family_for(cfg)

    def loss_fn(params, batch):
        return fam.loss(cfg, params, batch)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0, (
                    f"global batch {B} not divisible by "
                    f"train_microbatches={microbatches}"
                )
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(carry, b):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, b)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g
                )
                return (loss_acc + loss.astype(jnp.float32), g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, g_sum), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mb
            )
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = adamw.apply(
            opt_cfg, params, grads, opt_state
        )
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return step


def make_tm_train_step(tm_cfg, mesh, *, batch: int) -> Callable:
    """-> step(state, key, xb, yb) -> state, sharded over ``mesh``.

    ``state`` int32[M, C, 2F] shards classes over ``model``; ``xb``/``yb``
    shard their leading dim over the non-``model`` axes (``batch_axes``).
    Each device computes the summed-delta feedback of its batch shard
    restricted to its class rows (``core.train.sample_class_delta``), the
    deltas are psum'd over the batch axes, and one clipped update is
    applied — the large-class-count scale-out of the recal worker.

    Seeding follows the core contract: global sample ``i`` (its position
    in the UNSHARDED batch) trains under ``fold_in(key, i)``, so the
    result equals ``train_batch_parallel(cfg, state, key, xb, yb)``
    bit-exactly regardless of the mesh shape.
    """
    sizes = _axis_sizes(mesh)
    n_model = sizes.get("model", 1)
    M, N = tm_cfg.n_classes, tm_cfg.n_states
    if M % n_model:
        raise ValueError(
            f"the model axis size ({n_model}) must divide n_classes={M} for "
            f"the class-sharded TM train step; pad the config or shrink the "
            f"mesh"
        )
    bx = batch_axes(mesh, batch)
    has_model = "model" in sizes
    state_spec = P("model", None, None) if has_model else P()
    m_local = M // n_model

    def local(state_l, key, xb_l, yb_l):
        B_l = xb_l.shape[0]
        shard = jnp.int32(0)
        for ax in bx or ():
            shard = shard * sizes[ax] + jax.lax.axis_index(ax)
        keys = sample_keys(key, B_l, offset=shard * B_l)
        m0 = (
            jax.lax.axis_index("model") * m_local if has_model else jnp.int32(0)
        )
        m_ids = m0 + jnp.arange(m_local)
        deltas = jax.vmap(
            lambda k, x, y: sample_class_delta(
                tm_cfg, state_l, m_ids, k, x, y
            )
        )(keys, xb_l.astype(jnp.bool_), yb_l)
        delta = jnp.sum(deltas, axis=0)
        if bx:
            delta = jax.lax.psum(delta, bx)
        return jnp.clip(state_l + delta, 1, 2 * N)

    def step(state, key, xb, yb):
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(state_spec, P(), P(bx, None), P(bx)),
            out_specs=state_spec,
            check_rep=False,
        )(state, key, xb, yb)

    return jax.jit(step)


def make_prefill_step(cfg) -> Callable:
    """-> step(params, batch) -> (last-position logits, kv cache)."""
    fam = family_for(cfg)

    def step(params, batch):
        return fam.prefill(cfg, params, batch)

    return step


def make_decode_step(cfg) -> Callable:
    """-> step(params, cache, batch) -> (greedy token int32[B], cache).

    Greedy sampling lives inside the compiled program so the serving loop
    moves one int per sequence per step off-device, not the logits.
    """
    fam = family_for(cfg)

    def step(params, cache, batch):
        logits, cache = fam.decode(cfg, params, cache, batch)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return step
