"""Mesh-sharded execution: activation/param sharding rules, jitted step
builders, and the paper's multi-core compressed-TM executor on a mesh.

Modules:
  sharding.py    activation-sharding hints + per-family parameter sharding
                 rules (the single source of truth for mesh layouts)
  steps.py       make_train_step / make_prefill_step / make_decode_step —
                 the jittable programs the launchers and dry-run lower —
                 plus make_tm_train_step, the class-sharded TM feedback
                 step the recal worker scales out with
  tm_sharded.py  class-parallel x batch-parallel compressed-TM executor
                 (the Fig-7 multi-core split, mesh-native)
"""

from . import sharding  # noqa: F401
