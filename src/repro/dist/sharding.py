"""Sharding rules: the single source of truth for how params, optimizer
state, activations, inputs and KV caches are laid out on a mesh.

Mesh axes (launch/mesh.py):
  pod    cross-pod data parallelism (DCN)           -- optional, 3-axis only
  data   in-pod data parallelism / FSDP
  model  tensor / expert / vocab parallelism

The batch dimension shards over every non-``model`` axis that divides it
(``batch_axes``); weight matrices shard their largest contraction-free dim
over ``model`` and (under FSDP) a second dim over ``data``; anything that
does not divide evenly stays replicated — the rules never raise on a
degenerate mesh, so the same code paths run from a 1-chip CI box to the
2x16x16 production mesh.

Activation hints (``hint``) are advisory ``with_sharding_constraint``s: the
model code states the logical layout ("batch", None, "model") and this
module translates it for whatever mesh is installed (or is a no-op when
none is).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Installed by set_activation_mesh; read by hint() and the MoE EP gate.
_ACTIVATION_MESH: Optional[Mesh] = None


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or clear, with None) the mesh used by activation hints."""
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh, B: int) -> Optional[Tuple[str, ...]]:
    """Mesh axes the batch dim shards over, major-to-minor.

    Every non-``model`` axis is taken in mesh order while the running
    product still divides ``B`` — so a (pod, data, model) mesh yields
    ("pod", "data"), a (data, model) mesh yields ("data",), and a batch
    too small for the leading axis stays replicated (None).
    """
    sizes = _axis_sizes(mesh)
    chosen = []
    prod = 1
    for name in mesh.axis_names:
        if name == "model":
            continue
        if B % (prod * sizes[name]) == 0:
            chosen.append(name)
            prod *= sizes[name]
        else:
            break
    return tuple(chosen) if chosen else None


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def hint(x: jax.Array, *axes: Any) -> jax.Array:
    """Advisory activation layout: one entry per leading dim of ``x``.

    Entries: "batch" (shard over batch_axes), a mesh axis name, or None.
    No-op when no activation mesh is installed or a dim does not divide.
    """
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    sizes = _axis_sizes(mesh)
    spec = []
    for d, a in enumerate(axes):
        if a is None:
            spec.append(None)
        elif a == "batch":
            spec.append(batch_axes(mesh, x.shape[d]))
        elif a in sizes and x.shape[d] % sizes[a] == 0:
            spec.append(a)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

def _path_names(path) -> list:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "name", None)
        if k is None:
            k = getattr(p, "idx", None)
        out.append(str(k))
    return out


def _param_spec(cfg, mesh, path, leaf) -> P:
    """One PartitionSpec per param leaf.

    Rules (checked in this order):
      * scalars / vectors (norm scales)            -> replicated
      * embedding [V, D]                           -> vocab over model
                                                      (+ D over data if fsdp)
      * router [D, E]                              -> replicated (fp32, tiny)
      * MoE expert stacks [L, E, D, F]             -> experts over model (EP)
      * attention weights with cfg.attn_tp=False   -> replicated (pure DP)
      * other matrices: largest non-stack dim over model; under FSDP the
        largest remaining dim over data.  A dim is only assigned an axis
        it divides evenly; otherwise it stays replicated.
    """
    sizes = _axis_sizes(mesh)
    n_model = sizes.get("model", 1)
    n_data = sizes.get("data", 1)
    names = _path_names(path)
    shape = leaf.shape
    spec = [None] * len(shape)

    if len(shape) <= 1:
        return P()

    if "embed" in names:
        if "model" in sizes and shape[0] % n_model == 0:
            spec[0] = "model"
        if cfg.fsdp and "data" in sizes and shape[1] % n_data == 0:
            spec[1] = "data"
        return P(*spec)

    if "router" in names:
        return P(*spec)

    is_attn = any(n in ("attn", "wq", "wk", "wv", "wo", "self_attn",
                        "cross_attn") for n in names)
    if is_attn and not cfg.attn_tp:
        return P(*spec)

    is_expert = cfg.is_moe and any(
        n in ("w_gate", "w_up", "w_down") for n in names
    ) and "moe" in names
    if is_expert:
        # [L, E, D, F] (stacked) or [E, D, F]: shard the expert dim
        e_dim = 1 if len(shape) == 4 else 0
        if "model" in sizes and shape[e_dim] % n_model == 0:
            spec[e_dim] = "model"
        return P(*spec)

    # generic matrix: dims after the leading stack dim are candidates;
    # for unstacked 2-D weights all dims are candidates.
    cand = list(range(1, len(shape))) if len(shape) >= 3 else list(range(len(shape)))
    by_size = sorted(cand, key=lambda d: shape[d], reverse=True)
    for d in by_size:
        if "model" in sizes and shape[d] % n_model == 0:
            spec[d] = "model"
            break
    if cfg.fsdp and "data" in sizes:
        for d in by_size:
            if spec[d] is None and shape[d] % n_data == 0:
                spec[d] = "data"
                break
    return P(*spec)


def param_shardings(cfg, mesh: Mesh, specs: Any) -> Any:
    """Param-spec pytree -> NamedSharding pytree (one sharding per leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _param_spec(cfg, mesh, path, leaf)),
        specs,
    )


def opt_shardings(cfg, mesh: Mesh, o_specs: Any, p_sh: Any) -> Any:
    """AdamW state shards exactly like the params; step is replicated."""
    from ..optim.adamw import AdamWState

    return AdamWState(step=replicated(mesh), m=p_sh, v=p_sh)


# ---------------------------------------------------------------------------
# input / cache shardings
# ---------------------------------------------------------------------------

def input_shardings(cfg, mesh: Mesh, shape, in_specs: Any) -> Any:
    """Batch-leading inputs shard over the batch axes; scalars replicate."""
    bx = batch_axes(mesh, shape.global_batch)

    def rule(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] == shape.global_batch:
            return NamedSharding(mesh, P(bx, *([None] * (leaf.ndim - 1))))
        return replicated(mesh)

    return jax.tree.map(rule, in_specs)


def _cache_head_sizes(cfg) -> set:
    """Every head count a decode-cache dim of this config might carry:
    attention heads (q and kv) plus, for the SSM/recurrent families, the
    SSM head count (xLSTM's mLSTM head count IS ``n_heads``)."""
    heads = set()
    for attr in ("n_heads", "n_kv_heads"):
        v = getattr(cfg, attr, None)
        if v:
            heads.add(int(v))
    if getattr(cfg, "family", "") in ("ssm_xlstm", "hybrid"):
        from ..models.ssm import ssm_dims  # deferred: models import dist

        heads.add(ssm_dims(cfg)[1])
    return heads


def cache_shardings(cfg, mesh: Mesh, shape, c_specs: Any) -> Any:
    """Decode caches shard their batch dim over the batch axes and their
    HEAD dim over model — for every cache family, not just attention KV:

      KV          [L, B, S, H, hd]       head at dim 3
      SSM conv    [L, B, K-1, d_conv]    batch only (channel mix, no heads)
      SSM state   [L, B, H, N, P]        head at dim 2
      hybrid SSM  [G, E, B, H, N, P]     batch at dim 2, head at dim 3
      mLSTM C/n/m [P, B, H, hd, hd] / [P, B, H, hd] / [P, B, H]
                                         head at dim 2
      sLSTM       [P, B, D]              batch only (fused per-channel)

    The head dim is recognized by its SIZE (one of the config's head
    counts, see ``_cache_head_sizes``): the first such dim after the
    batch dim takes "model", except the KV convention [stack, B, S, H,
    hd] which pins dim 3 so a window length colliding with a head count
    cannot steal the assignment.  The pin checks the shape signature,
    not just rank: the mLSTM C cache [P, B, H, hd, hd] is also 5-D and
    its per-head feature dim 3 coincides with a head count whenever
    hd == H (e.g. d_model=64, n_heads=8) — a square trailing [hd, hd]
    with a head count at dim 2 is recognized as that matrix-memory
    signature and falls through to the generic first-head-after-batch
    rule (dim 2), as the table above requires.  Dims that don't divide
    the axis stay replicated, as everywhere in this module."""
    sizes = _axis_sizes(mesh)
    n_model = sizes.get("model", 1)
    bx = batch_axes(mesh, shape.global_batch)
    heads = _cache_head_sizes(cfg)

    def rule(leaf):
        spec = [None] * leaf.ndim
        # caches are [stack, B, ...] (dim 1), prefill-less [B, ...], or
        # double-stacked hybrid groups [G, E, B, ...] (dim 2)
        b_dim = next(
            (
                d
                for d in (1, 0, 2)
                if d < leaf.ndim and leaf.shape[d] == shape.global_batch
            ),
            None,
        )
        if b_dim is not None:
            spec[b_dim] = bx
        if "model" in sizes:
            def head_at(d):
                return leaf.shape[d] in heads and leaf.shape[d] % n_model == 0

            is_mlstm_c = (
                leaf.ndim == 5
                and leaf.shape[3] == leaf.shape[4]
                and leaf.shape[2] in heads
            )
            if (leaf.ndim == 5 and b_dim == 1 and head_at(3)
                    and not is_mlstm_c):
                spec[3] = "model"  # the KV [L, B, S, H, hd] convention
            else:
                for d in range((b_dim if b_dim is not None else -1) + 1,
                               leaf.ndim):
                    if spec[d] is None and head_at(d):
                        spec[d] = "model"
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, c_specs)
