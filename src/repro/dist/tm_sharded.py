"""Mesh-sharded compressed-TM inference: the paper's multi-core class-split
(Fig 7) realized as a JAX ``shard_map`` over a (data, model) mesh.

Layout (the MATADOR-style plan: one fixed layout chosen per deployment,
exploited ETHEREAL-style by the compressed include-list executors):

  * classes shard over ``model``  — each device holds the include plans of
    its class slice only (the AXIS splitter of core/runtime.py, mesh-native)
  * the batch shards over every non-model axis (``sharding.batch_axes``)
  * each device runs a *local plan executor* over its (class, batch) tile;
    the combined output is the global [B, M] class-sum matrix with no
    collective at all (outputs tile disjointly).

Three local executors over decode_to_plan output, all bit-exact against
``core.batch_class_sums`` (enforced by tests/test_tm_sharded.py):

  _local_plan_executor             include-major streaming over CHUNK-sized
                                   instruction blocks, scatter-min clause
                                   accumulation (clauses may span chunks)
  _local_plan_executor_packed      the same stream over pack_literals words
                                   (32 datapoints per uint32, paper §3),
                                   running-AND with seg_last emission
  _local_plan_executor_clausemajor clause-major padded include table, one
                                   gather + AND-reduce per clause (the
                                   TPU-native layout build_tm_sharded uses)
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
try:  # jax >= 0.6 moved shard_map out of experimental
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import _pad_to
from ..core.tm import unpack_bits
from .sharding import _axis_sizes, batch_axes

# Includes processed per streaming step of the include-major executors
# (the VMEM-resident instruction block; tests shrink it to force
# chunk-spanning clauses).
CHUNK = 512

_ONES32 = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# local (single-shard) plan executors
# ---------------------------------------------------------------------------

def _local_plan_executor(lit_idx, cid, clause_class, clause_pol, lits):
    """Include-major executor over an unpacked literal matrix.

    lit_idx      int32[I_cap]  absolute literal slots, padded with 0
    cid          int32[I_cap]  global clause id; padded slots -> NCL (sink)
    clause_class int32[NCL]    class of each clause
    clause_pol   int32[NCL]    +1 / -1
    lits         {0,1}[B, 2F]  interleaved literal matrix
    -> int32[NCL, B] class sums (rows >= n_classes are zero; caller slices)

    Streams the include list in CHUNK-sized blocks; each block scatter-mins
    into a clause accumulator, so clauses spanning block boundaries combine
    correctly.  Clauses that never receive an include output 0 (inference
    semantics for empty clauses).
    """
    B = lits.shape[0]
    NCL = clause_pol.shape[0]
    I_cap = lit_idx.shape[0]
    chunk = min(CHUNK, I_cap)
    assert I_cap % chunk == 0, (I_cap, chunk)
    n_chunks = I_cap // chunk

    sel = jnp.take(lits.astype(jnp.int32).T, lit_idx, axis=0)  # [I_cap, B]
    sel_c = sel.reshape(n_chunks, chunk, B)
    cid_c = cid.reshape(n_chunks, chunk)

    def body(carry, inp):
        acc, cnt = carry
        s, c = inp  # s: [chunk, B]; c: [chunk]
        acc = acc.at[c].min(s)
        cnt = cnt.at[c].add(1)
        return (acc, cnt), None

    acc0 = jnp.ones((NCL + 1, B), jnp.int32)  # +1: sink row for padding
    cnt0 = jnp.zeros((NCL + 1,), jnp.int32)
    (acc, cnt), _ = jax.lax.scan(body, (acc0, cnt0), (sel_c, cid_c))

    clause_out = jnp.where(cnt[:NCL, None] > 0, acc[:NCL], 0)  # [NCL, B]
    contrib = clause_out * clause_pol[:, None]
    return jnp.zeros((NCL, B), jnp.int32).at[clause_class].add(contrib)


def _local_plan_executor_packed(lit_idx, seg_last, clause_class, clause_pol,
                                packed):
    """Include-major executor over pack_literals words (32 points/word).

    lit_idx   int32[I_cap]   absolute literal slots, padded with 0
    seg_last  int32[I_cap]   1 at the last include of each clause, else 0
    packed    uint32[2F, W]  pack_literals output (bit b = datapoint w*32+b)
    -> int32[NCL, W*32] class sums

    A running AND word accumulates the current clause; on seg_last the word
    is emitted to the clause's output row and the accumulator resets.  The
    instruction stream is consumed in CHUNK-sized blocks (outer scan) with
    a sequential inner scan — the same fetch/accumulate discipline as the
    eFPGA pipeline, 32-wide.
    """
    NCL = clause_pol.shape[0]
    W = packed.shape[1]
    ones = jnp.uint32(_ONES32)
    I_cap = lit_idx.shape[0]
    chunk = min(CHUNK, I_cap)
    assert I_cap % chunk == 0, (I_cap, chunk)
    n_chunks = I_cap // chunk

    words = jnp.take(packed, lit_idx, axis=0)  # [I_cap, W]
    words_c = words.reshape(n_chunks, chunk, W)
    last_c = seg_last.reshape(n_chunks, chunk)

    def instr(carry, inp):
        acc, c, out = carry
        w, last = inp  # w: [W]; last: scalar
        acc = acc & w
        row = jnp.where(last == 1, c, NCL)  # non-final writes hit the sink
        out = out.at[row].set(acc)
        c = c + last
        acc = jnp.where(last == 1, ones, acc)
        return (acc, c, out), None

    def chunk_body(carry, inp):
        carry, _ = jax.lax.scan(instr, carry, inp)
        return carry, None

    out0 = jnp.zeros((NCL + 1, W), jnp.uint32)
    carry0 = (jnp.full((W,), ones, jnp.uint32), jnp.int32(0), out0)
    (_, _, out), _ = jax.lax.scan(chunk_body, carry0, (words_c, last_c))

    bits = unpack_bits(out[:NCL])  # [NCL, W*32]
    contrib = bits * clause_pol[:, None]
    return jnp.zeros((NCL, W * 32), jnp.int32).at[clause_class].add(contrib)


def _local_plan_executor_clausemajor(pad_idx, clause_class, clause_pol,
                                     packed1):
    """Clause-major executor: padded include table, bitpacked datapoints.

    pad_idx  int32[NCL, Lc]   per-clause literal slots, padded with the
                              index of the all-ones row of ``packed1``
    packed1  uint32[2F+1, W]  pack_literals output + one all-ones row
    -> int32[NCL, W*32] class sums

    One gather + one AND-reduction per clause — fully parallel over clauses
    AND datapoints (this is the layout ``build_tm_sharded`` distributes).
    """
    ones = jnp.uint32(_ONES32)
    words = jnp.take(packed1, pad_idx, axis=0)  # [NCL, Lc, W]
    acc = jax.lax.reduce(words, ones, jnp.bitwise_and, dimensions=(1,))
    bits = unpack_bits(acc)  # [NCL, W*32]
    contrib = bits * clause_pol[:, None]
    return jnp.zeros_like(contrib).at[clause_class].add(contrib)


# ---------------------------------------------------------------------------
# sharded executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TMShardedConfig:
    """A deployed multi-core TM: model dims + executor capacity plan."""

    name: str
    n_classes: int
    n_clauses: int      # clauses per class
    n_features: int
    batch: int          # global batch (multiple of 32: bitpacked words)
    include_cap: int = 0  # max includes per clause (0 -> density estimate)
    density: float = 0.05

    @property
    def lc_cap(self) -> int:
        if self.include_cap:
            return self.include_cap
        est = int(2 * self.n_features * self.density * 2)
        return max(8, -(-est // 8) * 8)


TM_CONFIGS: Dict[str, TMShardedConfig] = {
    # the paper's MNIST-scale machine, batch-scaled for mesh serving
    "tm-paper": TMShardedConfig(
        name="tm-paper", n_classes=10, n_clauses=128, n_features=784,
        batch=8192, density=0.05,
    ),
    "tm-xl": TMShardedConfig(
        name="tm-xl", n_classes=64, n_clauses=512, n_features=4096,
        batch=32768, density=0.02,
    ),
}


def build_tm_sharded(cfg: TMShardedConfig, mesh) -> Tuple[Callable, tuple]:
    """-> (fn, specs): the jittable class x batch sharded executor.

    fn(idx, pol, lits) -> int32[Bp, Mp] class sums, where
      idx  int32[Mp, C, Lc]  per-class clause-major include tables (padded
                             entries point at the trailing all-ones column)
      pol  int32[Mp, C]      +1/-1, 0 for padded clauses/classes
      lits int8[Bp, 2F+1]    interleaved literals + all-ones pad column

    Classes shard over ``model`` (Mp is padded up to divide), the batch over
    the non-model axes; each device computes its disjoint [B_l, M_l] tile so
    the assembled output needs no collective.  ``specs`` are ShapeDtypeStructs
    carrying the input NamedShardings — pass them straight to
    ``jax.jit(fn).lower(*specs)`` (dry-run) or build real operands with
    ``operands_from_plan``.
    """
    sizes = _axis_sizes(mesh)
    n_model = sizes.get("model", 1)
    Mp = _pad_to(cfg.n_classes, n_model)
    Bp = cfg.batch
    C, Lc, F2 = cfg.n_clauses, cfg.lc_cap, 2 * cfg.n_features
    bx = batch_axes(mesh, Bp)

    idx_spec = P("model", None, None)
    pol_spec = P("model", None)
    lit_spec = P(bx, None)
    out_spec = P(bx, "model")

    def local(idx_l, pol_l, lits_l):
        # idx_l: [M_l, C, Lc]; lits_l: [B_l, 2F+1]
        sel = jnp.take(lits_l.astype(jnp.int32), idx_l, axis=1)
        clause = jnp.min(sel, axis=-1)          # [B_l, M_l, C] AND of includes
        return jnp.sum(clause * pol_l[None].astype(jnp.int32), axis=-1)

    def fn(idx, pol, lits):
        return shard_map(
            local, mesh=mesh,
            in_specs=(idx_spec, pol_spec, lit_spec),
            out_specs=out_spec,
            check_rep=False,
        )(idx, pol, lits)

    specs = (
        jax.ShapeDtypeStruct((Mp, C, Lc), jnp.int32,
                             sharding=NamedSharding(mesh, idx_spec)),
        jax.ShapeDtypeStruct((Mp, C), jnp.int32,
                             sharding=NamedSharding(mesh, pol_spec)),
        jax.ShapeDtypeStruct((Bp, F2 + 1), jnp.int8,
                             sharding=NamedSharding(mesh, lit_spec)),
    )
    return fn, specs


def fill_clause_tables(plan, Mp: int, C: int, Lc: int, F2: int):
    """DecodedPlan -> clause-major (idx int32[Mp, C, Lc], pol int32[Mp, C]).

    Padded idx entries point at the all-ones literal column ``F2``; padded
    pol entries are 0 so they contribute nothing.  Clause weights
    (repro.prune) fold straight into the polarity table
    (``pol = weight * polarity``) — the local executor's
    ``clause * pol`` sum is already a weighted vote, so weighted models
    run the SAME compiled shard_map, bit-identical at weight 1.  Raises
    when the plan exceeds the (C, Lc) capacity plan (the mesh analog of
    "resynthesize with a bigger AcceleratorConfig").  Shared by
    ``operands_from_plan`` and the serve_tm sharded executor.
    """
    idx = np.full((Mp, C, Lc), F2, np.int32)
    pol = np.zeros((Mp, C), np.int32)
    next_slot = np.zeros(Mp, np.int64)
    wpol = plan.weighted_pol
    # clause_id is sorted (decode_to_plan emits stream order), so one
    # searchsorted gives every clause's include span.
    bounds = np.searchsorted(
        plan.clause_id, np.arange(plan.n_clauses_total + 1)
    )
    for c in range(plan.n_clauses_total):
        m = int(plan.clause_class[c])
        j = int(next_slot[m])
        next_slot[m] += 1
        if j >= C:
            raise ValueError(f"class {m} exceeds clause capacity {C}")
        ks = plan.lit_idx[bounds[c] : bounds[c + 1]]
        if ks.size > Lc:
            raise ValueError(
                f"clause {c} has {ks.size} includes; capacity {Lc}"
            )
        idx[m, j, : ks.size] = ks
        pol[m, j] = int(wpol[c])
    return idx, pol


def operands_from_plan(cfg: TMShardedConfig, plan, X: np.ndarray, mesh):
    """DecodedPlan + raw features -> real operands matching build_tm_sharded.

    Raises if the plan exceeds the config's capacity plan (the mesh analog
    of "resynthesize with a bigger AcceleratorConfig").
    """
    from ..core.tm import literals

    Mp = _pad_to(cfg.n_classes, _axis_sizes(mesh).get("model", 1))
    C, Lc, F2 = cfg.n_clauses, cfg.lc_cap, 2 * cfg.n_features
    idx, pol = fill_clause_tables(plan, Mp, C, Lc, F2)

    B = X.shape[0]
    if B != cfg.batch:
        raise ValueError(f"batch {B} != configured {cfg.batch}")
    lits = np.asarray(literals(jnp.asarray(X, bool))).astype(np.int8)
    lits1 = np.concatenate([lits, np.ones((B, 1), np.int8)], axis=1)
    return jnp.asarray(idx), jnp.asarray(pol), jnp.asarray(lits1)


def dryrun_tm(name: str, *, multi_pod: bool = False, out_dir=None) -> dict:
    """Lower + compile the sharded TM on the production mesh and derive
    roofline terms (the --include-tm path of launch/dryrun.py)."""
    from ..analysis.roofline import build_roofline, cost_analysis_dict
    from ..launch.mesh import make_production_mesh

    cfg = TM_CONFIGS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    fn, specs = build_tm_sharded(cfg, mesh)
    with mesh:
        compiled = jax.jit(fn).lower(*specs).compile()
    cost = cost_analysis_dict(compiled.cost_analysis())
    # useful work: one AND + one accumulate per (include, datapoint)
    includes = cfg.n_classes * cfg.n_clauses * cfg.lc_cap
    mf = 2.0 * includes * cfg.batch
    rl = build_roofline(
        arch=name, shape=f"batch{cfg.batch}", mesh_name=mesh_name,
        chips=mesh.devices.size, cost=cost, hlo_text=compiled.as_text(),
        model_flops_global=mf,
    )
    rec = json.loads(rl.to_json())
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{name}_{mesh_name}.json").write_text(
            json.dumps(rec, indent=1)
        )
    return rec
