"""Gradient compression for the DP all-reduce (int8 + error feedback).

At 1000+ node scale the pod-to-pod (DCN) gradient all-reduce dominates;
int8 quantization cuts those bytes 4x vs fp32 (2x vs bf16) at negligible
quality loss when error feedback accumulates the quantization residual
locally (Seide et al. 2014; 1-bit Adam lineage).

Usage (train loop):
    comp = GradCompressor.init(params)
    grads_q, comp = comp.compress(grads)     # before cross-pod reduce
    grads   = comp.decompress(grads_q)       # after reduce
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressedGrads(NamedTuple):
    q: Any  # int8 pytree
    scale: Any  # fp32 per-leaf scale


class GradCompressor(NamedTuple):
    error: Any  # residual feedback pytree (fp32)

    @staticmethod
    def init(params: Any) -> "GradCompressor":
        return GradCompressor(
            error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )

    def compress(self, grads: Any) -> Tuple[CompressedGrads, "GradCompressor"]:
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            err = g32 - q.astype(jnp.float32) * scale
            return q, scale, err

        out = jax.tree.map(one, grads, self.error)
        q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return CompressedGrads(q=q, scale=s), GradCompressor(error=e)

    @staticmethod
    def decompress(cg: CompressedGrads) -> Any:
        return jax.tree.map(
            lambda q, s: q.astype(jnp.float32) * s, cg.q, cg.scale
        )


def compressed_psum(cg: CompressedGrads, axis_name: str) -> Any:
    """All-reduce the int8 payload inside shard_map/pmap: each member
    contributes q*scale; the sum happens in fp32 after a single int8
    all-gather-equivalent (here modeled with psum of the dequantized value —
    the wire format is the int8 tensor + one scalar per leaf)."""
    deq = GradCompressor.decompress(cg)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), deq)
