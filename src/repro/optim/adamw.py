"""Minimal AdamW with dtype-configurable moments (ZeRO-friendly).

Moments can be kept in bf16 for very large models (llama4-maverick) so the
optimizer state fits the per-chip HBM budget — recorded in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 for >100B models


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, cfg.moment_dtype)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def init_specs(cfg: AdamWConfig, param_specs: Any) -> AdamWState:
    """ShapeDtypeStruct version for dry-run lowering (no allocation)."""
    def spec(p):
        return jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)

    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(spec, param_specs),
        v=jax.tree.map(spec, param_specs),
    )


def apply(
    cfg: AdamWConfig, params: Any, grads: Any, state: AdamWState
) -> tuple[Any, AdamWState, jax.Array]:
    """-> (new_params, new_state, grad_norm). Params keep their dtype."""
    gflat = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in gflat))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))

    step = state.step + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return p_new, m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    # unzip the 3-tuples
    p_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(step=step, m=m_new, v=v_new), gnorm
