"""Online recalibration: the paper's Fig-8 loop as a running subsystem.

The low-power training node (``core.train``) and the runtime-tunable
accelerator (``serve_tm``) were two endpoints; this package is the wire
between them, run continuously under live traffic:

  monitor.py        DriftMonitor — windowed accuracy / class-sum-margin
                    statistics over served predictions; decides WHEN
  train_engine.py   TrainEngine plugin registry — HOW one update runs
                    ('reference' host path, 'packed' fused int8 kernel,
                    'sharded' dist-mesh step; all bit-identical)
  worker.py         RecalWorker — incremental fold-in-seeded fine-tuning
                    through a TrainEngine; produces the new TA state
  compressor.py     Compressor — include-stream encoding with a bit-exact
                    dense-oracle publication gate; produces WHAT ships
  controller.py     RecalController — drain-then-swap publication through
                    the serving registry, post-swap validation,
                    auto-rollback
"""

from .compressor import CompressionReport, Compressor
from .controller import RecalController, RecalEvent
from .monitor import DriftDecision, DriftMonitor
from .train_engine import (
    TRAIN_ENGINES,
    PackedTrainEngine,
    ReferenceTrainEngine,
    ShardedTrainEngine,
    TrainEngine,
    TrainEngineBase,
    make_train_engine,
    register_train_engine,
    select_train_engine,
    train_engine_names,
)
from .worker import RecalWorker

__all__ = [
    "CompressionReport",
    "Compressor",
    "DriftDecision",
    "DriftMonitor",
    "PackedTrainEngine",
    "RecalController",
    "RecalEvent",
    "RecalWorker",
    "ReferenceTrainEngine",
    "ShardedTrainEngine",
    "TRAIN_ENGINES",
    "TrainEngine",
    "TrainEngineBase",
    "make_train_engine",
    "register_train_engine",
    "select_train_engine",
    "train_engine_names",
]
