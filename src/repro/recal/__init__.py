"""Online recalibration: the paper's Fig-8 loop as a running subsystem.

The low-power training node (``core.train``) and the runtime-tunable
accelerator (``serve_tm``) were two endpoints; this package is the wire
between them, run continuously under live traffic:

  monitor.py     DriftMonitor — windowed accuracy / class-sum-margin
                 statistics over served predictions; decides WHEN
  worker.py      RecalWorker — incremental fold-in-seeded fine-tuning
                 (``fit_step``), optional dist-mesh sharded step; produces
                 the new TA state
  compressor.py  Compressor — include-stream encoding with a bit-exact
                 dense-oracle publication gate; produces WHAT ships
  controller.py  RecalController — drain-then-swap publication through the
                 serving registry, post-swap validation, auto-rollback
"""

from .compressor import CompressionReport, Compressor
from .controller import RecalController, RecalEvent
from .monitor import DriftDecision, DriftMonitor
from .worker import RecalWorker

__all__ = [
    "CompressionReport",
    "Compressor",
    "DriftDecision",
    "DriftMonitor",
    "RecalController",
    "RecalEvent",
    "RecalWorker",
]
