"""The closed Fig-8 loop: monitor -> retrain -> compress -> hot-swap.

``RecalController`` sits between live traffic and a ``TMServer`` slot:

  1. every served batch feeds the ``DriftMonitor`` (class-sum margins +
     the labelled tail) and, when labelled, a bounded replay buffer;
  2. when the monitor triggers, the ``RecalWorker`` fine-tunes on the
     buffered (drifted) data — incremental ``fit_step``s, optionally the
     dist-mesh sharded step;
  3. the ``Compressor`` emits the include stream and PROVES it bit-exact
     against the dense oracle before publication;
  4. the new version is published through the server's drain-then-swap
     path (``register`` with ``recal:`` provenance) — queued traffic
     finishes under the old program, the engine is never recompiled;
  5. post-swap validation re-scores a held-out slice of the buffer: if
     the new version regresses past ``regression_margin`` the controller
     rolls the slot back (old program buffers reinstalled verbatim) and
     reverts the worker to its pre-recal state.

Every completed run is a ``RecalEvent`` in ``controller.events`` and a
``recals``/``rollbacks`` tick in the server's metrics.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from ..prune import PrunePolicy
from .compressor import Compressor
from .monitor import DriftMonitor
from .worker import RecalWorker


@dataclasses.dataclass(frozen=True)
class RecalEvent:
    """One completed trip around the Fig-8 loop."""

    version: int  # slot version published (pre-rollback)
    reason: str
    steps_taken: int
    train_s: float
    compress_s: float
    swap_s: float
    holdout_acc_before: float
    holdout_acc_after: float
    rolled_back: bool
    compression_ratio: float
    # prune-pass stamp (defaults keep pre-prune consumers working)
    pruned_clauses: int = 0
    prune_stages: tuple = ()
    # (knob, provisioned, reclaimable) envelope-renegotiation diagnostics
    reclaimable: tuple = ()


class RecalController:
    def __init__(
        self,
        server,
        slot: str,
        worker: RecalWorker,
        *,
        monitor: Optional[DriftMonitor] = None,
        compressor: Optional[Compressor] = None,
        buffer_batches: int = 32,
        epochs_per_recal: int = 4,
        train_batch_size: int = 128,
        min_buffer_rows: Optional[int] = None,
        holdout_fraction: float = 0.25,
        regression_margin: float = 0.02,
        prune: Optional[PrunePolicy] = None,
    ):
        self.server = server
        self.slot = slot
        self.worker = worker
        self.monitor = monitor or DriftMonitor()
        if compressor is None:
            # stamp publications against the server's negotiated capacity
            # plan when it exposes one: every recal swap then ships a
            # checksummed TMProgram artifact (reprogram-over-the-wire).
            # Gating on the serving NODE's own validate_model (the
            # ServingNode boundary) means the capacity half of the gate
            # is exactly the check the hot-swap will repeat — without
            # reaching for the node's engine internals.  Legacy
            # server-shaped objects fall back to their engine attribute.
            gate = server if hasattr(server, "validate_model") else None
            if gate is None:
                eng = getattr(server, "engine", None)
                if eng is None:
                    eng = getattr(server, "executor", None)
                gate = eng if hasattr(eng, "validate_model") else None
            compressor = Compressor(
                plan=getattr(server, "capacity", None), engine=gate,
            )
        self.compressor = compressor
        self.epochs_per_recal = epochs_per_recal
        self.train_batch_size = train_batch_size
        # don't retrain off a thin buffer: a trigger only fires once this
        # many labelled rows (mostly post-drift, as old batches age out)
        # are available to learn the new distribution from
        self.min_buffer_rows = min_buffer_rows or train_batch_size
        self.holdout_fraction = holdout_fraction
        self.regression_margin = regression_margin
        # the model-compression pass between train and publish: every
        # deploy/recal publication goes through the policy.  deploy() has
        # no labelled holdout, so only the bit-exact passes run there;
        # recalibrate() hands the policy the holdout slice, enabling the
        # tolerance-gated ranked drop too.
        self.prune = prune
        self._buffer: deque = deque(maxlen=buffer_batches)
        self._refreeze_pending = False
        self.events: list = []

    # -- deployment ----------------------------------------------------------

    def deploy(self, provenance: str = "deploy") -> None:
        """Compress the worker's current state and install it into the
        slot (initial deployment or a manual push).  Publishes the
        stamped ``TMProgram`` artifact when the compressor carries a
        capacity plan."""
        report = self.compressor.compress(
            self.worker.cfg, self.worker.state, prune=self.prune
        )
        self.server.register(
            self.slot,
            report.artifact if report.artifact is not None else report.model,
            provenance=provenance,
        )

    def freeze_baseline(self) -> float:
        """Snapshot the current margin window as the healthy reference
        (call after serving known-good traffic post-deploy/post-swap)."""
        return self.monitor.freeze_baseline()

    # -- the serving tap -----------------------------------------------------

    def observe(
        self, x: np.ndarray, y: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Serve ``x`` through the real batched path, feed the monitor
        (margins from the class sums the batch demuxed into the request
        handle — no second engine pass), buffer labelled rows.

        With the server's continuous-batching scheduler loop running,
        the request is completed BY THE LOOP — the tap blocks on the
        handle instead of driving a sync flush, so recalibration
        observes exactly the scheduler-served traffic."""
        x = np.asarray(x, np.uint8)
        handle = self.server.submit(self.slot, x)
        if getattr(self.server, "scheduler_running", False):
            preds = handle.wait(timeout=60.0)
        else:
            self.server.flush()
            preds = handle.result()
        self.monitor.observe(handle.class_sums, preds, y)
        if y is not None:
            self._buffer.append((x, np.asarray(y, np.int32)))
        return preds

    def serve(
        self, x: np.ndarray, y: Optional[np.ndarray] = None
    ) -> tuple:
        """``observe`` + auto-recalibrate: returns (preds, event-or-None)."""
        preds = self.observe(x, y)
        if (
            self._refreeze_pending
            and self.monitor.n_samples >= self.monitor.min_samples
        ):
            # the margin reference tracks the MODEL: after a swap the healthy
            # margin level legitimately changes, so re-freeze on the first
            # full post-swap window instead of comparing against the old one
            self.monitor.freeze_baseline()
            self._refreeze_pending = False
        decision = self.monitor.decision()
        event = None
        if decision.trigger and self.buffered_rows >= self.min_buffer_rows:
            event = self.recalibrate(reason=decision.reason)
        return preds, event

    @property
    def buffered_rows(self) -> int:
        return sum(x.shape[0] for x, _ in self._buffer)

    # -- the loop body -------------------------------------------------------

    def recalibrate(self, reason: str = "manual") -> RecalEvent:
        """One full trip: fine-tune on the buffer, compress + validate,
        drain-then-swap, post-swap validation, auto-rollback."""
        if not self._buffer:
            raise RuntimeError(
                "cannot recalibrate: no labelled traffic buffered — "
                "pass labels to observe()/serve() first"
            )
        X = np.concatenate([x for x, _ in self._buffer], axis=0)
        Y = np.concatenate([y for _, y in self._buffer], axis=0)
        n_holdout = max(1, int(X.shape[0] * self.holdout_fraction))
        X_train, Y_train = X[:-n_holdout], Y[:-n_holdout]
        X_hold, Y_hold = X[-n_holdout:], Y[-n_holdout:]
        if X_train.shape[0] == 0:  # degenerate tiny buffer: train==holdout
            X_train, Y_train = X_hold, Y_hold

        acc_before = float(
            (self.server.infer(self.slot, X_hold) == Y_hold).mean()
        )

        snap = self.worker.snapshot()
        t0 = time.perf_counter()
        steps = self.worker.fine_tune_epochs(
            X_train, Y_train,
            epochs=self.epochs_per_recal, batch=self.train_batch_size,
        )
        train_s = time.perf_counter() - t0

        try:
            t0 = time.perf_counter()
            report = self.compressor.compress(
                self.worker.cfg, self.worker.state,
                traffic_sample=X_hold, labels=Y_hold, prune=self.prune,
            )
            compress_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            entry = self.server.register(
                self.slot,
                report.artifact if report.artifact is not None
                else report.model,
                provenance=f"recal:{reason}",
            )
            swap_s = time.perf_counter() - t0
        except ValueError:
            # publication refused (capacity envelope exhausted, or the
            # bit-exactness gate tripped): the live slot is untouched, so
            # revert the worker too — its fine-tuned state was never
            # published and must not silently seed the next attempt
            self.worker.restore(snap)
            raise

        acc_after = float(
            (self.server.infer(self.slot, X_hold) == Y_hold).mean()
        )
        rolled_back = acc_after < acc_before - self.regression_margin
        if rolled_back:
            self.server.rollback(self.slot)
            self.worker.restore(snap)

        self.server.metrics.record_recal(train_s, compress_s)
        self.monitor.reset()
        self._refreeze_pending = not rolled_back
        event = RecalEvent(
            version=entry.version,
            reason=reason,
            steps_taken=steps,
            train_s=train_s,
            compress_s=compress_s,
            swap_s=swap_s,
            holdout_acc_before=acc_before,
            holdout_acc_after=acc_after,
            rolled_back=rolled_back,
            compression_ratio=report.compression_ratio,
            pruned_clauses=(
                0 if report.prune is None else report.prune.n_removed
            ),
            prune_stages=(
                () if report.prune is None else report.prune.stages
            ),
            reclaimable=report.shrink,
        )
        self.events.append(event)
        return event
