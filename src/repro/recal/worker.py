"""The Fig-8 Model Training Node as a long-lived worker.

Owns one (``TMConfig``, TA-state) pair and fine-tunes it incrementally on
labelled batches via ``core.train.fit_step`` — every update is keyed by a
monotone step counter under the fold-in seeding contract, so a worker can
be checkpointed as the (key, step, state) triple and resumed bit-exactly.

For large class counts the per-step update can run as the ``dist``-mesh
sharded feedback step (``dist.steps.make_tm_train_step``: classes over
``model``, batch over the data axes) — same contract, same bits.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tm import TMConfig, init_state
from ..core.train import fit_step


class RecalWorker:
    def __init__(
        self,
        cfg: TMConfig,
        state: Optional[jax.Array] = None,
        *,
        key: Optional[jax.Array] = None,
        mesh=None,
        sharded_batch: int = 0,
    ):
        """``mesh`` + ``sharded_batch`` opt into the dist-mesh sharded
        training step: batches of exactly ``sharded_batch`` rows run the
        class-sharded ``make_tm_train_step`` (bit-identical to the local
        path); other batch sizes fall back to the local ``fit_step``."""
        self.cfg = cfg
        self.key = key if key is not None else jax.random.key(0)
        self.state = state if state is not None else init_state(cfg, self.key)
        self.step_count = 0
        self._sharded_step = None
        self._sharded_batch = 0
        if mesh is not None and sharded_batch:
            from ..dist.steps import make_tm_train_step

            self._sharded_step = make_tm_train_step(
                cfg, mesh, batch=sharded_batch
            )
            self._sharded_batch = sharded_batch

    # -- training ------------------------------------------------------------

    def fine_tune(self, xb: np.ndarray, yb: np.ndarray) -> int:
        """One incremental update on a labelled batch; returns the step id
        the batch trained under (for exact replay/resume)."""
        step = self.step_count
        xb = jnp.asarray(np.asarray(xb, np.uint8))
        yb = jnp.asarray(np.asarray(yb, np.int32))
        if self._sharded_step is not None and xb.shape[0] == self._sharded_batch:
            # same bits as the local path: fold_in(key, step) is the call
            # key, global sample i trains under fold_in(call_key, i)
            kb = jax.random.fold_in(self.key, step)
            self.state = self._sharded_step(self.state, kb, xb, yb)
        else:
            self.state = fit_step(
                self.cfg, self.state, self.key, xb, yb,
                step=step, parallel=True,
            )
        self.step_count += 1
        return step

    def fine_tune_epochs(
        self, x: np.ndarray, y: np.ndarray, *, epochs: int, batch: int
    ) -> int:
        """Epoch loop over a buffered corpus (shuffled per epoch under the
        worker's own key stream); returns the number of steps taken."""
        n = x.shape[0]
        n_batches = max(1, n // batch)
        taken = 0
        for e in range(epochs):
            order = np.asarray(
                jax.random.permutation(
                    jax.random.fold_in(self.key, 0x7E000000 + self.step_count),
                    n,
                )
            )
            for b in range(n_batches):
                idx = order[b * batch : (b + 1) * batch]
                self.fine_tune(x[idx], y[idx])
                taken += 1
        return taken

    # -- snapshots (rollback support) ----------------------------------------

    def snapshot(self) -> np.ndarray:
        """Host copy of the TA state (restore() it to undo fine-tuning —
        note train steps DONATE the device state buffer, so the device
        array itself must not be aliased across steps)."""
        return np.asarray(self.state)

    def restore(self, snap: np.ndarray) -> None:
        self.state = jnp.asarray(snap)
