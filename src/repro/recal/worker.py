"""The Fig-8 Model Training Node as a long-lived worker.

Owns one (``TMConfig``, TA-state) pair and fine-tunes it incrementally on
labelled batches — every update is keyed by a monotone step counter under
the fold-in seeding contract, so a worker can be checkpointed as the
(key, step, state) triple and resumed bit-exactly.

HOW each update runs is a ``TrainEngine`` plugin (``train_engine.py``):
the worker holds the engine's internal state representation (int8 for the
fused 'packed' engine) and converts to/from the canonical ``int32[M, C,
2F]`` tensor only at the ``state``/``snapshot`` boundary.  Because every
registered engine is bit-identical, the backend is a pure speed knob —
checkpoints and the step counter transfer across engines unchanged.

The old ``RecalWorker(cfg, mesh=..., sharded_batch=...)`` construction
still works (it maps onto the 'sharded' engine) but emits a
``DeprecationWarning``, once per process.
"""

from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tm import TMConfig, init_state
from .train_engine import TrainEngineBase, make_train_engine, select_train_engine

_warned_legacy_sharded = False


def _warn_legacy_sharded() -> None:
    global _warned_legacy_sharded
    if _warned_legacy_sharded:
        return
    _warned_legacy_sharded = True
    warnings.warn(
        "RecalWorker(mesh=..., sharded_batch=...) is deprecated: pass "
        "train_engine='sharded' with engine_options={'batch': ...} (or "
        "just mesh=, which auto-selects the sharded engine)",
        DeprecationWarning,
        stacklevel=3,
    )


class RecalWorker:
    def __init__(
        self,
        cfg: TMConfig,
        state: Optional[jax.Array] = None,
        *,
        key: Optional[jax.Array] = None,
        train_engine: "Optional[str | TrainEngineBase]" = None,
        mesh=None,
        plan=None,
        engine_options: Optional[dict] = None,
        sharded_batch: int = 0,
    ):
        """``train_engine`` names the backend ('reference', 'packed',
        'sharded', or a built ``TrainEngineBase``); ``None`` auto-selects
        the fastest engine eligible for (cfg, mesh) via
        ``select_train_engine``.  ``engine_options`` are forwarded to the
        plugin constructor verbatim; ``plan`` opts training batches into
        the negotiated capacity envelope (``CapacityExceeded``).

        ``sharded_batch`` is the deprecated pre-engine spelling of the
        dist-mesh path; with ``mesh`` it maps to the 'sharded' engine
        pinned at that batch size (and warns, once per process)."""
        self.cfg = cfg
        self.key = key if key is not None else jax.random.key(0)
        options = dict(engine_options or {})
        if sharded_batch:
            _warn_legacy_sharded()
            if mesh is not None and train_engine is None:
                train_engine = "sharded"
                options.setdefault("batch", int(sharded_batch))
        if train_engine is None:
            train_engine = select_train_engine(cfg, mesh=mesh)
        self.engine = make_train_engine(
            train_engine, cfg, mesh=mesh, plan=plan, **options
        )
        if state is None:
            state = init_state(cfg, self.key)
        self._internal = self.engine.prepare(state)
        self.step_count = 0

    @property
    def train_engine(self) -> str:
        """Name of the active training backend plugin."""
        return self.engine.name

    # -- canonical-state boundary --------------------------------------------

    @property
    def state(self) -> jax.Array:
        """Canonical ``int32[M, C, 2F]`` TA state (converted from the
        engine's internal representation on access)."""
        return self.engine.canonical(self._internal)

    @state.setter
    def state(self, value) -> None:
        self._internal = self.engine.prepare(value)

    # -- training ------------------------------------------------------------

    def fine_tune(self, xb: np.ndarray, yb: np.ndarray) -> int:
        """One incremental update on a labelled batch; returns the step id
        the batch trained under (for exact replay/resume)."""
        step = self.step_count
        xb = jnp.asarray(np.asarray(xb, np.uint8))
        yb = jnp.asarray(np.asarray(yb, np.int32))
        self._internal = self.engine.fit_step(
            self._internal, self.key, xb, yb, step=step
        )
        self.step_count += 1
        return step

    def fine_tune_epochs(
        self, x: np.ndarray, y: np.ndarray, *, epochs: int, batch: int
    ) -> int:
        """Epoch loop over a buffered corpus (shuffled per epoch under the
        worker's own key stream); returns the number of steps taken."""
        n = x.shape[0]
        n_batches = max(1, n // batch)
        taken = 0
        for e in range(epochs):
            order = np.asarray(
                jax.random.permutation(
                    jax.random.fold_in(self.key, 0x7E000000 + self.step_count),
                    n,
                )
            )
            for b in range(n_batches):
                idx = order[b * batch : (b + 1) * batch]
                self.fine_tune(x[idx], y[idx])
                taken += 1
        return taken

    # -- snapshots (rollback support) ----------------------------------------

    def snapshot(self) -> np.ndarray:
        """Host copy of the canonical TA state (restore() it to undo
        fine-tuning — note train steps DONATE the internal state buffer,
        so the device array itself must not be aliased across steps)."""
        return np.asarray(self.state)

    def restore(self, snap: np.ndarray) -> None:
        self.state = jnp.asarray(snap)
