"""Drift detection over live prediction traffic (WHEN to recalibrate).

The monitor keeps two sliding windows over the slot's served predictions:

  * **accuracy** — fraction correct over the labelled tail of the window
    (labels arrive late and sparsely in the field; unlabelled rows simply
    don't enter this window);
  * **class-sum margin** — mean (top1 - top2) class-sum gap, a
    label-free confidence proxy.  Under concept drift the margin collapses
    well before labels confirm the accuracy drop, which is what lets the
    Fig-8 training node start retraining early.

``freeze_baseline()`` snapshots the healthy-traffic margin right after a
deploy; ``decision()`` then triggers when EITHER window degrades past its
threshold.  All statistics are windowed (bounded memory) — this runs
beside the serving loop for the lifetime of the deployment.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    """The monitor's verdict for the current window."""

    trigger: bool
    reason: str
    accuracy: Optional[float]  # None when the window has no labels
    margin: float
    baseline_margin: Optional[float]


class DriftMonitor:
    def __init__(
        self,
        *,
        window: int = 512,
        min_samples: int = 64,
        min_labelled: int = 32,
        accuracy_threshold: float = 0.90,
        margin_fraction: float = 0.6,
    ):
        """``margin_fraction``: trigger when the windowed margin falls
        below this fraction of the frozen baseline margin.
        ``min_labelled``: the accuracy trigger needs at least this many
        labelled rows in the window (labels are sparse in the field; one
        noisy label must not launch a recalibration)."""
        self.window = window
        self.min_samples = min_samples
        self.min_labelled = min_labelled
        self.accuracy_threshold = accuracy_threshold
        self.margin_fraction = margin_fraction
        self._correct: deque = deque(maxlen=window)
        self._margins: deque = deque(maxlen=window)
        self._baseline_margin: Optional[float] = None

    # -- ingest --------------------------------------------------------------

    def observe(
        self,
        class_sums: np.ndarray,  # int[B, M] engine output
        preds: np.ndarray,  # int[B] served predictions
        labels: Optional[np.ndarray] = None,  # int[B] when ground truth exists
    ) -> None:
        sums = np.asarray(class_sums)
        if sums.ndim != 2 or sums.shape[0] != np.asarray(preds).shape[0]:
            raise ValueError(
                f"class_sums {sums.shape} does not match preds "
                f"{np.asarray(preds).shape}"
            )
        if sums.shape[1] >= 2:
            top2 = np.partition(sums, -2, axis=1)[:, -2:]
            self._margins.extend((top2[:, 1] - top2[:, 0]).tolist())
        else:
            self._margins.extend(sums[:, 0].tolist())
        if labels is not None:
            self._correct.extend(
                (np.asarray(preds) == np.asarray(labels)).tolist()
            )

    def freeze_baseline(self) -> float:
        """Snapshot the current margin as the healthy reference (call after
        a deploy, on traffic the model is known to serve well)."""
        self._baseline_margin = self.margin
        return self._baseline_margin

    def reset(self) -> None:
        """Clear the windows (call after a recalibration swap so stale
        pre-swap statistics don't immediately re-trigger)."""
        self._correct.clear()
        self._margins.clear()

    # -- statistics ----------------------------------------------------------

    @property
    def n_samples(self) -> int:
        return len(self._margins)

    @property
    def margin(self) -> float:
        return float(np.mean(self._margins)) if self._margins else 0.0

    @property
    def accuracy(self) -> Optional[float]:
        if not self._correct:
            return None
        return float(np.mean(self._correct))

    # -- verdict -------------------------------------------------------------

    def decision(self) -> DriftDecision:
        acc = self.accuracy
        margin = self.margin
        if self.n_samples < self.min_samples:
            return DriftDecision(False, "warmup", acc, margin,
                                 self._baseline_margin)
        if (
            acc is not None
            and len(self._correct) >= self.min_labelled
            and acc < self.accuracy_threshold
        ):
            return DriftDecision(
                True,
                f"accuracy {acc:.3f} < {self.accuracy_threshold}",
                acc, margin, self._baseline_margin,
            )
        if (
            self._baseline_margin is not None
            and self._baseline_margin > 0
            and margin < self.margin_fraction * self._baseline_margin
        ):
            return DriftDecision(
                True,
                f"margin {margin:.2f} < {self.margin_fraction:.2f} x "
                f"baseline {self._baseline_margin:.2f}",
                acc, margin, self._baseline_margin,
            )
        return DriftDecision(False, "healthy", acc, margin,
                             self._baseline_margin)
