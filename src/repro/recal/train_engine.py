"""The formal TrainEngine plugin protocol + registry.

The training twin of ``accel.engine``: where an inference *engine* is one
realization of the runtime-tunable accelerator, a *train engine* is one
realization of the Fig-8 training node.  Every plugin honours one
contract, built around the fold-in seeding contract of ``core.train``:

  ``prepare(state)``        canonical ``int32[M, C, 2F]`` TA state ->
                            the engine's internal representation (the
                            packed engine keeps int8 across steps; the
                            reference/sharded engines are identity)
  ``canonical(internal)``   internal -> canonical int32 state (what
                            checkpoints, compressors and other engines
                            consume — the (key, step, state) triple
                            round-trips across backends)
  ``fit_step(internal, key, xb, yb, step=)``
                            one resumable update: the batch trains under
                            ``fold_in(key, step)``, sample ``i`` under
                            ``fold_in(call_key, i)``.  Every registered
                            engine produces the BIT-IDENTICAL canonical
                            state for the same (key, step, batch) —
                            backend choice is a speed knob, never a
                            semantics knob (property-tested).

Engines self-describe through capability flags set by
``@register_train_engine``:

  ``needs_mesh``            consumes a device mesh (the class-sharded
                            dist step);
  ``priority``              relative speed rank used by
                            ``select_train_engine`` to auto-pick the
                            fastest eligible engine;

plus a per-class ``supports(cfg)`` hook for representation limits (the
packed int8 layout holds at most 128 states per action).

Construction is uniform: ``make_train_engine(name, cfg, *, mesh=None,
plan=None, **options)`` — mesh and implementation knobs are per-engine
options, not special-cased branches (``RecalWorker`` no longer branches
on ``use_dist_mesh``-style arguments).  ``plan`` opts every engine into
the negotiated ``CapacityPlan`` batch envelope: a training batch wider
than ``plan.batch_words * 32`` raises the structured
``CapacityExceeded``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from ..core.tm import TMConfig
from ..core.train import fit_step as _core_fit_step
from ..core.train import validate_batch_capacity
from ..kernels.tm_train import (
    fused_train_batch,
    pack_ta_state,
    supports_packed_states,
    unpack_ta_state,
)

Array = jax.Array

# name -> engine class; populated by @register_train_engine (the three
# built-ins below register on import)
TRAIN_ENGINES: Dict[str, type] = {}


@runtime_checkable
class TrainEngine(Protocol):
    """Structural type of a training backend (see module docstring)."""

    name: str
    needs_mesh: bool
    priority: int
    cfg: TMConfig

    def prepare(self, state) -> Any: ...

    def canonical(self, internal) -> Array: ...

    def fit_step(self, internal, key, xb, yb, *, step: int) -> Any: ...


def register_train_engine(
    name: str, *, needs_mesh: bool = False, priority: int = 0
):
    """Class decorator registering a train-engine plugin under ``name``
    and stamping its capability flags.  Re-registering a taken name
    raises — auto-selection must be deterministic."""

    def deco(cls):
        if name in TRAIN_ENGINES and TRAIN_ENGINES[name] is not cls:
            raise ValueError(
                f"train engine name {name!r} already registered to "
                f"{TRAIN_ENGINES[name].__name__}"
            )
        cls.name = name
        cls.needs_mesh = bool(needs_mesh)
        cls.priority = int(priority)
        TRAIN_ENGINES[name] = cls
        return cls

    return deco


def train_engine_names() -> list:
    return sorted(TRAIN_ENGINES)


def select_train_engine(
    cfg: Optional[TMConfig] = None, *, mesh=None
) -> str:
    """Deterministically pick the fastest eligible train engine name.

    With a mesh, mesh-consuming engines are the eligible set — the
    caller provisioned devices for exactly them.  Without one, the
    fastest mesh-free engine that ``supports(cfg)`` wins (the packed
    engine bows out for configs outside its int8 state range).  Ties
    break lexicographically so selection is stable across processes."""
    eligible = [
        c
        for c in TRAIN_ENGINES.values()
        if c.needs_mesh == (mesh is not None)
        and (cfg is None or c.supports(cfg))
    ]
    if not eligible:
        raise ValueError(
            f"no eligible train engine "
            f"(mesh={'yes' if mesh is not None else 'no'}; "
            f"registered: {train_engine_names() or 'none'})"
        )
    return max(eligible, key=lambda c: (c.priority, c.name)).name


def make_train_engine(
    engine: "str | TrainEngineBase",
    cfg: TMConfig,
    *,
    mesh=None,
    plan=None,
    **options,
) -> "TrainEngineBase":
    """Uniform plugin construction: name (or a built instance) -> engine.

    ``options`` go to the engine verbatim; the mesh is forwarded only to
    engines that declare ``needs_mesh`` (capability-flag-driven, the same
    rule as ``accel.make_engine``)."""
    if isinstance(engine, TrainEngineBase):
        return engine
    if engine not in TRAIN_ENGINES:
        raise ValueError(
            f"unknown train engine {engine!r}; registered: "
            f"{train_engine_names()}"
        )
    cls = TRAIN_ENGINES[engine]
    if cls.needs_mesh and mesh is not None:
        options = {**options, "mesh": mesh}
    return cls(cfg, plan=plan, **options)


class TrainEngineBase:
    """Shared train-engine mechanics: batch-envelope validation and the
    canonical-representation identity hooks."""

    name = "?"
    needs_mesh = False
    priority = 0

    def __init__(self, cfg: TMConfig, *, plan=None):
        self.cfg = cfg
        self.plan = plan

    @classmethod
    def supports(cls, cfg: TMConfig) -> bool:
        """Whether this engine's representation can hold ``cfg`` (the
        packed int8 layout narrows this; the default is unconditional)."""
        return True

    # -- representation ------------------------------------------------------

    def prepare(self, state) -> Any:
        """Canonical int32 state -> engine-internal representation.

        Always a fresh buffer: train steps DONATE the internal state, so
        aliasing the caller's array would delete it out from under them."""
        return jnp.array(state)

    def canonical(self, internal) -> Array:
        """Engine-internal representation -> canonical int32 state."""
        return internal

    # -- the step ------------------------------------------------------------

    def fit_step(self, internal, key, xb, yb, *, step: int) -> Any:
        """One resumable update under the fold-in seeding contract.
        Validates the negotiated batch envelope (when a plan was given)
        before dispatching to the engine-specific ``_fit_step``."""
        validate_batch_capacity(xb.shape[0], self.plan)
        return self._fit_step(internal, key, xb, yb, step=step)

    def _fit_step(self, internal, key, xb, yb, *, step: int) -> Any:
        raise NotImplementedError


@register_train_engine("reference", priority=1)
class ReferenceTrainEngine(TrainEngineBase):
    """The host reference path: ``core.train.fit_step`` on the canonical
    int32 state.  ``parallel=True`` (summed-delta) is the default — the
    semantics every other engine is bit-identical to; ``parallel=False``
    opts into the sequential online scan (a different, slower contract
    no other engine implements)."""

    def __init__(self, cfg: TMConfig, *, plan=None, parallel: bool = True):
        super().__init__(cfg, plan=plan)
        self.parallel = bool(parallel)

    def _fit_step(self, internal, key, xb, yb, *, step: int):
        return _core_fit_step(
            self.cfg, internal, key, xb, yb,
            step=step, parallel=self.parallel,
        )


@register_train_engine("packed", priority=2)
class PackedTrainEngine(TrainEngineBase):
    """The fused packed-TA path (``kernels.tm_train``): int8 states in
    the flat (clauses, literals, 2) layout, clause-eval + feedback + TA
    update in one compiled pass over packed uint32 literal bitplanes.
    Bit-identical to ``reference`` and internal-state persistent: the
    int8 tensor survives across steps; conversion happens only at the
    ``prepare``/``canonical`` checkpoint boundary."""

    def __init__(self, cfg: TMConfig, *, plan=None):
        super().__init__(cfg, plan=plan)
        if not supports_packed_states(cfg):
            raise ValueError(
                f"n_states={cfg.n_states} exceeds the packed int8 TA "
                f"range (<= 128); use the 'reference' or 'sharded' train "
                f"engines for this config"
            )

    @classmethod
    def supports(cls, cfg: TMConfig) -> bool:
        return supports_packed_states(cfg)

    def prepare(self, state) -> Array:
        return pack_ta_state(self.cfg, state)

    def canonical(self, internal) -> Array:
        return unpack_ta_state(self.cfg, internal)

    def _fit_step(self, internal, key, xb, yb, *, step: int):
        kb = jax.random.fold_in(key, step)
        return fused_train_batch(self.cfg, internal, kb, xb, yb)


@register_train_engine("sharded", needs_mesh=True, priority=1)
class ShardedTrainEngine(TrainEngineBase):
    """The dist-mesh class-sharded step (``dist.steps.make_tm_train_step``:
    classes over ``model``, batch over the data axes, psum'd integer
    deltas — bit-identical to the reference on any mesh).

    The sharded step compiles for ONE batch size.  ``batch`` pins it at
    construction; otherwise it binds to the first batch seen.  Other
    batch sizes fall back to the reference path (bit-identical anyway) —
    ragged tail batches never force a recompile, the same discipline the
    serving engines keep (``compile_cache_size() == 1``)."""

    def __init__(self, cfg: TMConfig, *, mesh, plan=None, batch: int = 0):
        super().__init__(cfg, plan=plan)
        self.mesh = mesh
        self._step = None
        self._batch = int(batch)
        if self._batch:
            self._build(self._batch)

    def _build(self, batch: int) -> None:
        from ..dist.steps import make_tm_train_step

        self._step = make_tm_train_step(self.cfg, self.mesh, batch=batch)
        self._batch = batch

    def _fit_step(self, internal, key, xb, yb, *, step: int):
        if self._step is None:
            self._build(int(xb.shape[0]))
        if xb.shape[0] == self._batch:
            # same bits as the local path: fold_in(key, step) is the call
            # key, global sample i trains under fold_in(call_key, i)
            kb = jax.random.fold_in(key, step)
            return self._step(internal, kb, xb, yb)
        return _core_fit_step(
            self.cfg, internal, key, xb, yb, step=step, parallel=True
        )
