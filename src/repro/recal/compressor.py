"""Trained TA state -> validated ``CompressedModel`` (WHAT gets shipped).

The compression stage of the Fig-8 loop.  Encoding is the cheap part; the
point of this class is the *publication gate*: before a stream may be
hot-swapped into a live accelerator it is decoded back and checked
bit-exact against the dense oracle (``core.compress.validate_roundtrip``)
on a deterministic probe batch plus, optionally, a sample of real traffic.
A model that fails the gate never reaches the registry.

With a ``CapacityPlan``, the gate also covers the deployment envelope:
the model must FIT the plan (``CapacityExceeded`` otherwise — better to
learn that on the training node than on the live accelerator's load
path), and the report carries the stamped, checksummed ``TMProgram``
artifact — the wire-portable thing the controller actually publishes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..accel.capacity import CapacityPlan
from ..accel.program import TMProgram
from ..core.compress import CompressedModel, encode, validate_roundtrip
from ..core.tm import TMConfig, include_actions
from ..prune import PrunePolicy, PruneReport


@dataclasses.dataclass(frozen=True)
class CompressionReport:
    """What the compressor hands the controller alongside the model."""

    model: CompressedModel
    n_includes: int
    compression_ratio: float
    probe_rows: int
    artifact: Optional[TMProgram] = None  # stamped when a plan was given
    prune: Optional[PruneReport] = None  # stamped when a policy ran
    # per-knob (name, provisioned, reclaimable) rows with reclaimable > 0:
    # how much tighter a renegotiated envelope could be for THIS artifact
    shrink: Tuple[Tuple[str, int, int], ...] = ()


class Compressor:
    def __init__(
        self,
        *,
        probe_rows: int = 64,
        probe_seed: int = 0,
        plan: Optional[CapacityPlan] = None,
        engine=None,
        validate_knobs=None,
    ):
        """``plan`` turns the gate capacity-aware and the report
        artifact-bearing.  Pass the TARGET ``engine`` to gate on exactly
        the check its load path will repeat (``Engine.validate_model`` —
        a publication the gate passes can never crash the hot-swap);
        ``validate_knobs`` instead narrows a plain plan check to a knob
        subset (None = the full envelope, conservative for every
        engine)."""
        self.probe_rows = probe_rows
        self.probe_seed = probe_seed
        self.engine = engine
        if plan is None and engine is not None:
            # engines carry .plan; ServingNode-shaped gates carry .capacity
            plan = getattr(engine, "plan", None)
            if plan is None:
                plan = getattr(engine, "capacity", None)
        self.plan = plan
        self.validate_knobs = validate_knobs

    def compress(
        self,
        cfg: TMConfig,
        state,
        *,
        traffic_sample: Optional[np.ndarray] = None,
        labels: Optional[np.ndarray] = None,
        prune: Optional[PrunePolicy] = None,
    ) -> CompressionReport:
        """Encode + validate.  ``traffic_sample`` ({0,1}[B, F]) extends the
        deterministic probe with rows from the live distribution, so the
        gate exercises exactly the inputs the swap will face.

        ``prune`` runs the compression pass between train and publish:
        the policy sees the traffic sample (ranking + ranked-drop gating,
        when ``labels`` accompany it) and the PRUNED actions/weights are
        what gets encoded — the roundtrip gate then proves the pruned
        weighted stream against the pruned dense oracle, so an unsound
        prune is refused publication exactly like a corrupt encode."""
        actions = np.asarray(include_actions(cfg, state))
        weights = None
        prune_report = None
        if prune is not None:
            result = prune.apply(
                cfg, actions, X=traffic_sample, y=labels
            )
            actions, weights = result.actions, result.weights
            prune_report = result.report
        model = encode(cfg, actions, clause_weights=weights)
        rng = np.random.default_rng(self.probe_seed)
        probe = rng.integers(
            0, 2, (self.probe_rows, cfg.n_features)
        ).astype(np.uint8)
        if traffic_sample is not None:
            sample = np.asarray(traffic_sample, np.uint8)
            if sample.ndim != 2 or sample.shape[1] != cfg.n_features:
                raise ValueError(
                    f"traffic_sample must be {{0,1}}[B, {cfg.n_features}], "
                    f"got {sample.shape}"
                )
            probe = np.concatenate([probe, sample], axis=0)
        validate_roundtrip(cfg, actions, model, probe, clause_weights=weights)
        artifact = None
        if self.engine is not None:
            # the capacity half of the gate: raises CapacityExceeded with
            # the offending knob before anything touches a live slot —
            # the exact check the target engine's load path will repeat
            self.engine.validate_model(model)
            artifact = TMProgram(capacity=self.plan, model=model)
        elif self.plan is not None:
            self.plan.validate(model, self.validate_knobs)
            artifact = TMProgram(capacity=self.plan, model=model)
        shrink: Tuple[Tuple[str, int, int], ...] = ()
        if artifact is not None:
            # envelope-renegotiation intel for the operator: how much of
            # the provisioned plan this (possibly pruned) artifact no
            # longer needs.  Diagnostics only — the published artifact
            # keeps the negotiated plan so no engine recompiles.
            shrink = tuple(
                row for row in artifact.capacity.shrink_diagnostics(model)
                if row[2] > 0
            )
        return CompressionReport(
            model=model,
            n_includes=int(actions.sum()),
            compression_ratio=model.compression_ratio(cfg),
            probe_rows=probe.shape[0],
            artifact=artifact,
            prune=prune_report,
            shrink=shrink,
        )
