"""Analytic corrections for XLA cost-analysis under-counting.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, not trip_count
times (verified empirically in this repo; see EXPERIMENTS.md §Dry-run
methodology).  Two mechanisms recover the true totals:

1. **Layer-stack extrapolation** (launch/dryrun.py): every model scans its
   layer stack, so metrics are affine in the unit count u:
        m(u) = intercept + u * per_unit
   We compile u=1 and u=2 variants and extrapolate to the real depth.
   This is exact for flops/bytes/collective-bytes of everything outside
   within-layer loops.

2. **Within-layer scan corrections** (this module): loops nested inside a
   single layer body are still counted once.  The offenders and their
   closed-form additions (GLOBAL flops; caller divides by chip count):

   * streaming attention over nB KV blocks (models/common.py):
       add (nB-1)/nB * 4*B*Sq*Skv_pad*Hq*hd per layer application
       (blocks are computed densely — masked positions are still MACs)
   * mLSTM chunk scan over nC chunks (models/xlstm.py):
       intra-chunk  4*B*S*Q*H*hd  +  state einsums  4*B*S*H*hd^2
   * sLSTM per-token scan (S steps):   (S-1) * (8*B*D^2 + 8*B*H*hd^2)
   * xLSTM prefill per-token scans:    (S-1) * (8*B*D^2 + 6*B*H*hd^2) * 2
   * Mamba2 inter-chunk scan: body is elementwise state decay (~B*H*N*P)
     — negligible, NOT corrected (documented).

   Training multiplies by MULT_TRAIN = 4 (forward + remat-forward + ~2x
   backward); prefill by 1; decode paths contain no within-layer scans.

These corrections are estimates (relative error ~1/nB of the attention
term); the dry-run JSON records raw, extrapolated and corrected values
separately so the provenance is auditable.
"""

from __future__ import annotations

import math

from ..configs.base import ArchConfig, ShapeSpec
from ..models.common import ATTN_CHUNK, ATTN_CHUNK_THRESHOLD

MULT_TRAIN = 4.0
MLSTM_CHUNK = 256


def _attn_correction(B, Sq, Skv, Hq, hd, n_apps: float, mult: float) -> float:
    if Sq <= 1 or Skv <= ATTN_CHUNK_THRESHOLD:
        return 0.0  # plain path: fully counted
    nB = math.ceil(Skv / ATTN_CHUNK)
    skv_pad = nB * ATTN_CHUNK
    full = 4.0 * B * Sq * skv_pad * Hq * hd
    return n_apps * mult * full * (nB - 1) / nB


def scan_correction_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Additive GLOBAL flops missing from the layer-extrapolated metrics."""
    B = shape.global_batch
    S = shape.seq_len
    mult = MULT_TRAIN if shape.kind == "train" else 1.0
    if shape.kind == "decode":
        return 0.0

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _attn_correction(
            B, S, S, cfg.n_heads, cfg.head_dim, cfg.n_layers, mult
        )
    if fam == "encdec":
        # decoder self-attention only (encoder S=1500 and cross-attn use the
        # plain, fully-counted path)
        return _attn_correction(
            B, S, S, cfg.n_heads, cfg.head_dim, cfg.n_layers, mult
        )
    if fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        return _attn_correction(B, S, S, cfg.n_heads, cfg.head_dim, g, mult)
    if fam == "ssm_xlstm":
        pairs = cfg.n_layers // 2
        D = cfg.d_model
        H = cfg.n_heads
        hd = D // H
        if shape.kind == "train":
            Q = min(MLSTM_CHUNK, S)
            nC = S // Q
            f_mlstm = 4.0 * B * S * Q * H * hd + 4.0 * B * S * H * hd * hd
            f_slstm = (S - 1.0) * (8.0 * B * D * D + 8.0 * B * H * hd * hd)
            return pairs * mult * (f_mlstm * (nC - 1) / max(nC, 1) + f_slstm)
        # prefill: per-token decode-step scans for both cores
        f_step = (8.0 * B * D * D + 6.0 * B * H * hd * hd) + (
            8.0 * B * D * D + 8.0 * B * H * hd * hd
        )
        return pairs * (S - 1.0) * f_step
    return 0.0
