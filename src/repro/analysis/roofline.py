"""Roofline-term derivation from compiled XLA artifacts (no real hardware).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

The compiled module is the per-device SPMD program, so ``cost_analysis()``
FLOPs/bytes are per-device, and collective operand bytes parsed from the
post-partitioning HLO are per-device too.  Terms (seconds):

    compute    = flops_per_device / PEAK_FLOPS
    memory     = hbm_bytes_per_device / HBM_BW
    collective = collective_operand_bytes_per_device / ICI_BW
                 (== global_collective_bytes / (chips * ICI_BW))
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],{}\/ ]+?))\s+([\w\-]+)\("
)
_TYPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]"
)
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")


def cost_analysis_dict(cost) -> Dict[str, float]:
    """Normalize Compiled.cost_analysis() output across jax versions:
    0.4.x returns a one-element list of dicts, newer jax a flat dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _types_bytes(type_str: str) -> int:
    return sum(_shape_bytes(t, d) for t, d in _TYPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum OPERAND bytes of every collective op, per kind (per-device).

    Post-partitioning CPU HLO lists operands by name only, so this is a
    two-pass parse: 1) map op name -> result type, 2) resolve collective
    operand names.  ``-start`` async halves are counted; their ``-done``
    halves are not.  Collectives inside while bodies appear once — the
    dry-run's layer extrapolation recovers trip counts.
    """
    defs: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = op[: -len("-start")] if op.endswith("-start") else op
        if kind not in _COLLECTIVES:
            continue
        rest = line[m.end() - 1 :]
        om = _OPERAND_RE.search(rest)
        operands = []
        if om and om.group(1):
            operands = [o.strip() for o in om.group(1).split(",")]
        got = 0
        for name in operands:
            if name in defs:
                got += _types_bytes(defs[name])
        if got == 0:  # fallback: result size (== operand size for all-reduce)
            got = _types_bytes(m.group(2))
        out[kind] += got
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_by_kind: Dict[str, int]
    model_flops_global: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_flops_ratio: float
    peak_fraction: float  # model_flops / (chips * PEAK * t_bound)
    memory_analysis: Dict[str, float]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)


def build_roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops_global: float,
    memory_analysis: Optional[Dict[str, float]] = None,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll_total / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(t_c, t_m, t_x)
    useful = model_flops_global / (flops * chips) if flops > 0 else 0.0
    peak_frac = (
        model_flops_global / (chips * PEAK_FLOPS * t_bound) if t_bound > 0 else 0.0
    )
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=coll_total,
        collective_by_kind={k: v for k, v in coll.items() if v},
        model_flops_global=model_flops_global,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        useful_flops_ratio=useful,
        peak_fraction=peak_frac,
        memory_analysis=memory_analysis or {},
    )


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6·N·D for training, 2·N·D for inference steps (dense approximation;
    MoE uses active params)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch
