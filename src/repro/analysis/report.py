"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

Adds the fused-memory lower bound: XLA-CPU ``bytes accessed`` counts every
operand/result of every op (an UNFUSED upper bound on HBM traffic — the CPU
backend does not fuse like the TPU backend).  The fused lower bound models
perfect producer-consumer fusion: every live buffer moves once each way,

    bytes_lower ~= argument + output + 2 * temp   (memory_analysis sizes)

The true TPU number lies between; we classify the bottleneck with the lower
bound (closer to a fused TPU program) and report both.

    PYTHONPATH=src python -m repro.analysis.report > experiments/roofline.md
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .roofline import HBM_BW, PEAK_FLOPS

HERE = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))
DRYRUN_DIR = os.path.join(HERE, "experiments", "dryrun")

SKIPPED_LONG = [
    ("starcoder2-7b", "full attention is O(S^2); no published sub-quadratic variant"),
    ("stablelm-12b", "full attention"),
    ("deepseek-7b", "full attention"),
    ("stablelm-3b", "full attention"),
    ("llama4-maverick-400b-a17b", "full attention"),
    ("moonshot-v1-16b-a3b", "full attention"),
    ("whisper-medium", "full-attention decoder"),
    ("internvl2-26b", "full attention"),
]


def enrich(d: Dict) -> Dict:
    ma = d.get("memory_analysis", {})
    lower = (
        ma.get("argument_size_in_bytes", 0)
        + ma.get("output_size_in_bytes", 0)
        + 2 * ma.get("temp_size_in_bytes", 0)
    )
    d["t_memory_lower"] = lower / HBM_BW
    d["t_memory_upper"] = d["t_memory"]
    terms = {
        "compute": d["t_compute"],
        "memory": d["t_memory_lower"],
        "collective": d["t_collective"],
    }
    d["bottleneck_fused"] = max(terms, key=terms.get)
    t_bound = max(terms.values())
    mf = d.get("model_flops_global", 0)
    d["peak_fraction_fused"] = (
        mf / (d["chips"] * PEAK_FLOPS * t_bound) if t_bound > 0 and mf > 0 else 0.0
    )
    return d


def load(mesh: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        d = json.load(open(f))
        if "t_compute" not in d:
            continue
        out.append(enrich(d))
    return out


def ms(x: float) -> str:
    return f"{x * 1e3:.1f}"


def table(mesh: str) -> str:
    rows = load(mesh)
    hdr = (
        "| arch | shape | t_comp ms | t_mem ms [fused..unfused] | t_coll ms "
        "| bottleneck | MODEL/HLO flops | peak frac | HBM/dev GB |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for d in rows:
        ma = d.get("memory_analysis", {})
        hbm = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0)) / 1e9
        lines.append(
            f"| {d['arch']} | {d['shape']} | {ms(d['t_compute'])} "
            f"| {ms(d['t_memory_lower'])}..{ms(d['t_memory_upper'])} "
            f"| {ms(d['t_collective'])} | {d['bottleneck_fused']} "
            f"| {d.get('useful_flops_ratio', 0):.2f} "
            f"| {100 * d.get('peak_fraction_fused', 0):.1f}% | {hbm:.1f} |"
        )
    skip = "\n".join(
        f"| {a} | long_500k | — | — | — | SKIP ({why}) | — | — | — |"
        for a, why in SKIPPED_LONG
    )
    return hdr + "\n".join(lines) + "\n" + skip + "\n"


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n### Mesh {mesh} ({rows[0]['chips']} chips)\n")
        print(table(mesh))


if __name__ == "__main__":
    main()
