"""Data pipeline: deterministic synthetic streams (token LM + TM datasets).

Synthetic-but-structured: token streams are Zipf-distributed with Markov
bigram structure (so training loss measurably decreases), sharded by host
and placed with the mesh batch sharding.  TM datasets replicate the UCI
edge-dataset dimensionalities used by the paper's Table 2.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenStream:
    """Deterministic, restartable synthetic LM token stream.

    ``state()``/``restore()`` give exact-resume semantics so checkpoint
    restarts do not replay or skip batches (fault-tolerance property,
    tested in tests/test_ft.py)."""

    def __init__(self, cfg: TokenStreamConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step

    def state(self) -> int:
        return self._step

    def restore(self, state: int) -> None:
        self._step = state

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ self._step)
        self._step += 1
        # zipf body + bigram structure: next token correlated with previous
        base = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len))
        base = np.minimum(base - 1, cfg.vocab - 1).astype(np.int32)
        shift = np.roll(base, 1, axis=1)
        mix = rng.random((cfg.global_batch, cfg.seq_len)) < 0.3
        tokens = np.where(mix, (shift * 7 + 13) % cfg.vocab, base)
        return {"tokens": tokens.astype(np.int32)}


# ---------------------------------------------------------------------------
# TM edge datasets (paper Table 2 dimensionalities)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TMDatasetSpec:
    name: str
    n_raw_features: int
    n_classes: int
    thermometer_bits: int
    n_clauses: int  # per class, as used for the paper-scale models


# Feature/class counts follow the public UCI datasets the paper evaluates
# (EMG [10], Human Activity [19], Gesture Phase [14], Sensorless Drives [4],
# Gas Sensor Array Drift [24]); data itself is synthesized with per-class
# Gaussian prototypes + noise so the pipeline is self-contained/offline.
TM_DATASETS = {
    "emg": TMDatasetSpec("emg", 8, 4, 8, 100),
    "har": TMDatasetSpec("har", 561, 6, 2, 100),
    "gesture": TMDatasetSpec("gesture", 18, 5, 6, 100),
    "sensorless": TMDatasetSpec("sensorless", 48, 11, 4, 100),
    "gas": TMDatasetSpec("gas", 128, 6, 4, 100),
    "mnist": TMDatasetSpec("mnist", 784, 10, 1, 200),
}


def make_tm_dataset(
    spec: TMDatasetSpec, n: int, seed: int = 0, drift: float = 0.0
) -> Tuple[np.ndarray, np.ndarray]:
    """-> (X float[n, F_raw], y int[n]).

    Class prototypes are keyed by the DATASET identity (so train/test splits
    share a distribution); ``seed`` only draws the samples.  ``drift`` shifts
    the prototypes deterministically (sensor aging / environment change —
    the paper's Fig 8 recalibration trigger).  The identity hash is a stable
    CRC (NOT the salted builtin ``hash``), so the same dataset is generated
    across processes and machines — the recal example/bench rely on it."""
    proto_seed = zlib.crc32(spec.name.encode()) % (2**31)
    rng_proto = np.random.default_rng(proto_seed)
    protos = rng_proto.normal(size=(spec.n_classes, spec.n_raw_features))
    if drift:
        rng_drift = np.random.default_rng(proto_seed + int(drift * 1000) + 1)
        protos = protos + drift * rng_drift.normal(size=protos.shape)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, spec.n_classes, size=n)
    x = protos[y] + 0.6 * rng.normal(size=(n, spec.n_raw_features))
    return x.astype(np.float32), y.astype(np.int32)


def booleanized_tm_dataset(
    spec: TMDatasetSpec, n: int, seed: int = 0, drift: float = 0.0,
    booleanizer=None,
):
    """-> (X_bool uint8[n, F_bool], y, booleanizer)."""
    from ..core.booleanize import Booleanizer

    x, y = make_tm_dataset(spec, n, seed=seed, drift=drift)
    if booleanizer is None:
        booleanizer = Booleanizer.fit(x, bits=spec.thermometer_bits)
    return booleanizer.transform(x), y, booleanizer


def shard_batch(batch: Dict[str, np.ndarray], mesh, shardings) -> Dict[str, jax.Array]:
    return jax.tree.map(
        lambda x, sh: jax.device_put(x, sh), batch, shardings
    )
