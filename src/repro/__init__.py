"""repro: Runtime Tunable Tsetlin Machines (tinyML'25) as a multi-pod JAX framework."""

__version__ = "1.0.0"
