"""Shared model components: norms, RoPE, GQA attention (+KV cache), MLP,
embedding, loss.  All layer stacks are scanned (compact HLO at any depth)
and rematerialized (activation checkpointing) in training.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Array = jax.Array
PyTree = Any


def sds(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return out


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

ATTN_CHUNK = 1024  # kv-block size for the streaming-softmax path
ATTN_CHUNK_THRESHOLD = 2048  # use streaming path when Skv exceeds this


def _plain_attention(q, k, v, *, causal, q_offset, window, kv_len):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, hd)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))

    q_pos = jnp.arange(Sq)[:, None] + q_offset  # [Sq, 1]
    k_pos = jnp.arange(Skv)[None, :]  # [1, Skv]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# flash attention with custom VJP: streaming-softmax forward + recompute-based
# backward, so neither direction materializes [.., Sq, Skv] for more than one
# KV block.  This is the TPU-idiomatic (VMEM-block-resident) formulation.
# ---------------------------------------------------------------------------

def _block_mask(Sq, C, j, q_offset, causal, window, Skv):
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = j * C + jnp.arange(C)[None, :]
    mask = k_pos < Skv
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask


def _flash_fwd_scan(qg, kb, vb, *, causal, q_offset, window, Skv):
    """qg: [B,Sq,Hkv,rep,hd] (pre-scaled fp32); kb/vb: [nB,B,C,Hkv,hd].
    -> (out fp32 [B,Sq,Hkv,rep,hd], m, l  [B,Hkv,rep,Sq])"""
    B, Sq, Hkv, rep, hd = qg.shape
    nB, _, C = kb.shape[0], kb.shape[1], kb.shape[2]

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kj.astype(jnp.float32))
        mask = _block_mask(Sq, C, j, q_offset, causal, window, Skv)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bhrqk,bkhd->bqhrd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(nB))
    )
    return acc, m, l


def _flash_prep(q, k, v):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    C = ATTN_CHUNK
    nB = -(-Skv // C)
    pad = nB * C - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).reshape(
        B, Sq, Hkv, rep, hd
    )
    kb = jnp.moveaxis(k.reshape(B, nB, C, Hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nB, C, Hkv, hd), 1, 0)
    return qg, kb, vb, nB, C, pad


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attention(q, k, v, causal, q_offset, window):
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    qg, kb, vb, nB, C, pad = _flash_prep(q, k, v)
    acc, m, l = _flash_fwd_scan(
        qg, kb, vb, causal=causal, q_offset=q_offset, window=window, Skv=Skv
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def _flash_fwd(q, k, v, causal, q_offset, window):
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    qg, kb, vb, nB, C, pad = _flash_prep(q, k, v)
    acc, m, l = _flash_fwd_scan(
        qg, kb, vb, causal=causal, q_offset=q_offset, window=window, Skv=Skv
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    res = (q, k, v, out.astype(q.dtype), m, l)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype), res


def _flash_bwd(causal, q_offset, window, res, dout):
    q, k, v, out, m, l = res  # out: [B,Sq,Hkv,rep,hd]
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    qg, kb, vb, nB, C, pad = _flash_prep(q, k, v)
    do = dout.reshape(out.shape).astype(jnp.float32)  # [B,Sq,Hkv,rep,hd]
    out32 = out.astype(jnp.float32)
    linv = 1.0 / jnp.maximum(l, 1e-30)  # [B,Hkv,rep,Sq]
    # delta = rowsum(dout * out)  [B,Hkv,rep,Sq]
    delta = jnp.einsum("bqhrd,bqhrd->bhrq", do, out32)

    def body(dq, inp):
        kj, vj, j = inp
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kj.astype(jnp.float32))
        mask = _block_mask(Sq, C, j, q_offset, causal, window, Skv)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jnp.exp(s - m[..., None]) * linv[..., None]  # normalized probs
        dv_j = jnp.einsum("bhrqk,bqhrd->bkhd", p, do)
        dp = jnp.einsum("bqhrd,bkhd->bhrqk", do, vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bhrqk,bkhd->bqhrd", ds, kj.astype(jnp.float32))
        dk_j = jnp.einsum("bhrqk,bqhrd->bkhd", ds, qg)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros_like(qg)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nB)))
    dq = (dq / jnp.sqrt(jnp.float32(hd))).reshape(B, Sq, Hq, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, nB * C, k.shape[2], hd)[:, :Skv]
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, nB * C, v.shape[2], hd)[:, :Skv]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def gqa_attention(
    q: Array,  # [B, Sq, Hq, hd]
    k: Array,  # [B, Skv, Hkv, hd]
    v: Array,  # [B, Skv, Hkv, hd]
    *,
    causal: bool,
    q_offset: Array | int = 0,  # absolute position of q[0] (decode)
    window: int = 0,  # sliding window (0 = unlimited)
    kv_len: Array | None = None,  # valid kv prefix length (decode masking)
) -> Array:
    Sq, Skv = q.shape[1], k.shape[1]
    if Sq > 1 and Skv > ATTN_CHUNK_THRESHOLD and kv_len is None:
        return _flash_attention(q, k, v, causal, q_offset, window)
    return _plain_attention(
        q, k, v, causal=causal, q_offset=q_offset, window=window, kv_len=kv_len
    )


class AttnParams(NamedTuple):
    wq: Array  # [D, Hq*hd]
    wk: Array  # [D, Hkv*hd]
    wv: Array  # [D, Hkv*hd]
    wo: Array  # [Hq*hd, D]


def attn_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> AttnParams:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return AttnParams(
        wq=sds((D, Hq * hd), dtype),
        wk=sds((D, Hkv * hd), dtype),
        wv=sds((D, Hkv * hd), dtype),
        wo=sds((Hq * hd, D), dtype),
    )


def attention_block(
    p: AttnParams,
    x: Array,  # [B, S, D]
    cfg: ArchConfig,
    *,
    positions: Array,  # [S] absolute positions for RoPE
    causal: bool = True,
    window: int = 0,
    cache_kv: Optional[Tuple[Array, Array]] = None,  # decode: full caches
    cache_pos: Optional[Array] = None,  # decode: write index
) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Self-attention with optional KV cache read/write.

    Returns (out [B,S,D], updated (k_cache, v_cache) or the fresh (k, v)).
    """
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p.wq).reshape(B, S, Hq, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p.wk).reshape(B, S, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p.wv).reshape(B, S, Hkv, hd)
    q = rope(q, positions[None, :], cfg.rope_theta)
    k = rope(k, positions[None, :], cfg.rope_theta)

    if cache_kv is None:
        out = gqa_attention(q, k, v, causal=causal, window=window)
        kv = (k, v)
    else:
        kc, vc = cache_kv  # [B, Smax, Hkv, hd]
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, cache_pos, 0, 0))
        out = gqa_attention(
            q,
            kc,
            vc,
            causal=False,
            q_offset=cache_pos,
            window=window,
            kv_len=cache_pos + S,
        )
        kv = (kc, vc)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, Hq * hd), p.wo)
    return out, kv


# ---------------------------------------------------------------------------
# embedding / loss
# ---------------------------------------------------------------------------

def embed_lookup(embed: Array, tokens: Array) -> Array:
    from ..dist.sharding import hint

    return hint(jnp.take(embed, tokens, axis=0), "batch", None, None)


def lm_logits(x: Array, embed: Array) -> Array:
    """Tied-embedding readout: [..., D] x [V, D] -> [..., V]."""
    return jnp.einsum("...d,vd->...v", x, embed)


def causal_lm_loss(logits: Array, tokens: Array, true_vocab: int) -> Array:
    """Next-token cross entropy; padded vocab rows masked out.

    Logits stay vocab-sharded on the ``model`` axis (the log-sum-exp reduces
    over the sharded dim with a small all-reduce instead of materializing a
    replicated [B, S, V] fp32 tensor)."""
    from ..dist.sharding import hint

    V = logits.shape[-1]
    logits = hint(logits.astype(jnp.float32), "batch", None, "model")
    vocab_mask = jnp.arange(V) < true_vocab
    logits = jnp.where(vocab_mask[None, None, :], logits, -1e30)
    shift_logits = logits[:, :-1]
    shift_labels = tokens[:, 1:]
    logz = jax.nn.logsumexp(shift_logits, axis=-1)
    gold = jnp.take_along_axis(
        shift_logits, shift_labels[..., None], axis=-1
    ).squeeze(-1)
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# layer stacks: scanned (compact HLO) or python-unrolled (exact cost counts)
# ---------------------------------------------------------------------------

def _leading_dim(tree: PyTree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def stack_apply(layer_fn, params_stacked: PyTree, x: Array, *, unrolled: bool) -> Array:
    """x -> fold layer_fn over the stacked layer axis.

    unrolled=True (analysis variants) uses a Python loop so every layer's
    cost lands in XLA cost_analysis; unrolled=False scans (one while loop,
    compact HLO at any depth — the production path).
    """
    if unrolled:
        h = x
        for i in range(_leading_dim(params_stacked)):
            p_i = jax.tree.map(lambda a: a[i], params_stacked)
            h = layer_fn(p_i, h)
        return h
    h, _ = jax.lax.scan(lambda hh, p: (layer_fn(p, hh), None), x, params_stacked)
    return h


def stack_apply_collect(layer_fn, params_stacked: PyTree, x: Array, *, unrolled: bool):
    """Like stack_apply but layer_fn returns (h, aux); auxes stacked on axis 0."""
    if unrolled:
        h, auxes = x, []
        for i in range(_leading_dim(params_stacked)):
            p_i = jax.tree.map(lambda a: a[i], params_stacked)
            h, aux = layer_fn(p_i, h)
            auxes.append(aux)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *auxes)
        return h, stacked
    return jax.lax.scan(lambda hh, p: layer_fn(p, hh), x, params_stacked)


def stack_apply_with_state(layer_fn, params_stacked: PyTree, x: Array, state: PyTree,
                           *, unrolled: bool):
    """layer_fn(p, h, s) -> (h, s'); threads per-layer state (leaves stacked
    on axis 0)."""
    if unrolled:
        h, outs = x, []
        for i in range(_leading_dim(params_stacked)):
            p_i = jax.tree.map(lambda a: a[i], params_stacked)
            s_i = jax.tree.map(lambda a: a[i], state)
            h, s_new = layer_fn(p_i, h, s_i)
            outs.append(s_new)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *outs)
        return h, stacked

    def body(hh, inp):
        p, s = inp
        return layer_fn(p, hh, s)

    return jax.lax.scan(body, x, (params_stacked, state))
