"""Whisper-style encoder-decoder backbone (whisper-medium).

The conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings [B, encoder_len, D].  24 bidirectional
encoder layers + 24 causal decoder layers with cross-attention; decode uses
a self-attention KV cache (cross KV computed once at prefill).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from .common import (
    AttnParams,
    attention_block,
    attn_param_specs,
    stack_apply,
    stack_apply_collect,
    stack_apply_with_state,
    causal_lm_loss,
    embed_lookup,
    gqa_attention,
    lm_logits,
    rms_norm,
    sds,
)

Array = jax.Array


def _stack(spec_tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec_tree
    )


class Whisper:
    @staticmethod
    def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
        D, F = cfg.d_model, cfg.d_ff
        Le = cfg.n_encoder_layers or cfg.n_layers
        Ld = cfg.n_layers
        mlp = {"w_up": sds((D, F)), "w_down": sds((F, D))}
        enc_layer = {
            "attn": attn_param_specs(cfg)._asdict(),
            "attn_norm": sds((D,)),
            "mlp_norm": sds((D,)),
            "mlp": dict(mlp),
        }
        dec_layer = {
            "self_attn": attn_param_specs(cfg)._asdict(),
            "cross_attn": attn_param_specs(cfg)._asdict(),
            "self_norm": sds((D,)),
            "cross_norm": sds((D,)),
            "mlp_norm": sds((D,)),
            "mlp": dict(mlp),
        }
        return {
            "embed": sds((cfg.padded_vocab, D)),
            "enc_final_norm": sds((D,)),
            "dec_final_norm": sds((D,)),
            "encoder": _stack(enc_layer, Le),
            "decoder": _stack(dec_layer, Ld),
        }

    @staticmethod
    def init_params(cfg: ArchConfig, key):
        specs = Whisper.param_specs(cfg)
        flat, tree = jax.tree.flatten(specs)
        keys = jax.random.split(key, len(flat))
        leaves = [
            (jax.random.normal(k, s.shape) * 0.02).astype(s.dtype)
            for k, s in zip(keys, flat)
        ]
        return jax.tree.unflatten(tree, leaves)

    # -- encoder ------------------------------------------------------------

    @staticmethod
    def encode(cfg: ArchConfig, params, frames: Array, *, remat: bool) -> Array:
        S = frames.shape[1]
        positions = jnp.arange(S)

        def layer_fn(p, hh):
            a_in = rms_norm(hh, p["attn_norm"])
            out, _ = attention_block(
                AttnParams(**p["attn"]), a_in, cfg, positions=positions,
                causal=False,
            )
            hh = hh + out
            m_in = rms_norm(hh, p["mlp_norm"])
            u = jax.nn.gelu(jnp.einsum("bsd,df->bsf", m_in, p["mlp"]["w_up"]))
            return hh + jnp.einsum("bsf,fd->bsd", u, p["mlp"]["w_down"])

        fn = jax.checkpoint(layer_fn) if remat else layer_fn
        h = stack_apply(fn, params["encoder"], frames, unrolled=cfg.analysis_unroll)
        return rms_norm(h, params["enc_final_norm"])

    # -- decoder ------------------------------------------------------------

    @staticmethod
    def _cross(cfg, p, hh, enc_kv):
        B, S, D = hh.shape
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        a_in = rms_norm(hh, p["cross_norm"])
        q = jnp.einsum("bsd,dh->bsh", a_in, p["cross_attn"]["wq"]).reshape(B, S, Hq, hd)
        k, v = enc_kv
        out = gqa_attention(q, k, v, causal=False)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, Hq * hd), p["cross_attn"]["wo"])
        return hh + out

    @staticmethod
    def _enc_kv(cfg, p, enc: Array):
        B, Se, D = enc.shape
        Hkv, hd = cfg.n_kv_heads, cfg.head_dim
        k = jnp.einsum("bsd,dh->bsh", enc, p["cross_attn"]["wk"]).reshape(B, Se, Hkv, hd)
        v = jnp.einsum("bsd,dh->bsh", enc, p["cross_attn"]["wv"]).reshape(B, Se, Hkv, hd)
        return k, v

    @staticmethod
    def _dec_layer(cfg, p, hh, enc, positions, cache=None, pos=None):
        a_in = rms_norm(hh, p["self_norm"])
        if cache is None:
            out, kv = attention_block(
                AttnParams(**p["self_attn"]), a_in, cfg, positions=positions,
                causal=True,
            )
        else:
            out, kv = attention_block(
                AttnParams(**p["self_attn"]), a_in, cfg,
                positions=jnp.atleast_1d(pos), causal=True,
                cache_kv=cache, cache_pos=pos,
            )
        hh = hh + out
        hh = Whisper._cross(cfg, p, hh, Whisper._enc_kv(cfg, p, enc))
        m_in = rms_norm(hh, p["mlp_norm"])
        u = jax.nn.gelu(jnp.einsum("bsd,df->bsf", m_in, p["mlp"]["w_up"]))
        return hh + jnp.einsum("bsf,fd->bsd", u, p["mlp"]["w_down"]), kv

    @staticmethod
    def loss(cfg: ArchConfig, params, batch):
        enc = Whisper.encode(cfg, params, batch["frames"], remat=True)
        tokens = batch["tokens"]
        h = embed_lookup(params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])

        def layer_fn(p, hh):
            hh, _ = Whisper._dec_layer(cfg, p, hh, enc, positions)
            return hh

        fn = jax.checkpoint(layer_fn)
        h = stack_apply(fn, params["decoder"], h, unrolled=cfg.analysis_unroll)
        h = rms_norm(h, params["dec_final_norm"])
        return causal_lm_loss(lm_logits(h, params["embed"]), tokens, cfg.vocab)

    @staticmethod
    def prefill(cfg: ArchConfig, params, batch):
        enc = Whisper.encode(cfg, params, batch["frames"], remat=False)
        tokens = batch["tokens"]
        h = embed_lookup(params["embed"], tokens)
        positions = jnp.arange(tokens.shape[1])

        def layer_fn(p, hh):
            hh, kv = Whisper._dec_layer(cfg, p, hh, enc, positions)
            return hh, kv

        h, kv = stack_apply_collect(
            layer_fn, params["decoder"], h, unrolled=cfg.analysis_unroll
        )
        h = rms_norm(h, params["dec_final_norm"])
        # cross-KV cached once for decode
        def ckv(p):
            return Whisper._enc_kv(cfg, p, enc)

        cross = jax.vmap(lambda p: ckv(p))(params["decoder"])
        cache = {"k": kv[0], "v": kv[1], "ck": cross[0], "cv": cross[1]}
        return lm_logits(h[:, -1], params["embed"]), cache

    @staticmethod
    def decode(cfg: ArchConfig, params, cache, batch):
        h = embed_lookup(params["embed"], batch["token"])
        pos = batch["pos"]
        B = h.shape[0]
        Hq, hd = cfg.n_heads, cfg.head_dim

        def body(hh, inp):
            p, (kc, vc, ck, cv) = inp
            a_in = rms_norm(hh, p["self_norm"])
            out, (kc, vc) = attention_block(
                AttnParams(**p["self_attn"]), a_in, cfg,
                positions=jnp.atleast_1d(pos), causal=True,
                cache_kv=(kc, vc), cache_pos=pos,
            )
            hh = hh + out
            # cross-attention against cached encoder KV
            a_in = rms_norm(hh, p["cross_norm"])
            q = jnp.einsum("bsd,dh->bsh", a_in, p["cross_attn"]["wq"]).reshape(
                B, 1, Hq, hd
            )
            out = gqa_attention(q, ck, cv, causal=False)
            hh = hh + jnp.einsum(
                "bsh,hd->bsd", out.reshape(B, 1, Hq * hd), p["cross_attn"]["wo"]
            )
            m_in = rms_norm(hh, p["mlp_norm"])
            u = jax.nn.gelu(jnp.einsum("bsd,df->bsf", m_in, p["mlp"]["w_up"]))
            hh = hh + jnp.einsum("bsf,fd->bsd", u, p["mlp"]["w_down"])
            return hh, (kc, vc)

        h, (k_new, v_new) = stack_apply_with_state(
            lambda p, hh, c: body(hh, (p, c)), params["decoder"], h,
            (cache["k"], cache["v"], cache["ck"], cache["cv"]),
            unrolled=cfg.analysis_unroll,
        )
        h = rms_norm(h, params["dec_final_norm"])
        cache = {"k": k_new, "v": v_new, "ck": cache["ck"], "cv": cache["cv"]}
        return lm_logits(h[:, -1], params["embed"]), cache

    @staticmethod
    def input_specs(cfg: ArchConfig, shape: ShapeSpec):
        B = shape.global_batch
        frames = sds((B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        if shape.kind in ("train", "prefill"):
            return {"frames": frames, "tokens": sds((B, shape.seq_len), jnp.int32)}
        return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}

    @staticmethod
    def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
        B, S = shape.global_batch, shape.seq_len
        L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        return {
            "k": sds((L, B, S, Hkv, hd), jnp.bfloat16),
            "v": sds((L, B, S, Hkv, hd), jnp.bfloat16),
            "ck": sds((L, B, cfg.encoder_len, Hkv, hd), jnp.bfloat16),
            "cv": sds((L, B, cfg.encoder_len, Hkv, hd), jnp.bfloat16),
        }
