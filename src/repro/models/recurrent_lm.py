"""Full-model assemblies for the recurrent families:

* xLSTM LM (xlstm-125m): alternating mLSTM / sLSTM blocks, O(1)-state decode
* Zamba2 (zamba2-2.7b): Mamba2 backbone + ONE shared attention+MLP block
  applied every ``attn_every`` layers (window-limited KV ring buffer so
  long_500k decode memory is bounded)
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from .common import (
    attn_param_specs,
    stack_apply,
    stack_apply_collect,
    stack_apply_with_state,
    causal_lm_loss,
    embed_lookup,
    gqa_attention,
    lm_logits,
    rms_norm,
    rope,
    sds,
)
from .ssm import (
    ssm_cache_specs,
    ssm_decode_step,
    ssm_forward,
    ssm_param_specs,
)
from .xlstm import (
    mlstm_decode_step,
    mlstm_forward,
    mlstm_param_specs,
    slstm_decode_step,
    slstm_forward,
    slstm_param_specs,
    xlstm_dims,
)

Array = jax.Array


def _stack(spec_tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec_tree
    )


def _init_from_specs(specs, key):
    flat, tree = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    leaves = [
        (jax.random.normal(k, s.shape) * 0.02).astype(s.dtype)
        for k, s in zip(keys, flat)
    ]
    return jax.tree.unflatten(tree, leaves)


# ===========================================================================
# xLSTM LM
# ===========================================================================

class XLSTM:
    @staticmethod
    def n_pairs(cfg: ArchConfig) -> int:
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2

    @staticmethod
    def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
        P = XLSTM.n_pairs(cfg)
        D = cfg.d_model
        pair = {
            "m": mlstm_param_specs(cfg),
            "s": slstm_param_specs(cfg),
            "m_norm": sds((D,)),
            "s_norm": sds((D,)),
        }
        return {
            "embed": sds((cfg.padded_vocab, D)),
            "final_norm": sds((D,)),
            "pairs": _stack(pair, P),
        }

    @staticmethod
    def init_params(cfg: ArchConfig, key):
        return _init_from_specs(XLSTM.param_specs(cfg), key)

    @staticmethod
    def _trunk(cfg, params, h, remat: bool):
        def pair_fn(p, hh):
            hh = hh + mlstm_forward(p["m"], rms_norm(hh, p["m_norm"]), cfg)
            hh = hh + slstm_forward(p["s"], rms_norm(hh, p["s_norm"]), cfg)
            return hh

        fn = jax.checkpoint(pair_fn) if remat else pair_fn
        h = stack_apply(fn, params["pairs"], h, unrolled=cfg.analysis_unroll)
        return rms_norm(h, params["final_norm"])

    @staticmethod
    def loss(cfg: ArchConfig, params, batch):
        h = embed_lookup(params["embed"], batch["tokens"])
        h = XLSTM._trunk(cfg, params, h, remat=True)
        return causal_lm_loss(lm_logits(h, params["embed"]), batch["tokens"], cfg.vocab)

    @staticmethod
    def prefill(cfg: ArchConfig, params, batch):
        """Recurrent-state prefill: run the chunked forms, then rebuild the
        final state by a single-step pass is expensive; instead we run
        step-wise scans for the states.  For benchmark/dry-run purposes we
        return the state after processing the whole prompt."""
        # run trunk for logits; states rebuilt via decode-form scan per pair
        tokens = batch["tokens"]
        h = embed_lookup(params["embed"], tokens)
        B, S, D = h.shape
        _, H, hd = xlstm_dims(cfg)

        def pair_fn(p, hh):
            # mLSTM: scan decode steps to both output and final state
            def m_step(c, xt):
                y, c2 = mlstm_decode_step(p["m"], xt[:, None], c, cfg)
                return c2, y[:, 0]

            mc0 = (
                jnp.zeros((B, H, hd, hd), jnp.float32),
                jnp.zeros((B, H, hd), jnp.float32),
                jnp.full((B, H), -1e30, jnp.float32),
            )
            x_in = rms_norm(hh, p["m_norm"])
            mc, ys = jax.lax.scan(m_step, mc0, jnp.moveaxis(x_in, 1, 0))
            hh = hh + jnp.moveaxis(ys, 0, 1)

            def s_step(c, xt):
                y, c2 = slstm_decode_step(p["s"], xt[:, None], c, cfg)
                return c2, y[:, 0]

            sc0 = (
                jnp.zeros((B, D), jnp.float32),
                jnp.zeros((B, D), jnp.float32),
                jnp.full((B, D), -1e30, jnp.float32),
                jnp.zeros((B, D), hh.dtype),
            )
            x_in = rms_norm(hh, p["s_norm"])
            sc, ys = jax.lax.scan(s_step, sc0, jnp.moveaxis(x_in, 1, 0))
            hh = hh + jnp.moveaxis(ys, 0, 1)
            return hh, (mc, sc)

        h, caches = stack_apply_collect(
            lambda p, hh: pair_fn(p, hh), params["pairs"], h,
            unrolled=cfg.analysis_unroll,
        )
        h = rms_norm(h, params["final_norm"])
        return lm_logits(h[:, -1], params["embed"]), caches

    @staticmethod
    def decode(cfg: ArchConfig, params, cache, batch):
        h = embed_lookup(params["embed"], batch["token"])  # [B,1,D]

        def pair_fn(p, hh, c):
            mc, sc = c
            y, mc = mlstm_decode_step(p["m"], rms_norm(hh, p["m_norm"]), mc, cfg)
            hh = hh + y
            y, sc = slstm_decode_step(p["s"], rms_norm(hh, p["s_norm"]), sc, cfg)
            hh = hh + y
            return hh, (mc, sc)

        h, cache = stack_apply_with_state(
            pair_fn, params["pairs"], h, cache, unrolled=cfg.analysis_unroll
        )
        h = rms_norm(h, params["final_norm"])
        return lm_logits(h[:, -1], params["embed"]), cache

    @staticmethod
    def input_specs(cfg: ArchConfig, shape: ShapeSpec):
        B = shape.global_batch
        if shape.kind in ("train", "prefill"):
            return {"tokens": sds((B, shape.seq_len), jnp.int32)}
        return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}

    @staticmethod
    def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
        B = shape.global_batch
        P = XLSTM.n_pairs(cfg)
        D, H, hd = xlstm_dims(cfg)
        mc = (
            sds((P, B, H, hd, hd), jnp.float32),
            sds((P, B, H, hd), jnp.float32),
            sds((P, B, H), jnp.float32),
        )
        sc = (
            sds((P, B, D), jnp.float32),
            sds((P, B, D), jnp.float32),
            sds((P, B, D), jnp.float32),
            sds((P, B, D), jnp.bfloat16),
        )
        return (mc, sc)


# ===========================================================================
# Zamba2 hybrid
# ===========================================================================

class Zamba2:
    @staticmethod
    def n_groups(cfg: ArchConfig) -> int:
        assert cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every

    @staticmethod
    def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
        G, E = Zamba2.n_groups(cfg), cfg.attn_every
        D, F = cfg.d_model, cfg.d_ff
        mamba_layer = {"ssm": ssm_param_specs(cfg), "norm": sds((D,))}
        shared = {
            "attn": attn_param_specs(cfg)._asdict(),
            "attn_norm": sds((D,)),
            "mlp_norm": sds((D,)),
            "mlp": {
                "w_gate": sds((D, F)),
                "w_up": sds((D, F)),
                "w_down": sds((F, D)),
            },
        }
        return {
            "embed": sds((cfg.padded_vocab, D)),
            "final_norm": sds((D,)),
            "mamba": _stack(_stack(mamba_layer, E), G),  # [G, E, ...]
            "shared": shared,  # ONE block, applied G times (the paper of
            # record for this arch shares transformer weights)
        }

    @staticmethod
    def init_params(cfg: ArchConfig, key):
        return _init_from_specs(Zamba2.param_specs(cfg), key)

    @staticmethod
    def _shared_attn(cfg, shared, hh, positions, window):
        a_in = rms_norm(hh, shared["attn_norm"])
        B, S, D = a_in.shape
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = jnp.einsum("bsd,dh->bsh", a_in, shared["attn"]["wq"]).reshape(B, S, Hq, hd)
        k = jnp.einsum("bsd,dh->bsh", a_in, shared["attn"]["wk"]).reshape(B, S, Hkv, hd)
        v = jnp.einsum("bsd,dh->bsh", a_in, shared["attn"]["wv"]).reshape(B, S, Hkv, hd)
        q = rope(q, positions[None], cfg.rope_theta)
        k = rope(k, positions[None], cfg.rope_theta)
        out = gqa_attention(q, k, v, causal=True, window=window)
        out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, Hq * hd), shared["attn"]["wo"])
        hh = hh + out
        m_in = rms_norm(hh, shared["mlp_norm"])
        m = shared["mlp"]
        g = jnp.einsum("bsd,df->bsf", m_in, m["w_gate"])
        u = jnp.einsum("bsd,df->bsf", m_in, m["w_up"])
        hh = hh + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, m["w_down"])
        return hh

    @staticmethod
    def _trunk(cfg, params, h, remat: bool):
        S = h.shape[1]
        positions = jnp.arange(S)

        def group_fn(g_params, hh):
            def mamba_fn(p, hx):
                return hx + ssm_forward(p["ssm"], rms_norm(hx, p["norm"]), cfg)

            mfn = jax.checkpoint(mamba_fn) if remat else mamba_fn
            hh, _ = jax.lax.scan(lambda hx, p: (mfn(p, hx), None), hh, g_params,
                                 unroll=cfg.attn_every if cfg.analysis_unroll else 1)
            return Zamba2._shared_attn(cfg, params["shared"], hh, positions, cfg.window)

        gfn = jax.checkpoint(group_fn) if remat else group_fn
        h = stack_apply(gfn, params["mamba"], h, unrolled=cfg.analysis_unroll)
        return rms_norm(h, params["final_norm"])

    @staticmethod
    def loss(cfg: ArchConfig, params, batch):
        h = embed_lookup(params["embed"], batch["tokens"])
        h = Zamba2._trunk(cfg, params, h, remat=True)
        return causal_lm_loss(lm_logits(h, params["embed"]), batch["tokens"], cfg.vocab)

    @staticmethod
    def prefill(cfg: ArchConfig, params, batch):
        """Prefill producing decode caches: mamba states via step scans and
        windowed KV ring buffers for the shared attention."""
        tokens = batch["tokens"]
        h = embed_lookup(params["embed"], tokens)
        B, S, D = h.shape
        W = min(cfg.window or S, S)
        positions = jnp.arange(S)
        Hkv, hd = cfg.n_kv_heads, cfg.head_dim

        def group_fn(g_params, hh):
            def m_step(p, hx):  # sequential state build per mamba layer
                x_in = rms_norm(hx, p["norm"])
                y = ssm_forward(p["ssm"], x_in, cfg)
                # final ssm state via decode-form scan would double compute;
                # we rebuild it from the last CONV_K inputs + a step scan of
                # the tail only in the serving path (cheap approximation for
                # benchmark lowering: full-state scan).
                def step(c, xt):
                    _, c2 = ssm_decode_step(p["ssm"], xt[:, None], c, cfg)
                    return c2, None

                from .ssm import CONV_K, ssm_dims

                d_inner, H, P_, N = ssm_dims(cfg)
                c0 = (
                    jnp.zeros((B, CONV_K - 1, d_inner + 2 * N), hx.dtype),
                    jnp.zeros((B, H, N, P_), jnp.float32),
                )
                c_fin, _ = jax.lax.scan(step, c0, jnp.moveaxis(x_in, 1, 0))
                return hx + y, c_fin

            hh, m_caches = jax.lax.scan(
                lambda hx, p: m_step(p, hx), hh, g_params, unroll=cfg.attn_every if cfg.analysis_unroll else 1
            )
            # shared attention with cache capture (last W positions)
            a_in = rms_norm(hh, params["shared"]["attn_norm"])
            q = jnp.einsum("bsd,dh->bsh", a_in, params["shared"]["attn"]["wq"]).reshape(
                B, S, cfg.n_heads, hd
            )
            k = jnp.einsum("bsd,dh->bsh", a_in, params["shared"]["attn"]["wk"]).reshape(
                B, S, Hkv, hd
            )
            v = jnp.einsum("bsd,dh->bsh", a_in, params["shared"]["attn"]["wv"]).reshape(
                B, S, Hkv, hd
            )
            q = rope(q, positions[None], cfg.rope_theta)
            k = rope(k, positions[None], cfg.rope_theta)
            out = gqa_attention(q, k, v, causal=True, window=cfg.window)
            out = jnp.einsum(
                "bsh,hd->bsd",
                out.reshape(B, S, cfg.n_heads * hd),
                params["shared"]["attn"]["wo"],
            )
            hh = hh + out
            m_in = rms_norm(hh, params["shared"]["mlp_norm"])
            m = params["shared"]["mlp"]
            g = jnp.einsum("bsd,df->bsf", m_in, m["w_gate"])
            u = jnp.einsum("bsd,df->bsf", m_in, m["w_up"])
            hh = hh + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, m["w_down"])
            kv_cache = (k[:, -W:], v[:, -W:])  # ring buffer, absolute-rope keys
            return hh, (m_caches, kv_cache)

        h, caches = stack_apply_collect(
            lambda p, hh: group_fn(p, hh), params["mamba"], h,
            unrolled=cfg.analysis_unroll,
        )
        h = rms_norm(h, params["final_norm"])
        return lm_logits(h[:, -1], params["embed"]), caches

    @staticmethod
    def decode(cfg: ArchConfig, params, cache, batch):
        h = embed_lookup(params["embed"], batch["token"])  # [B,1,D]
        pos = batch["pos"]
        B = h.shape[0]
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def full_group(carry_h, inp):
            g_params, g_cache = inp
            m_caches, (kc, vc) = g_cache
            hh = carry_h
            W = kc.shape[1]

            def m_step(hx, pin):
                p, c = pin
                y, c2 = ssm_decode_step(p["ssm"], rms_norm(hx, p["norm"]), c, cfg)
                return hx + y, c2

            hh, m_new = jax.lax.scan(m_step, hh, (g_params, m_caches),
                                     unroll=cfg.attn_every if cfg.analysis_unroll else 1)
            # shared attention against the ring buffer
            sh = params["shared"]
            a_in = rms_norm(hh, sh["attn_norm"])
            q = jnp.einsum("bsd,dh->bsh", a_in, sh["attn"]["wq"]).reshape(B, 1, Hq, hd)
            k = jnp.einsum("bsd,dh->bsh", a_in, sh["attn"]["wk"]).reshape(B, 1, Hkv, hd)
            v = jnp.einsum("bsd,dh->bsh", a_in, sh["attn"]["wv"]).reshape(B, 1, Hkv, hd)
            q = rope(q, pos[None, None], cfg.rope_theta)
            k = rope(k, pos[None, None], cfg.rope_theta)
            slot = jnp.mod(pos, W)
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
            # all slots valid once pos+1 >= W
            n_valid = jnp.minimum(pos + 1, W)
            scores = jnp.einsum(
                "bqhrd,bkhd->bhrqk",
                q.reshape(B, 1, Hkv, Hq // Hkv, hd),
                kc,
            ).astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
            slot_ids = jnp.arange(W)
            valid = slot_ids[None, :] < n_valid
            scores = jnp.where(valid[None, None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(hh.dtype)
            out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, vc).reshape(B, 1, Hq * hd)
            hh = hh + jnp.einsum("bsh,hd->bsd", out, sh["attn"]["wo"])
            m_in = rms_norm(hh, sh["mlp_norm"])
            m = sh["mlp"]
            g = jnp.einsum("bsd,df->bsf", m_in, m["w_gate"])
            u = jnp.einsum("bsd,df->bsf", m_in, m["w_up"])
            hh = hh + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, m["w_down"])
            return hh, (m_new, (kc, vc))

        h, cache = stack_apply_with_state(
            lambda p, hh, c: full_group(hh, (p, c)), params["mamba"], h, cache,
            unrolled=cfg.analysis_unroll,
        )
        h = rms_norm(h, params["final_norm"])
        return lm_logits(h[:, -1], params["embed"]), cache

    @staticmethod
    def input_specs(cfg: ArchConfig, shape: ShapeSpec):
        B = shape.global_batch
        if shape.kind in ("train", "prefill"):
            return {"tokens": sds((B, shape.seq_len), jnp.int32)}
        return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}

    @staticmethod
    def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
        B = shape.global_batch
        G, E = Zamba2.n_groups(cfg), cfg.attn_every
        W = min(cfg.window or shape.seq_len, shape.seq_len)
        conv, state = ssm_cache_specs(cfg, B, E)
        m_caches = (
            jax.ShapeDtypeStruct((G, *conv.shape), conv.dtype),
            jax.ShapeDtypeStruct((G, *state.shape), state.dtype),
        )
        kv = sds((G, B, W, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
        return (m_caches, (kv, kv))
