"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, recurrent scan).  Layers alternate mLSTM/sLSTM.

mLSTM per head (state C: hd x hd matrix, normalizer n: hd, stabilizer m):
    f_t, i_t exp/sigmoid input-conditioned gates
    C_t = f C_{t-1} + i v_t k_t^T ;  n_t = f n_{t-1} + i k_t
    h_t = o_t * (C_t q_t) / max(|n_t . q_t|, 1)
Chunkwise: quadratic within chunk, recurrent (C, n, m) across chunks —
training is sub-quadratic in S, decode is O(1)/token (long_500k path).

sLSTM per unit (c, n, m scalar states; per-head block-diag recurrence):
    c_t = f c_{t-1} + i tanh(z);  n_t = f n_{t-1} + i;  h = o * c/n
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import rms_norm, sds

Array = jax.Array


def xlstm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    H = cfg.n_heads
    hd = cfg.d_model // H
    return cfg.d_model, H, hd


def mlstm_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D, H, hd = xlstm_dims(cfg)
    return {
        "wq": sds((D, D), dtype),
        "wk": sds((D, D), dtype),
        "wv": sds((D, D), dtype),
        "wi": sds((D, H), jnp.float32),  # input gate (per head)
        "wf": sds((D, H), jnp.float32),  # forget gate (per head)
        "wo": sds((D, D), dtype),  # output gate (per unit)
        "norm": sds((D,), dtype),
        "proj": sds((D, D), dtype),
    }


def slstm_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D, H, hd = xlstm_dims(cfg)
    return {
        "wz": sds((D, D), dtype),
        "wi": sds((D, D), jnp.float32),
        "wf": sds((D, D), jnp.float32),
        "wo": sds((D, D), dtype),
        "rz": sds((H, hd, hd), dtype),  # block-diagonal recurrence
        "ri": sds((H, hd, hd), jnp.float32),
        "rf": sds((H, hd, hd), jnp.float32),
        "ro": sds((H, hd, hd), dtype),
        "norm": sds((D,), dtype),
        "proj": sds((D, D), dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_forward(p, x: Array, cfg: ArchConfig, *, chunk: int = 256) -> Array:
    """x: [B, S, D] -> [B, S, D] chunkwise-parallel."""
    B, S, D = x.shape
    _, H, hd = xlstm_dims(cfg)
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, H, hd) / jnp.sqrt(
        jnp.float32(hd)
    ).astype(x.dtype)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, H, hd)
    ig = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"])  # log-space
    fg = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"])
    )
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo"]))

    Q = min(chunk, S)
    assert S % Q == 0
    nC = S // Q
    def rs(a):
        return a.reshape(B, nC, Q, *a.shape[2:])

    qc, kc, vc, ic, fc = map(rs, (q, k, v, ig, fg))

    cumf = jnp.cumsum(fc, axis=2)  # [B,nC,Q,H]
    # intra-chunk log weights: lw[t,s] = cumf_t - cumf_s + i_s  (s <= t)
    lw = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + ic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    lw = jnp.where(causal[None, None, :, :, None], lw, -jnp.inf)
    # inter-chunk state contribution log weight: cumf_t + m_prev
    # scan chunks carrying (C [B,H,hd,hd], n [B,H,hd], m [B,H])
    def chunk_step(carry, inp):
        C, n, m = carry
        qq, kk, vv, ii, ff, lww, cf = inp  # per-chunk tensors (leading B)
        total_f = cf[:, -1]  # [B,H]
        # stabilizer per t: max of intra weights and the state weight
        state_lw = cf + m[:, None, :]  # [B,Q,H]
        m_new_t = jnp.maximum(jnp.max(lww, axis=2), state_lw)  # [B,Q,H]
        w_intra = jnp.exp(lww - m_new_t[:, :, None, :])  # [B,Q,K,H]
        scores = jnp.einsum("bqhd,bkhd->bqkh", qq.astype(jnp.float32), kk.astype(jnp.float32))
        y_intra = jnp.einsum("bqkh,bqkh,bkhd->bqhd", scores, w_intra, vv.astype(jnp.float32))
        norm_intra = jnp.einsum("bqkh,bqkh->bqh", scores, w_intra)
        w_state = jnp.exp(state_lw - m_new_t)  # [B,Q,H]
        y_state = jnp.einsum("bqhd,bhde,bqh->bqhe", qq.astype(jnp.float32), C, w_state)
        norm_state = jnp.einsum("bqhd,bhd,bqh->bqh", qq.astype(jnp.float32), n, w_state)
        denom = jnp.maximum(jnp.abs(norm_intra + norm_state), jnp.exp(-m_new_t))
        y = (y_intra + y_state) / denom[..., None]  # [B,Q,H,hd]
        # update chunk state
        m_next = jnp.maximum(
            total_f + m, jnp.max(ii + total_f[:, None] - cf, axis=1)
        )  # [B,H]
        w_keep = jnp.exp(total_f + m - m_next)  # [B,H]
        w_add = jnp.exp(ii + total_f[:, None] - cf - m_next[:, None, :])  # [B,Q,H]
        C_new = C * w_keep[..., None, None] + jnp.einsum(
            "bqh,bqhd,bqhe->bhde", w_add, kk.astype(jnp.float32), vv.astype(jnp.float32)
        )
        n_new = n * w_keep[..., None] + jnp.einsum(
            "bqh,bqhd->bhd", w_add, kk.astype(jnp.float32)
        )
        return (C_new, n_new, m_next), y

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    def swap(a):  # scan over chunks
        return jnp.moveaxis(a, 1, 0)

    (_, _, _), ys = jax.lax.scan(
        chunk_step,
        (C0, n0, m0),
        (swap(qc), swap(kc), swap(vc), swap(ic), swap(fc), swap(lw), swap(cumf)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H * hd)
    y = og * y.astype(x.dtype)
    y = rms_norm(y, p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["proj"])


def mlstm_decode_step(p, x: Array, cache, cfg: ArchConfig):
    """x: [B,1,D]; cache = (C [B,H,hd,hd], n [B,H,hd], m [B,H])."""
    B = x.shape[0]
    _, H, hd = xlstm_dims(cfg)
    C, n, m = cache
    xt = x[:, 0]
    q = jnp.einsum("bd,de->be", xt, p["wq"]).reshape(B, H, hd)
    k = (jnp.einsum("bd,de->be", xt, p["wk"]) / jnp.sqrt(jnp.float32(hd)).astype(x.dtype)).reshape(B, H, hd)
    v = jnp.einsum("bd,de->be", xt, p["wv"]).reshape(B, H, hd)
    ig = jnp.einsum("bd,dh->bh", xt.astype(jnp.float32), p["wi"])
    fg = jax.nn.log_sigmoid(jnp.einsum("bd,dh->bh", xt.astype(jnp.float32), p["wf"]))
    og = jax.nn.sigmoid(jnp.einsum("bd,de->be", xt, p["wo"]))

    m_new = jnp.maximum(fg + m, ig)
    wf = jnp.exp(fg + m - m_new)
    wi = jnp.exp(ig - m_new)
    C = C * wf[..., None, None] + wi[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = n * wf[..., None] + wi[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)), jnp.exp(-m_new)
    )
    y = (num / den[..., None]).reshape(B, H * hd)
    y = og * y.astype(x.dtype)
    y = rms_norm(y, p["norm"])
    return jnp.einsum("be,ed->bd", y, p["proj"])[:, None], (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_step(p, state, xt: Array, cfg: ArchConfig):
    """One timestep. state = (c, n, m, h) each [B, D] (m,c,n fp32)."""
    B = xt.shape[0]
    D, H, hd = xlstm_dims(cfg)
    c, n, m, h = state
    hb = h.reshape(B, H, hd)

    def rec(w):  # block-diag recurrence
        return jnp.einsum("bhp,hpq->bhq", hb.astype(w.dtype), w).reshape(B, D)

    z = jnp.tanh(jnp.einsum("bd,de->be", xt, p["wz"]) + rec(p["rz"]))
    i_log = jnp.einsum("bd,de->be", xt.astype(jnp.float32), p["wi"]) + rec(p["ri"])
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bd,de->be", xt.astype(jnp.float32), p["wf"]) + rec(p["rf"])
    )
    o = jax.nn.sigmoid(jnp.einsum("bd,de->be", xt, p["wo"]) + rec(p["ro"]))
    m_new = jnp.maximum(f_log + m, i_log)
    ip = jnp.exp(i_log - m_new)
    fp = jnp.exp(f_log + m - m_new)
    c_new = fp * c + ip * z.astype(jnp.float32)
    n_new = fp * n + ip
    h_new = (o * (c_new / jnp.maximum(n_new, 1.0)).astype(o.dtype))
    return (c_new, n_new, m_new, h_new), h_new


def slstm_forward(p, x: Array, cfg: ArchConfig) -> Array:
    B, S, D = x.shape
    state0 = (
        jnp.zeros((B, D), jnp.float32),
        jnp.zeros((B, D), jnp.float32),
        jnp.full((B, D), -1e30, jnp.float32),
        jnp.zeros((B, D), x.dtype),
    )
    _, hs = jax.lax.scan(
        lambda s, xt: slstm_step(p, s, xt, cfg), state0, jnp.moveaxis(x, 1, 0)
    )
    y = jnp.moveaxis(hs, 0, 1)  # [B, S, D]
    y = rms_norm(y, p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["proj"])


def slstm_decode_step(p, x: Array, cache, cfg: ArchConfig):
    """x: [B,1,D]; cache = (c, n, m, h)."""
    state, h_new = slstm_step(p, cache, x[:, 0], cfg)
    y = rms_norm(h_new, p["norm"])
    return jnp.einsum("be,ed->bd", y, p["proj"])[:, None], state
