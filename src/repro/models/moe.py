"""Mixture-of-Experts FFN (top-k routing, sort+scatter dispatch, EP-shardable).

Dispatch strategy (compile-friendly on any backend, EP-sharded on the
``model`` mesh axis):
  1. router logits -> top-k experts per token (fp32 router)
  2. assignments sorted by expert id; rank-within-expert via searchsorted
  3. tokens scattered into a capacity-bounded [E, C, D] buffer
     (assignments past capacity C are dropped, standard GShard semantics)
  4. per-expert SwiGLU via batched einsum on the [E, ...] buffers
  5. results gathered back and combined with router weights

The [E, C, D] buffers and [E, D, F] weights shard on E over the ``model``
axis; XLA inserts the all-to-all at the scatter/gather boundaries.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import sds

Array = jax.Array


def moe_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": sds((D, E), jnp.float32),
        "w_gate": sds((E, D, F), dtype),
        "w_up": sds((E, D, F), dtype),
        "w_down": sds((E, F, D), dtype),
    }


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _dispatch_compute(xf, logits, w_gate, w_up, w_down, *, k, n_experts, C, dtype):
    """Capacity-bounded top-k dispatch + per-expert SwiGLU + combine.

    xf: [T, D]; logits fp32 [T, E_total]; weights [E_local, D, F].
    Experts outside [expert_lo, expert_lo + E_local) are dropped (their
    contribution comes from other shards; see moe_ffn_ep)."""
    T, D = xf.shape
    E_local = w_gate.shape[0]
    topw, topi = jax.lax.top_k(logits, k)  # [T, k] (global expert ids)
    topw = jax.nn.softmax(topw, axis=-1).astype(dtype)

    flat_e = topi.reshape(-1)  # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topw.reshape(-1)
    # local assignments keep id in [0, E_local); others -> sink E_local
    local = (flat_e >= 0) & (flat_e < E_local)
    flat_e = jnp.where(local, flat_e, E_local)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * k) - first  # rank within expert
    keep = (pos < C) & (se < E_local)
    pos_c = jnp.where(keep, pos, 0)
    se_c = jnp.where(keep, se, 0)

    buf = jnp.zeros((E_local, C, D), dtype)
    buf = buf.at[se_c, pos_c].add(jnp.where(keep[:, None], xf[st], 0))

    h_g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h_u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(h_g) * h_u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)  # [E_local, C, D]

    vals = out_buf[se_c, pos_c] * jnp.where(keep, sw, 0)[:, None]
    return jnp.zeros((T, D), dtype).at[st].add(vals)


def moe_ffn(p: Dict[str, Array], x: Array, cfg: ArchConfig) -> Array:
    """x: [B, S, D] -> [B, S, D].  Uses the shard_map expert-parallel path
    when an activation mesh is installed (EP: experts local, one psum of
    [T_local, D] per layer — see EXPERIMENTS.md §Perf); otherwise the plain
    single-device path."""
    from ..dist.sharding import _ACTIVATION_MESH

    mesh = _ACTIVATION_MESH
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.n_experts % dict(zip(mesh.axis_names, mesh.devices.shape))["model"] == 0
    ):
        return moe_ffn_ep(p, x, cfg, mesh)
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    y = _dispatch_compute(
        xf, logits, p["w_gate"], p["w_up"], p["w_down"],
        k=cfg.top_k, n_experts=cfg.n_experts,
        C=moe_capacity(cfg, T), dtype=x.dtype,
    )
    return y.reshape(B, S, D)


def moe_ffn_ep(p: Dict[str, Array], x: Array, cfg: ArchConfig, mesh) -> Array:
    """Expert-parallel MoE via shard_map (beyond-paper optimization).

    Tokens shard over (pod, data); experts shard over model.  Each device
    routes its token block to its LOCAL experts only and the partial outputs
    are summed with one psum over ``model`` — replacing the GSPMD
    replicate+all-reduce of the [E, C, D] dispatch buffer (which dominated
    the baseline collective term) with a [T_local, D] reduction."""
    try:  # jax >= 0.6 moved shard_map out of experimental
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..dist.sharding import batch_axes

    B, S, D = x.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes["model"]
    bx = batch_axes(mesh, B)
    n_batch = 1
    if bx:
        import numpy as _np

        n_batch = int(_np.prod([sizes[a] for a in bx]))
    T_local = (B // n_batch) * S
    C = moe_capacity(cfg, T_local)
    E_local = cfg.n_experts // n_model

    def local_fn(xl, router, wg, wu, wd):
        # xl: [B_l, S, D]; wg: [E_local, D, F]
        B_l = xl.shape[0]
        xf = xl.reshape(B_l * S, D)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        lo = jax.lax.axis_index("model") * E_local
        # route against the GLOBAL router, then localize expert ids
        topw, topi = jax.lax.top_k(logits, cfg.top_k)
        topw = jax.nn.softmax(topw, axis=-1).astype(xl.dtype)
        e_loc = topi - lo
        T = B_l * S
        flat_e = e_loc.reshape(-1)
        local = (flat_e >= 0) & (flat_e < E_local)
        flat_e = jnp.where(local, flat_e, E_local)
        flat_t = jnp.repeat(jnp.arange(T), cfg.top_k)
        flat_w = topw.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        first = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(T * cfg.top_k) - first
        keep = (pos < C) & (se < E_local)
        pos_c = jnp.where(keep, pos, 0)
        se_c = jnp.where(keep, se, 0)
        buf = jnp.zeros((E_local, C, D), xl.dtype)
        buf = buf.at[se_c, pos_c].add(jnp.where(keep[:, None], xf[st], 0))
        h_g = jnp.einsum("ecd,edf->ecf", buf, wg)
        h_u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(h_g) * h_u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
        vals = out_buf[se_c, pos_c] * jnp.where(keep, sw, 0)[:, None]
        yl = jnp.zeros((T, D), xl.dtype).at[st].add(vals)
        yl = jax.lax.psum(yl, "model")  # combine expert shards
        return yl.reshape(B_l, S, D)

    x_spec = P(bx, None, None)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            x_spec,
            P(),  # router replicated
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=x_spec,
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
