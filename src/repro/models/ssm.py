"""Mamba2 (SSD) block — chunked parallel scan for training/prefill, O(1)
recurrent step for decode (this is what makes ``long_500k`` runnable).

Simplified-but-faithful SSD (arXiv:2405.21060): scalar decay per head,
single B/C group.  Recurrence per head h with state N, head dim P:

    H_t = exp(dt_t * A_h) * H_{t-1} + dt_t * B_t (x)  (outer product  N x P)
    y_t = C_t · H_t + D_h * x_t

Chunked evaluation: intra-chunk attention-like term + inter-chunk state scan.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import rms_norm, sds

Array = jax.Array

CONV_K = 4


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def ssm_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    D = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    d_conv = d_inner + 2 * N  # conv over x, B, C channels
    return {
        "in_proj": sds((D, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": sds((CONV_K, d_conv), dtype),
        "conv_b": sds((d_conv,), dtype),
        "A_log": sds((H,), jnp.float32),
        "D": sds((H,), jnp.float32),
        "dt_bias": sds((H,), jnp.float32),
        "norm": sds((d_inner,), dtype),
        "out_proj": sds((d_inner, D), dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: Array):
    d_inner, H, P, N = ssm_dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv, k=4. xBC: [B, S, Cc]."""
    pads = jnp.pad(xBC, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + xBC.shape[1]] * w[i][None, None, :] for i in range(CONV_K)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssm_forward(
    p: Dict[str, Array], x: Array, cfg: ArchConfig, *, chunk: int = 256
) -> Array:
    """x: [B, S, D] -> [B, S, D] (training / prefill form)."""
    B, S, D = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs, Bs, Cs = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H] negative
    dA = dt * A[None, None]  # [B,S,H] log-decay per step

    Q = min(chunk, S)
    assert S % Q == 0, "seq must divide chunk"
    nC = S // Q

    def reshape_c(a):
        return a.reshape(B, nC, Q, *a.shape[2:])

    xs_c, Bs_c, Cs_c, dA_c, dt_c = map(reshape_c, (xs, Bs, Cs, dA, dt))
    cum = jnp.cumsum(dA_c, axis=2)  # [B,nC,Q,H] cumulative log-decay
    total = cum[:, :, -1]  # [B,nC,H]

    # intra-chunk (attention-like, causal)
    xw = xs_c * dt_c[..., None]  # dt-weighted inputs [B,nC,Q,H,P]
    scores_bc = jnp.einsum("bcqn,bckn->bcqk", Cs_c, Bs_c)  # [B,nC,Q,Q]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nC,Q,K,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores_bc, w, xw.astype(jnp.float32))

    # chunk states: S_c = sum_s exp(total - cum_s) * B_s (x) xw_s  -> [B,nC,H,N,P]
    state_w = jnp.exp(total[:, :, None] - cum)  # [B,nC,Q,H]
    chunk_state = jnp.einsum(
        "bcqn,bcqh,bcqhp->bchnp", Bs_c, state_w, xw.astype(jnp.float32)
    )

    # inter-chunk scan over nC
    def scan_body(h_prev, inp):
        st, tot = inp  # [B,H,N,P], [B,H]
        h_new = jnp.exp(tot)[..., None, None] * h_prev + st
        return h_new, h_prev

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_body,
        h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [B,nC,H,N,P] state before chunk

    y_inter = jnp.einsum(
        "bcqn,bcqh,bchnp->bcqhp", Cs_c, jnp.exp(cum), h_prevs
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def ssm_decode_step(
    p: Dict[str, Array],
    x: Array,  # [B, 1, D]
    cache: Tuple[Array, Array],  # (conv_state [B, K-1, Cc], ssm_state [B,H,N,P])
    cfg: ArchConfig,
) -> Tuple[Array, Tuple[Array, Array]]:
    B = x.shape[0]
    d_inner, H, P, N = ssm_dims(cfg)
    conv_state, h = cache
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    z, xBC, dt = _split_proj(cfg, zxbcdt[:, None, :])
    xBC = xBC[:, 0]
    # conv ring buffer: [B, K-1, Cc] previous inputs
    full = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # [B,K,Cc]
    conv_out = jnp.einsum("bkc,kc->bc", full, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv_out)
    xs, Bs, Cs = jnp.split(xBC_t, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, H, P)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * A[None])  # [B,H]
    contrib = jnp.einsum("bn,bh,bhp->bhnp", Bs.astype(jnp.float32), dt_t, xs.astype(jnp.float32))
    h = decay[..., None, None] * h + contrib
    y = jnp.einsum("bn,bhnp->bhp", Cs.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0])
    y = rms_norm(y, p["norm"])
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    new_conv = full[:, 1:]
    return out, (new_conv, h)


def ssm_cache_specs(cfg: ArchConfig, batch: int, n_layers: int):
    d_inner, H, P, N = ssm_dims(cfg)
    d_conv = d_inner + 2 * N
    return (
        sds((n_layers, batch, CONV_K - 1, d_conv), jnp.bfloat16),
        sds((n_layers, batch, H, N, P), jnp.float32),
    )
