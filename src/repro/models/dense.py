"""Dense (and MoE) GQA decoder LM — covers starcoder2-7b, stablelm-12b/3b,
deepseek-7b, moonshot-v1-16b (MoE), llama4-maverick (MoE), and the internvl2
backbone (early-fusion patch embeddings).

Structure per layer (pre-norm):  x += attn(RMSNorm(x)); x += ffn(RMSNorm(x))
FFN is SwiGLU for dense configs, top-k MoE for MoE configs.
Layers are stacked and scanned; training remats each layer.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from .common import (
    AttnParams,
    attention_block,
    attn_param_specs,
    causal_lm_loss,
    embed_lookup,
    lm_logits,
    rms_norm,
    sds,
    stack_apply,
    stack_apply_collect,
    stack_apply_with_state,
)
from .moe import moe_ffn, moe_param_specs

Array = jax.Array


def _stack_specs(spec_tree, n: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec_tree
    )


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    D, F, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    layer: Dict[str, Any] = {
        "attn": attn_param_specs(cfg)._asdict(),
        "attn_norm": sds((D,)),
        "mlp_norm": sds((D,)),
    }
    if cfg.is_moe:
        layer["moe"] = moe_param_specs(cfg)
    else:
        layer["mlp"] = {
            "w_gate": sds((D, F)),
            "w_up": sds((D, F)),
            "w_down": sds((F, D)),
        }
    out: Dict[str, Any] = {
        "embed": sds((cfg.padded_vocab, D)),
        "final_norm": sds((D,)),
        "layers": _stack_specs(layer, L),
    }
    if cfg.family == "vlm":
        out["patch_proj"] = sds((D, D))  # stub ViT output -> backbone space
    return out


def init_params(cfg: ArchConfig, key: Array) -> Dict[str, Any]:
    specs = param_specs(cfg)
    flat, tree = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(flat))
    leaves = [
        (jax.random.normal(k, s.shape, s.dtype) * 0.02).astype(s.dtype)
        for k, s in zip(keys, flat)
    ]
    return jax.tree.unflatten(tree, leaves)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _ffn(p_layer: Dict[str, Any], x: Array, cfg: ArchConfig) -> Array:
    if cfg.is_moe:
        return moe_ffn(p_layer["moe"], x, cfg)
    m = p_layer["mlp"]
    g = jnp.einsum("bsd,df->bsf", x, m["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, m["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, m["w_down"])


def _layer(p: Dict[str, Any], h: Array, cfg: ArchConfig, positions: Array) -> Array:
    a_in = rms_norm(h, p["attn_norm"])
    attn_out, _ = attention_block(
        AttnParams(**p["attn"]), a_in, cfg, positions=positions, causal=True,
        window=cfg.window,
    )
    h = h + attn_out
    f_in = rms_norm(h, p["mlp_norm"])
    h = h + _ffn(p, f_in, cfg)
    return h


def _trunk(params, h: Array, cfg: ArchConfig, positions: Array, remat: bool) -> Array:
    def layer_fn(p, hh):
        return _layer(p, hh, cfg, positions)

    fn = jax.checkpoint(layer_fn) if remat else layer_fn
    h = stack_apply(fn, params["layers"], h, unrolled=cfg.analysis_unroll)
    return rms_norm(h, params["final_norm"])


def _embed_inputs(params, batch: Dict[str, Array], cfg: ArchConfig) -> Array:
    h = embed_lookup(params["embed"], batch["tokens"])  # [B, St, D]
    if cfg.family == "vlm":
        patches = jnp.einsum("bpd,de->bpe", batch["patches"], params["patch_proj"])
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)  # early fusion
    return h


def loss(cfg: ArchConfig, params, batch: Dict[str, Array]) -> Array:
    h = _embed_inputs(params, batch, cfg)
    S = h.shape[1]
    positions = jnp.arange(S)
    h = _trunk(params, h, cfg, positions, remat=True)
    if cfg.family == "vlm":
        h = h[:, cfg.n_patches :]  # loss on text positions only
    logits = lm_logits(h, params["embed"])
    return causal_lm_loss(logits, batch["tokens"], cfg.vocab)


def prefill(cfg: ArchConfig, params, batch: Dict[str, Array]):
    """-> (last-position logits [B, V], kv cache [L, B, S, Hkv, hd] x2)."""
    h = _embed_inputs(params, batch, cfg)
    S = h.shape[1]
    positions = jnp.arange(S)

    def layer_fn(p, hh):
        a_in = rms_norm(hh, p["attn_norm"])
        attn_out, kv = attention_block(
            AttnParams(**p["attn"]), a_in, cfg, positions=positions, causal=True,
            window=cfg.window,
        )
        hh = hh + attn_out
        f_in = rms_norm(hh, p["mlp_norm"])
        hh = hh + _ffn(p, f_in, cfg)
        return hh, kv

    h, caches = stack_apply_collect(
        lambda p, hh: layer_fn(p, hh), params["layers"], h,
        unrolled=cfg.analysis_unroll,
    )
    h = rms_norm(h, params["final_norm"])
    logits = lm_logits(h[:, -1], params["embed"])
    return logits, {"k": caches[0], "v": caches[1]}


def decode(cfg: ArchConfig, params, cache: Dict[str, Array], batch: Dict[str, Array]):
    """One-token step. batch: token [B, 1], pos scalar. Cache donated."""
    h = embed_lookup(params["embed"], batch["token"])  # [B, 1, D]
    pos = batch["pos"]

    def layer_fn(p, hh, c):
        kc, vc = c
        a_in = rms_norm(hh, p["attn_norm"])
        attn_out, (kc, vc) = attention_block(
            AttnParams(**p["attn"]), a_in, cfg,
            positions=jnp.atleast_1d(pos), causal=True, window=cfg.window,
            cache_kv=(kc, vc), cache_pos=pos,
        )
        hh = hh + attn_out
        f_in = rms_norm(hh, p["mlp_norm"])
        hh = hh + _ffn(p, f_in, cfg)
        return hh, (kc, vc)

    h, (k_new, v_new) = stack_apply_with_state(
        layer_fn, params["layers"], h, (cache["k"], cache["v"]),
        unrolled=cfg.analysis_unroll,
    )
    h = rms_norm(h, params["final_norm"])
    logits = lm_logits(h[:, -1], params["embed"])
    return logits, {"k": k_new, "v": v_new}


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B = shape.global_batch
    if shape.kind == "train":
        S = shape.seq_len
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = sds((B, S - cfg.n_patches), jnp.int32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        return batch
    if shape.kind == "prefill":
        S = shape.seq_len
        if cfg.family == "vlm":
            return {
                "patches": sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
                "tokens": sds((B, S - cfg.n_patches), jnp.int32),
            }
        return {"tokens": sds((B, S), jnp.int32)}
    # decode
    return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}


def cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    kv = sds((L, B, S, Hkv, hd), jnp.bfloat16)
    return {"k": kv, "v": kv}
