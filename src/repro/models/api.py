"""Uniform model-family API used by the launcher, dry-run and tests.

Every family exposes:
  param_specs(cfg)            ShapeDtypeStruct pytree (no allocation)
  init_params(cfg, key)       real params (reduced/smoke configs only)
  loss(cfg, params, batch)    scalar training loss
  prefill(cfg, params, batch) (logits, cache)
  decode(cfg, params, cache, batch) (logits, cache)
  input_specs(cfg, shape)     batch pytree of ShapeDtypeStruct
  cache_specs(cfg, shape)     cache pytree of ShapeDtypeStruct (decode)
"""

from __future__ import annotations


import jax

from ..configs.base import ArchConfig
from . import dense
from .encdec import Whisper
from .recurrent_lm import XLSTM, Zamba2


class _DenseFamily:
    param_specs = staticmethod(dense.param_specs)
    init_params = staticmethod(dense.init_params)
    loss = staticmethod(dense.loss)
    prefill = staticmethod(dense.prefill)
    decode = staticmethod(dense.decode)
    input_specs = staticmethod(dense.input_specs)
    cache_specs = staticmethod(dense.cache_specs)


_FAMILIES = {
    "dense": _DenseFamily,
    "moe": _DenseFamily,  # same trunk, MoE FFN switched by cfg.is_moe
    "vlm": _DenseFamily,  # early-fusion patches handled by cfg.family
    "ssm_xlstm": XLSTM,
    "hybrid": Zamba2,
    "encdec": Whisper,
}


def family_for(cfg: ArchConfig):
    return _FAMILIES[cfg.family]


def abstract_params(cfg: ArchConfig):
    return family_for(cfg).param_specs(cfg)


def count_params(cfg: ArchConfig) -> int:
    import math

    specs = abstract_params(cfg)
    return sum(math.prod(s.shape) for s in jax.tree.leaves(specs))


def active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE: routed top-k of the experts)."""
    if not cfg.is_moe:
        return count_params(cfg)
    total = count_params(cfg)
    expert_p = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
    active_expert_p = 3 * cfg.d_model * cfg.d_ff * cfg.top_k * cfg.n_layers
    return total - expert_p + active_expert_p
