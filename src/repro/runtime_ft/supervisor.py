"""Fault-tolerant training supervisor: checkpoint/restart + straggler policy.

Control-plane logic, unit-testable in-process.  On a real cluster each
ingredient maps 1:1:

  * ``run_with_restarts``    — the per-job restart wrapper (k8s/borg restarts
    the process; we restart the loop) restoring from the latest atomic
    checkpoint;
  * ``StragglerMonitor``     — per-step deadline tracking; a step exceeding
    ``deadline_factor`` x the trailing-median step time marks its host
    suspect, and after ``max_strikes`` the supervisor requests a re-shard
    without the suspect host (elastic.py computes the new layout);
  * ``HeartbeatTracker``     — dead-node detection by missed heartbeats.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    completed_steps: int = 0
    restored_from: Optional[int] = None


def run_with_restarts(
    *,
    total_steps: int,
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    ckpt: CheckpointManager,
    save_every: int = 10,
    max_restarts: int = 5,
    fault_injector: Optional[Callable[[int], None]] = None,
) -> tuple[Any, RestartStats]:
    """Run ``total_steps`` of ``step_fn`` with checkpoint/restart.

    ``fault_injector(step)`` may raise to simulate node failure (tests)."""
    stats = RestartStats()
    attempts = 0
    while True:
        try:
            latest = ckpt.latest_step()
            if latest is None:
                state, start = make_state(), 0
            else:
                state = ckpt.restore(latest, like=make_state())
                start = latest
                stats.restored_from = latest
            for step in range(start, total_steps):
                if fault_injector is not None:
                    fault_injector(step)
                state = step_fn(state, step)
                stats.completed_steps = step + 1
                if (step + 1) % save_every == 0 or step + 1 == total_steps:
                    ckpt.save(step + 1, state)
            return state, stats
        except KeyboardInterrupt:
            raise
        except Exception:
            attempts += 1
            stats.restarts += 1
            if attempts > max_restarts:
                raise


class StragglerMonitor:
    """Deadline-based straggler detection over per-host step times."""

    def __init__(self, deadline_factor: float = 3.0, max_strikes: int = 3,
                 window: int = 32):
        self.deadline_factor = deadline_factor
        self.max_strikes = max_strikes
        self.window = window
        self.history: List[float] = []
        self.strikes: Dict[str, int] = {}

    def observe(self, host: str, step_time: float) -> str:
        """-> 'ok' | 'suspect' | 'evict'."""
        self.history.append(step_time)
        self.history = self.history[-self.window :]
        if len(self.history) < 5:
            return "ok"
        med = statistics.median(self.history)
        if step_time > self.deadline_factor * med:
            self.strikes[host] = self.strikes.get(host, 0) + 1
            if self.strikes[host] >= self.max_strikes:
                return "evict"
            return "suspect"
        self.strikes.pop(host, None)
        return "ok"


class HeartbeatTracker:
    def __init__(self, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[str, float] = {}

    def beat(self, host: str):
        self.last[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]
