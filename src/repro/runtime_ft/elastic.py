"""Elastic scaling: reshard a training state onto a different mesh.

When the supervisor evicts a straggler/dead host (or capacity grows), the
job restarts on a new mesh.  The checkpoint is mesh-agnostic (full logical
arrays, see checkpoint/manager.py); this module recomputes shardings for
the new mesh and re-places state.  ``plan_new_mesh`` picks the largest
axis-consistent mesh that fits the surviving chip count.
"""

from __future__ import annotations

from typing import Any, Tuple

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ArchConfig
from ..dist import sharding as shd


def plan_new_mesh(n_chips: int, *, model_parallel: int = 16) -> Tuple[int, int]:
    """-> (data, model) shape using as many surviving chips as possible while
    keeping the model axis intact (TP degree is a property of the weights'
    layout; shrinking it would change per-op shapes)."""
    if n_chips < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with {n_chips} chips"
        )
    data = n_chips // model_parallel
    return data, model_parallel


def reshard_state(
    cfg: ArchConfig,
    ckpt: CheckpointManager,
    step: int,
    like: Any,
    new_mesh,
) -> Any:
    """Restore checkpoint ``step`` placed for ``new_mesh``."""
    from ..models.api import family_for

    p_specs = family_for(cfg).param_specs(cfg)
    shardings = shd.param_shardings(cfg, new_mesh, p_specs)
    return ckpt.restore(step, like=like, shardings=shardings)
