"""repro.prune — ETHEREAL-style clause pruning + weighted clauses.

The model-compression pass of the Fig-8 loop: it sits between the
``RecalWorker`` (which grows clauses) and the ``Compressor`` (which ships
them), shrinking the compressed program before publication.

Three passes over the dense action mask (all shape-preserving — a pruned
clause is a ZEROED clause row, which ``encode`` already skips, so the
instruction stream shrinks automatically and every downstream engine/
capacity/artifact path keeps working unchanged):

  * ``prune_exact``    drops only provably-dead clauses (empty,
                       contradictory, polarity-cancelled groups) —
                       bit-exact by construction;
  * ``merge_weighted`` collapses duplicate clauses into one weighted
                       clause (vote = weight * polarity) — bit-exact by
                       construction;
  * ``prune_ranked``   drops the low-vote-contribution tail subject to a
                       holdout accuracy tolerance (binary-searched cut).

``PrunePolicy`` composes them into the gated pipeline the
``RecalController`` runs before every publication.
"""

from .rank import (
    clause_fire_counts,
    contradictory_clauses,
    dead_clause_mask,
    duplicate_groups,
    vote_contribution,
)
from .passes import (
    PrunePolicy,
    PruneReport,
    PruneResult,
    merge_weighted,
    prune_exact,
    prune_ranked,
)

__all__ = [
    "PrunePolicy",
    "PruneReport",
    "PruneResult",
    "clause_fire_counts",
    "contradictory_clauses",
    "dead_clause_mask",
    "duplicate_groups",
    "merge_weighted",
    "prune_exact",
    "prune_ranked",
    "vote_contribution",
]
