"""The three pruning passes and the policy that composes them.

All passes are shape-preserving: a pruned clause is a ZEROED action row.
``encode`` already skips empty clauses, so the compressed stream (and the
artifact, and every engine's working set) shrinks automatically — no
index remapping, no dims change, no capacity invalidation.

  * ``prune_exact``    provably dead clauses only — bit-exact on every
                       input, no traffic needed;
  * ``merge_weighted`` duplicate clauses -> one weighted clause — also
                       bit-exact (identical firing behaviour is what
                       makes the weighted collapse lossless);
  * ``prune_ranked``   lossy: drops the lowest-vote-contribution tail,
                       gated by a holdout accuracy tolerance with a
                       binary-searched cut point.

``PrunePolicy.apply`` chains exact -> merge -> ranked, skipping ranked
when no labelled holdout is available (the ``RecalController.deploy``
path) and recording what ran in the ``PruneReport``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.compress import encode
from ..core.tm import TMConfig, predict_weighted, state_from_actions
from .rank import (
    _as_actions,
    _weights_or_ones,
    dead_clause_mask,
    duplicate_groups,
    vote_contribution,
)

_MAX_WEIGHT = 65535  # uint16 wire format (program.py packs weights '<u2')


@dataclasses.dataclass(frozen=True)
class PruneReport:
    """What a pass (or a whole policy run) did, in clause counts."""

    stages: Tuple[str, ...]
    n_clauses_before: int
    n_clauses_after: int
    n_dead: int = 0
    n_merged: int = 0
    n_ranked: int = 0
    baseline_accuracy: Optional[float] = None
    pruned_accuracy: Optional[float] = None
    tolerance: Optional[float] = None

    @property
    def n_removed(self) -> int:
        return self.n_clauses_before - self.n_clauses_after


@dataclasses.dataclass(frozen=True)
class PruneResult:
    """Pruned model: zeroed-row action mask + (optionally) clause weights.

    ``weights`` is ``None`` whenever every surviving clause has weight 1 —
    the weightless wire format (v1) keeps covering exact-only pruning.
    Feed ``actions``/``weights`` straight to ``encode`` /
    ``Compressor.compress``.
    """

    actions: np.ndarray  # bool[M, C, 2F]
    weights: Optional[np.ndarray]  # uint16[M, C] or None (all unit)
    report: PruneReport


def _nonempty_count(actions: np.ndarray) -> int:
    return int(actions.any(axis=-1).sum())


def _normalize_weights(
    actions: np.ndarray, weights: Optional[np.ndarray]
) -> Optional[np.ndarray]:
    """Unit weights everywhere that matters -> ``None`` (weightless wire);
    otherwise a uint16[M, C] with empty rows pinned to the neutral 1."""
    if weights is None:
        return None
    w = np.asarray(weights).astype(np.int64).copy()
    nonempty = actions.any(axis=-1)
    w[~nonempty] = 1
    if bool((w == 1).all()):
        return None
    return w.astype(np.uint16)


def _accuracy(
    cfg: TMConfig,
    actions: np.ndarray,
    weights: Optional[np.ndarray],
    X: np.ndarray,
    y: np.ndarray,
) -> float:
    state = state_from_actions(cfg, actions)
    w = None if weights is None else jnp.asarray(weights, jnp.int32)
    pred = np.asarray(predict_weighted(cfg, state, jnp.asarray(X), w))
    return float((pred == np.asarray(y)).mean())


def prune_exact(
    cfg: TMConfig,
    actions: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> PruneResult:
    """Drop only provably-dead clauses — bit-exact class sums on EVERY
    input by construction (dead = zero contribution always)."""
    actions = _as_actions(cfg, actions)
    before = _nonempty_count(actions)
    dead = dead_clause_mask(cfg, actions, weights)
    out = actions.copy()
    out[dead] = False
    after = _nonempty_count(out)
    return PruneResult(
        actions=out,
        weights=_normalize_weights(out, weights),
        report=PruneReport(
            stages=("exact",),
            n_clauses_before=before,
            n_clauses_after=after,
            n_dead=before - after,
        ),
    )


def merge_weighted(
    cfg: TMConfig,
    actions: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> PruneResult:
    """Collapse each duplicate-clause group into ONE weighted clause.

    Clauses of a class with identical include sets fire identically, so
    the group's aggregate vote on any input is its net signed weight
    ``net = sum(+w even slots) - sum(w odd slots)``.  Keep a single
    survivor on a slot whose parity matches ``sign(net)`` with weight
    ``|net|`` (zero the rest); a fully-cancelled group (net 0) is zeroed
    outright.  Bit-exact by construction.  Groups whose ``|net|``
    overflows the uint16 weight format are left untouched rather than
    merged lossily."""
    actions = _as_actions(cfg, actions)
    w = _weights_or_ones(cfg, weights)
    before = _nonempty_count(actions)
    out = actions.copy()
    new_w = w.copy()
    for (m, _), slots in duplicate_groups(cfg, actions).items():
        net = sum(int(w[m, j]) * (1 if j % 2 == 0 else -1) for j in slots)
        if abs(net) > _MAX_WEIGHT:
            continue
        # net > 0 implies an even (positive) slot exists in the group, and
        # net < 0 an odd one — a parity-matched survivor always exists.
        want_parity = 0 if net > 0 else 1
        keep = next((j for j in slots if j % 2 == want_parity), None)
        for j in slots:
            if net != 0 and j == keep:
                new_w[m, j] = abs(net)
            else:
                out[m, j] = False
                new_w[m, j] = 1
    after = _nonempty_count(out)
    return PruneResult(
        actions=out,
        weights=_normalize_weights(out, new_w),
        report=PruneReport(
            stages=("merge",),
            n_clauses_before=before,
            n_clauses_after=after,
            n_merged=before - after,
        ),
    )


def prune_ranked(
    cfg: TMConfig,
    actions: np.ndarray,
    X: np.ndarray,
    y: np.ndarray,
    *,
    tolerance: float,
    weights: Optional[np.ndarray] = None,
) -> PruneResult:
    """Lossy tail drop, gated by holdout accuracy.

    Ranks every surviving clause by its vote contribution over ``X``
    (ablation class-sum delta = weight * fire count), then binary-searches
    the largest ascending-contribution prefix that can be zeroed while
    holdout accuracy stays within ``tolerance`` of the unpruned baseline.
    Cost: O(log n_clauses) holdout predictions."""
    if tolerance < 0:
        raise ValueError(f"tolerance must be >= 0, got {tolerance}")
    actions = _as_actions(cfg, actions)
    w = _weights_or_ones(cfg, weights)
    before = _nonempty_count(actions)
    baseline = _accuracy(cfg, actions, weights, X, y)
    floor = baseline - tolerance

    contrib = vote_contribution(cfg, actions, X, w)
    nonempty = actions.any(axis=-1)
    cand = np.argwhere(nonempty)  # [n, 2] (class, clause), all droppable
    order = np.argsort(contrib[nonempty], kind="stable")
    cand = cand[order]  # ascending contribution

    def drop(k: int) -> np.ndarray:
        out = actions.copy()
        if k:
            out[cand[:k, 0], cand[:k, 1]] = False
        return out

    lo, hi = 0, len(cand)
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if _accuracy(cfg, drop(mid), weights, X, y) >= floor:
            lo = mid
        else:
            hi = mid - 1
    out = drop(lo)
    after = _nonempty_count(out)
    return PruneResult(
        actions=out,
        weights=_normalize_weights(out, w),
        report=PruneReport(
            stages=("ranked",),
            n_clauses_before=before,
            n_clauses_after=after,
            n_ranked=before - after,
            baseline_accuracy=baseline,
            pruned_accuracy=_accuracy(cfg, out, weights, X, y),
            tolerance=float(tolerance),
        ),
    )


@dataclasses.dataclass(frozen=True)
class PrunePolicy:
    """Which passes to run before publication, composed in the only order
    that makes sense: exact (free) -> merge (free, may create weights) ->
    ranked (lossy, needs a labelled holdout).

    ``tolerance=None`` disables the ranked pass entirely; with a tolerance
    set, the pass still auto-skips when ``apply`` gets no ``X``/``y`` —
    the controller's deploy path has traffic but no labels."""

    exact: bool = True
    merge: bool = True
    tolerance: Optional[float] = None

    def apply(
        self,
        cfg: TMConfig,
        actions: np.ndarray,
        X: Optional[np.ndarray] = None,
        y: Optional[np.ndarray] = None,
        weights: Optional[np.ndarray] = None,
    ) -> PruneResult:
        actions = _as_actions(cfg, actions)
        before = _nonempty_count(actions)
        stages: List[str] = []
        n_dead = n_merged = n_ranked = 0
        baseline = pruned_acc = None
        cur_a, cur_w = actions, weights

        if self.exact:
            r = prune_exact(cfg, cur_a, cur_w)
            cur_a, cur_w = r.actions, r.weights
            stages.append("exact")
            n_dead = r.report.n_dead
        if self.merge:
            r = merge_weighted(cfg, cur_a, cur_w)
            # size-gate: the weight vector costs 2 bytes for EVERY
            # non-empty clause once any weight exceeds 1, which can
            # outweigh the instructions the merge saved.  A compression
            # pass must never grow the artifact, so keep the merge only
            # when the encoded stream actually shrinks (ties go to the
            # merge — fewer clauses at equal bytes).
            if (
                r.report.n_merged == 0
                or encode(cfg, r.actions, clause_weights=r.weights).n_bytes
                <= encode(cfg, cur_a, clause_weights=cur_w).n_bytes
            ):
                cur_a, cur_w = r.actions, r.weights
                stages.append("merge")
                n_merged = r.report.n_merged
            else:
                stages.append("merge:skipped-grows-bytes")
        if self.tolerance is not None and X is not None and y is not None:
            r = prune_ranked(
                cfg, cur_a, X, y, tolerance=self.tolerance, weights=cur_w
            )
            cur_a, cur_w = r.actions, r.weights
            stages.append("ranked")
            n_ranked = r.report.n_ranked
            baseline = r.report.baseline_accuracy
            pruned_acc = r.report.pruned_accuracy
        elif self.tolerance is not None:
            stages.append("ranked:skipped-no-labels")

        return PruneResult(
            actions=cur_a,
            weights=_normalize_weights(cur_a, cur_w),
            report=PruneReport(
                stages=tuple(stages),
                n_clauses_before=before,
                n_clauses_after=_nonempty_count(cur_a),
                n_dead=n_dead,
                n_merged=n_merged,
                n_ranked=n_ranked,
                baseline_accuracy=baseline,
                pruned_accuracy=pruned_acc,
                tolerance=self.tolerance,
            ),
        )
