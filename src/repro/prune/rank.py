"""Per-clause vote-contribution ranking + exact dead-clause detection.

The ranking signal is the ablation class-sum delta: removing clause
``(m, j)`` changes row ``m`` of the class-sum matrix by exactly
``-pol * weight * fires(j, x)`` on every datapoint ``x``, so the total
absolute inference impact of a clause over a traffic sample ``X`` is

    contribution(m, j) = weight(m, j) * |{x in X : clause (m, j) fires}|

— no re-encoding, no second engine pass: one batched dense sweep over the
replay-buffer/holdout sample scores every clause at once.

Dead-clause detection is structural (traffic-independent) and PROVABLY
zero-impact on all inputs:

  * empty clauses           no includes -> output 0 at inference;
  * contradictory clauses   include both literal ``2f`` and its complement
                            ``2f+1`` -> can never fire;
  * cancelled groups        clauses of one class with IDENTICAL include
                            sets fire identically, so their net vote is
                            ``sum(+w for even slots) - sum(w for odd)``;
                            a group whose net is 0 contributes nothing.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.tm import TMConfig, literals


def _as_actions(cfg: TMConfig, actions: np.ndarray) -> np.ndarray:
    actions = np.asarray(actions, dtype=bool)
    expect = (cfg.n_classes, cfg.n_clauses, cfg.n_literals)
    if actions.shape != expect:
        raise ValueError(
            f"actions must be bool{list(expect)}, got {actions.shape}"
        )
    return actions


def _weights_or_ones(cfg: TMConfig, weights) -> np.ndarray:
    if weights is None:
        return np.ones((cfg.n_classes, cfg.n_clauses), np.int64)
    w = np.asarray(weights)
    if w.shape != (cfg.n_classes, cfg.n_clauses):
        raise ValueError(
            f"weights must be int[{cfg.n_classes}, {cfg.n_clauses}], got "
            f"shape {w.shape}"
        )
    return w.astype(np.int64)


def clause_fire_counts(
    cfg: TMConfig, actions: np.ndarray, X: np.ndarray
) -> np.ndarray:
    """int64[M, C]: rows of ``X`` each clause fires on (inference
    semantics: empty clauses never fire).

    One batched pass: a clause fires iff every included literal is 1, i.e.
    iff its hit count ``sum_l actions[m,c,l] * lits[b,l]`` reaches its
    include count — a single einsum over the traffic sample."""
    actions = _as_actions(cfg, actions)
    X = np.asarray(X)
    lits = np.asarray(literals(jnp.asarray(X, bool))).astype(np.int64)
    includes = actions.sum(axis=-1)  # [M, C]
    hits = np.einsum(
        "bl,mcl->bmc", lits, actions.astype(np.int64), optimize=True
    )
    fires = (hits == includes[None]) & (includes[None] > 0)
    return fires.sum(axis=0).astype(np.int64)


def vote_contribution(
    cfg: TMConfig,
    actions: np.ndarray,
    X: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """int64[M, C]: total |class-sum delta| over ``X`` if the clause were
    ablated — ``weight * fire_count``.  THE ranking key of
    ``prune_ranked``; zero-contribution clauses are free to drop on this
    traffic (though only ``dead_clause_mask`` proves them dead on ALL
    traffic)."""
    w = _weights_or_ones(cfg, weights)
    return clause_fire_counts(cfg, actions, X) * w


def contradictory_clauses(cfg: TMConfig, actions: np.ndarray) -> np.ndarray:
    """bool[M, C]: clauses including some feature AND its complement —
    structurally unsatisfiable, they can never fire on any input."""
    actions = _as_actions(cfg, actions)
    a = actions.reshape(cfg.n_classes, cfg.n_clauses, cfg.n_features, 2)
    return np.any(a[..., 0] & a[..., 1], axis=-1)


def duplicate_groups(
    cfg: TMConfig, actions: np.ndarray
) -> Dict[Tuple[int, bytes], List[int]]:
    """Group non-empty clauses of each class by their exact include set.

    -> ``{(class, include-set key): [clause slots]}``, only groups with
    >= 2 members.  Clauses in one group fire identically on EVERY input,
    which is what makes cancellation (rank) and weighted merging (passes)
    exact rather than approximate."""
    actions = _as_actions(cfg, actions)
    groups: Dict[Tuple[int, bytes], List[int]] = defaultdict(list)
    for m in range(cfg.n_classes):
        for j in range(cfg.n_clauses):
            row = actions[m, j]
            if row.any():
                groups[(m, row.tobytes())].append(j)
    return {k: v for k, v in groups.items() if len(v) >= 2}


def dead_clause_mask(
    cfg: TMConfig,
    actions: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """bool[M, C]: provably-zero contributors on ALL inputs.

    Union of: empty clauses, contradictory clauses, and duplicate groups
    whose net weighted vote cancels to zero (equal positive and negative
    weight over identical firing behaviour).  ``prune_exact`` drops
    exactly this set — bit-exactness follows by construction."""
    actions = _as_actions(cfg, actions)
    w = _weights_or_ones(cfg, weights)
    dead = ~actions.any(axis=-1)  # empty
    dead |= contradictory_clauses(cfg, actions)
    for (m, _), slots in duplicate_groups(cfg, actions).items():
        live = [j for j in slots if not dead[m, j]]
        net = sum(int(w[m, j]) * (1 if j % 2 == 0 else -1) for j in live)
        if live and net == 0:
            dead[m, live] = True
    return dead
