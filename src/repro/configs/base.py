"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; shapes are the four
assigned input-shape cells.  ``registry.py`` maps ``--arch <id>`` to these.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm_xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    attn_every: int = 6  # hybrid: shared attention block period
    window: int = 0  # sliding-window attention (0 = full causal)
    # enc-dec
    n_encoder_layers: int = 0
    encoder_len: int = 1500  # whisper frame count (stub frontend output)
    # vlm
    n_patches: int = 0  # stub ViT patch embedding count
    # numerics / optimizer
    dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    rope_theta: float = 10000.0
    # distribution
    fsdp: bool = False  # shard big weight dims over the data axis too
    attn_tp: bool = True  # False: replicate attention weights (pure-DP
    # attention; right call when d_model/TP would be MXU-starved)
    # training memory: gradient-accumulation microbatches (activation
    # footprint scales with global_batch / microbatches)
    train_microbatches: int = 1
    # analysis: replace layer-stack scans with Python loops so XLA
    # cost_analysis counts every layer (used by the dry-run's u=1/u=2
    # variants; see analysis/corrections.py)
    analysis_unroll: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded so the vocab dim shards evenly (logits for
        padded rows are masked in the loss)."""
        return _pad_to(self.vocab, 256)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeSpec, ...]:
    """long_500k requires a sub-quadratic decode path: recurrent-state
    (ssm/xlstm) or windowed-attention (hybrid) families only.  Pure
    full-attention archs skip it (documented in DESIGN.md §Arch-applicability
    and recorded as SKIP rows in EXPERIMENTS.md)."""
    if cfg.family in ("ssm_xlstm", "hybrid"):
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)


def shape_by_name(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
