"""``--arch <id>`` registry: the 10 assigned architectures (exact dims from
the assignment) + the paper's own TM configurations + reduced smoke variants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ArchConfig

# --------------------------------------------------------------------------
# Assigned architectures (dims verbatim from the assignment block)
# --------------------------------------------------------------------------

ARCHS: Dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


STARCODER2_7B = _reg(ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152,
    fsdp=True, train_microbatches=8,
))

STABLELM_12B = _reg(ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352,
    fsdp=True, train_microbatches=8,
))

DEEPSEEK_7B = _reg(ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400,
    fsdp=True, train_microbatches=8,
))

STABLELM_3B = _reg(ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304,
    fsdp=True, train_microbatches=4,
))

XLSTM_125M = _reg(ArchConfig(
    name="xlstm-125m", family="ssm_xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    train_microbatches=2,
))

LLAMA4_MAVERICK = _reg(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=128, top_k=1,
    moment_dtype="bfloat16",  # optimizer state budget (DESIGN.md §5)
    fsdp=True, train_microbatches=8,
))

MOONSHOT_16B = _reg(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6,
    fsdp=True, train_microbatches=8, attn_tp=False,
))

ZAMBA2_2P7B = _reg(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2,
    attn_every=6, window=4096,  # windowed shared attention => long_500k OK
    fsdp=True, train_microbatches=4,
))

WHISPER_MEDIUM = _reg(ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    n_encoder_layers=24, encoder_len=1500,
    train_microbatches=4,
))

INTERNVL2_26B = _reg(ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553,
    n_patches=256, fsdp=True, train_microbatches=8,
))


# --------------------------------------------------------------------------
# Reduced smoke variants (same family/topology, tiny dims) — used by
# per-arch smoke tests that run a real forward/train step on CPU.
# --------------------------------------------------------------------------

def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2 if cfg.family != "hybrid" else 4,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
    )
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.family == "hybrid":
        kw.update(ssm_state=8, ssm_headdim=16, ssm_expand=2, attn_every=2, window=64)
    if cfg.family == "encdec":
        kw.update(n_encoder_layers=2, encoder_len=16)
    if cfg.family == "vlm":
        kw.update(n_patches=4)
    return dataclasses.replace(cfg, **kw)


def get(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return smoke_variant(ARCHS[name[: -len("-smoke")]])
    return ARCHS[name]


def all_arch_names():
    return list(ARCHS.keys())
