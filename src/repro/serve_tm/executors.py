"""DEPRECATED shim — the executor layer moved to ``repro.accel``.

The serving engines are now formal plugins (``repro.accel.engines``)
behind the ``Engine`` protocol, capacity is the negotiated
``CapacityPlan``, and deployment goes through the ``Accelerator`` façade
(``repro.accel.facade``).  The old names stay importable here so existing
callers keep working:

    ServeCapacity      -> accel.capacity.CapacityPlan  (same knobs,
                          same defaults; capacity errors are now the
                          structured CapacityExceeded, still a ValueError)
    InterpExecutor     -> accel.engines.InterpEngine
    PlanExecutor       -> accel.engines.PlanEngine
    ShardedExecutor    -> accel.engines.ShardedEngine
    PopcountExecutor   -> accel.engines.PopcountEngine
    BACKENDS           -> accel.engine.ENGINES (the live plugin registry)
    make_executor(...) -> accel.engine.make_engine(...)

New code should import from ``repro.accel`` directly — importing this
module (or calling ``make_executor``) emits a ``DeprecationWarning``,
once per process.  This module also
no longer mutates process-global warning state: the donation-declined
suppression is scoped to the donating engine's dispatch
(``accel.engine._donation_declined_ok``).
"""

from __future__ import annotations

import warnings

from ..accel.capacity import CapacityExceeded, CapacityPlan
from ..accel.engine import ENGINES, EngineBase, make_engine
from ..accel.engines import (
    InterpEngine,
    PlanEngine,
    PopcountEngine,
    ShardedEngine,
)

# fires once per process: the module body runs only on first import, and
# repro.serve_tm itself no longer routes through this shim
warnings.warn(
    "repro.serve_tm.executors is deprecated: the executor layer moved to "
    "repro.accel (ServeCapacity -> CapacityPlan, make_executor -> "
    "make_engine, BACKENDS -> ENGINES, *Executor -> accel.engines.*Engine)",
    DeprecationWarning,
    stacklevel=2,
)

# legacy spellings
ServeCapacity = CapacityPlan
InterpExecutor = InterpEngine
PlanExecutor = PlanEngine
ShardedExecutor = ShardedEngine
PopcountExecutor = PopcountEngine
_ExecutorBase = EngineBase
BACKENDS = ENGINES


def make_executor(
    backend: "str | EngineBase", capacity: CapacityPlan, mesh=None
) -> EngineBase:
    """Deprecated: use ``repro.accel.make_engine`` (uniform plugin
    construction; mesh forwarding is capability-flag-driven)."""
    warnings.warn(
        "make_executor is deprecated; use repro.accel.make_engine",
        DeprecationWarning,
        stacklevel=2,
    )
    return make_engine(backend, capacity, mesh=mesh)


__all__ = [
    "BACKENDS",
    "CapacityExceeded",
    "InterpExecutor",
    "PlanExecutor",
    "PopcountExecutor",
    "ServeCapacity",
    "ShardedExecutor",
    "make_executor",
]
