"""Pluggable executor backends for the TM serving subsystem.

Three engines over the same ``CompressedModel``, one shared contract:

  ``program(model)``             host-side compile-free "reprogram" — decode
                                 the instruction stream into the backend's
                                 fixed-capacity buffers (pure data movement)
  ``class_sums(prog, x)``        {0,1}[B, F] -> int32[B, n_classes]
  ``compile_cache_size()``       # of compiled variants of THIS executor's
                                 jitted program (the zero-resynthesis
                                 property: must stay 1 across model swaps)

Backends:

  * ``interp``   — the paper-faithful stream interpreter
    (``core.interp.interpret_stream``): one instruction per scan step over
    the fixed-depth instruction memory.
  * ``plan``     — the decoded-plan fast path
    (``core.interp.plan_class_sums``): gather + segmented reduction,
    parallel across includes and datapoints.
  * ``sharded``  — the ``dist.tm_sharded`` clause-major shard_map executor
    (classes over ``model``, batch over the data axes); on a 1x1 mesh this
    is the single-device realization of the Fig-7 multi-core split.
  * ``popcount`` — the popcount bitplane fast path
    (``kernels.tm_popcount``): clause outputs stay packed ``uint32`` until
    a clause boundary; class sums come from ``lax.population_count``
    against per-class polarity-bank selection bitplanes.  Pallas kernel on
    TPU, the bit-exact pure-XLA twin elsewhere.

All four are bit-exact against the ``core.tm.batch_class_sums`` oracle
(enforced by tests/test_serve_tm.py).  Every executor instance owns a
PRIVATE jit cache (a fresh closure over the underlying function), so
``compile_cache_size()`` counts only this engine's compilations — the
module-level jit caches of interp.py are shared process-wide and would
make the ==1 assertion meaningless under parallel test traffic.

Serving buffers are device-resident: ``program()`` moves the decoded
program to the accelerator ONCE (``jax.device_put``); per-flush features
are packed by the batcher straight into a preallocated host staging array
(``_ExecutorBase.staging``) instead of a fresh ``np.pad`` per call, and
the popcount backend donates its per-call device copy of that staging
block back to XLA (``donate_argnums``) so flushes never accumulate live
feature buffers.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import _pad_to
from ..core.compress import CompressedModel, decode_to_plan
from ..core.interp import interpret_stream, pack_features, pad_plan, plan_class_sums
from ..core.tm import literals, pack_literals
from ..dist.sharding import _axis_sizes
from ..dist.tm_sharded import (
    TMShardedConfig,
    build_tm_sharded,
    fill_clause_tables,
)
from ..kernels.tm_popcount.kernel import tm_popcount, tm_popcount_xla
from ..kernels.tm_popcount.ops import plan_to_popcount_operands
from ..kernels.tuning import choose_blocks

# buffer donation is an optimization hint; off-TPU XLA may decline it and
# warn — that is expected on the CPU test/CI containers, not actionable
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)


@dataclasses.dataclass(frozen=True)
class ServeCapacity:
    """The serving deployment's "synthesis-time" capacity plan (the Fig-6
    memory-depth customization, extended with the clause-table dims the
    plan/sharded layouts need).  Everything inside these bounds is runtime
    state; exceeding them raises (= "resynthesize with a bigger config")."""

    instruction_capacity: int = 4096   # instruction memory / include-list depth
    feature_capacity: int = 256        # Boolean features per datapoint
    class_capacity: int = 16           # class-sum accumulator bank depth
    clause_capacity: int = 64          # clauses per class (clause tables)
    include_capacity: int = 32         # includes per clause (clause-major)
    batch_words: int = 4               # 32 datapoints per bit-packed word

    @property
    def batch_capacity(self) -> int:
        return self.batch_words * 32

    @property
    def clause_total_capacity(self) -> int:
        return self.class_capacity * self.clause_capacity


def _private_jit(fn, **jit_kwargs):
    """jit over a FRESH closure: JAX keys its compilation cache on the
    callable, so wrapping gives this executor instance its own cache."""

    def inner(*args, **kwargs):
        return fn(*args, **kwargs)

    return jax.jit(inner, **jit_kwargs)


def _check(cond: bool, what: str, have: int, cap: int, knob: str) -> None:
    if not cond:
        raise ValueError(
            f"model {what} {have} exceeds serving capacity {cap}; "
            f"resynthesize with a larger ServeCapacity.{knob}"
        )


class _ExecutorBase:
    name = "?"

    def __init__(self, capacity: ServeCapacity):
        self.capacity = capacity
        self._staging: np.ndarray | None = None

    def compile_cache_size(self) -> int:
        return self._fn._cache_size()

    @property
    def staging(self) -> np.ndarray:
        """The engine's preallocated [batch_capacity, feature_capacity]
        uint8 feature staging array.  The batcher packs request rows
        straight into it (``Batcher.next_batch(out=...)``) and the engines
        consume it as their one fixed operand shape — no per-flush host
        allocation."""
        if self._staging is None:
            c = self.capacity
            self._staging = np.zeros(
                (c.batch_capacity, c.feature_capacity), np.uint8
            )
        return self._staging

    def _pad_x(self, x: np.ndarray) -> np.ndarray:
        """{0,1}[B, F] -> the staging array (zero-padded to capacity).

        When ``x`` is already a view of ``self.staging`` (the batcher
        packed it there), it is returned as-is — zero copies."""
        c = self.capacity
        B, F = x.shape
        _check(B <= c.batch_capacity, "batch", B, c.batch_capacity,
               "batch_words")
        _check(F <= c.feature_capacity, "n_features", F, c.feature_capacity,
               "feature_capacity")
        st = self.staging
        if np.shares_memory(x, st):
            if (x.__array_interface__["data"][0]
                    == st.__array_interface__["data"][0]):
                # a leading view — the batcher packed rows [0, B) in place
                # and zeroed the remainder (next_batch(out=) contract)
                return st
            # any other overlapping view would be corrupted by the zero
            # fill below; detach it first
            x = np.array(x)
        st.fill(0)
        st[:B, :F] = x
        return st


class InterpExecutor(_ExecutorBase):
    """Paper-faithful fixed-capacity stream interpreter (Fig 4.4-4.6)."""

    name = "interp"

    def __init__(self, capacity: ServeCapacity):
        super().__init__(capacity)
        self._fn = _private_jit(
            interpret_stream.__wrapped__, static_argnames=("m_cap",)
        )

    def program(self, model: CompressedModel) -> Dict[str, Any]:
        c = self.capacity
        _check(model.n_instructions <= c.instruction_capacity,
               "n_instructions", model.n_instructions,
               c.instruction_capacity, "instruction_capacity")
        _check(model.n_classes <= c.class_capacity, "n_classes",
               model.n_classes, c.class_capacity, "class_capacity")
        _check(model.n_features <= c.feature_capacity, "n_features",
               model.n_features, c.feature_capacity, "feature_capacity")
        imem = np.zeros(c.instruction_capacity, np.uint16)
        imem[: model.n_instructions] = model.instructions
        return {
            "imem": jnp.asarray(imem),
            "n_inst": jnp.int32(model.n_instructions),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        c = self.capacity
        B = x.shape[0]
        packed = pack_features(
            jnp.asarray(self._pad_x(x)), c.feature_capacity, c.batch_words
        )
        sums = self._fn(
            prog["imem"], prog["n_inst"], packed, jnp.int32(B),
            m_cap=c.class_capacity,
        )
        return np.asarray(sums)[: prog["n_classes"], :B].T


class PlanExecutor(_ExecutorBase):
    """Decoded-plan executor: gather + segmented min/sum (beyond-paper)."""

    name = "plan"

    def __init__(self, capacity: ServeCapacity):
        super().__init__(capacity)
        self._fn = _private_jit(
            plan_class_sums.__wrapped__,
            static_argnames=("n_clause_cap", "m_cap"),
        )

    def program(self, model: CompressedModel) -> Dict[str, Any]:
        c = self.capacity
        plan = decode_to_plan(model)
        _check(plan.n_includes <= c.instruction_capacity, "n_includes",
               plan.n_includes, c.instruction_capacity,
               "instruction_capacity")
        _check(plan.n_clauses_total <= c.clause_total_capacity,
               "total clauses", plan.n_clauses_total,
               c.clause_total_capacity, "clause_capacity")
        _check(model.n_classes <= c.class_capacity, "n_classes",
               model.n_classes, c.class_capacity, "class_capacity")
        _check(model.n_features <= c.feature_capacity, "n_features",
               model.n_features, c.feature_capacity, "feature_capacity")
        li, ci, cc, cp = pad_plan(
            plan, c.instruction_capacity, c.clause_total_capacity
        )
        return {
            "li": jnp.asarray(li), "ci": jnp.asarray(ci),
            "cc": jnp.asarray(cc), "cp": jnp.asarray(cp),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        c = self.capacity
        B = x.shape[0]
        lits = literals(jnp.asarray(self._pad_x(x)))  # [B_cap, 2*F_cap]
        sums = self._fn(
            prog["li"], prog["ci"], prog["cc"], prog["cp"], lits,
            n_clause_cap=c.clause_total_capacity, m_cap=c.class_capacity,
        )
        return np.asarray(sums)[:B, : prog["n_classes"]]


def _popcount_engine_xla(lit_idx, last, mask_pos, mask_neg, x_staged):
    """Staged features -> packed interleaved literals -> popcount sums."""
    return tm_popcount_xla.__wrapped__(
        lit_idx, last, mask_pos, mask_neg, pack_literals(x_staged)
    )


def _popcount_engine_pallas(
    lit_idx, last, mask_pos, mask_neg, x_staged,
    *, block_instructions, block_words, interpret,
):
    return tm_popcount.__wrapped__(
        lit_idx, last, mask_pos, mask_neg, pack_literals(x_staged),
        block_instructions=block_instructions, block_words=block_words,
        interpret=interpret,
    )


class PopcountExecutor(_ExecutorBase):
    """Popcount bitplane executor (kernels/tm_popcount): packed clause
    words end-to-end, class sums via ``lax.population_count`` against the
    program's polarity-bank selection bitplanes.

    The program (operand vectors + class masks) is pushed to the device
    ONCE at ``program()`` (``jax.device_put``); each engine call ships only
    the staging block, donated to XLA so the feature buffer is recycled
    across flushes rather than accumulating.
    """

    name = "popcount"

    def __init__(self, capacity: ServeCapacity, implementation: str | None = None):
        super().__init__(capacity)
        if implementation is None:
            # the Pallas kernel is the TPU artifact; its interpret-mode
            # emulation loses to the bit-exact XLA twin everywhere else
            implementation = (
                "pallas" if jax.default_backend() == "tpu" else "xla"
            )
        if implementation not in ("pallas", "xla"):
            raise ValueError(
                f"unknown implementation {implementation!r}; "
                f"choose 'pallas' or 'xla'"
            )
        self.implementation = implementation
        if implementation == "pallas":
            bi, bw = choose_blocks(
                capacity.instruction_capacity, capacity.batch_words
            )
            engine = functools.partial(
                _popcount_engine_pallas,
                block_instructions=bi, block_words=bw,
                interpret=jax.default_backend() != "tpu",
            )
        else:
            engine = _popcount_engine_xla
        self._fn = _private_jit(engine, donate_argnums=(4,))

    def program(self, model: CompressedModel) -> Dict[str, Any]:
        c = self.capacity
        _check(model.n_classes <= c.class_capacity, "n_classes",
               model.n_classes, c.class_capacity, "class_capacity")
        _check(model.n_features <= c.feature_capacity, "n_features",
               model.n_features, c.feature_capacity, "feature_capacity")
        plan = decode_to_plan(model)
        _check(plan.n_includes <= c.instruction_capacity, "n_includes",
               plan.n_includes, c.instruction_capacity,
               "instruction_capacity")
        lit_idx, last, mask_pos, mask_neg = plan_to_popcount_operands(
            plan, c.instruction_capacity, c.class_capacity,
            l2_cap=2 * c.feature_capacity,
        )
        # the reprogram is pure data movement: resident on-device until the
        # next swap, never retraced (fixed capacity shapes)
        return {
            "lit_idx": jax.device_put(lit_idx),
            "last": jax.device_put(last),
            "mask_pos": jax.device_put(mask_pos),
            "mask_neg": jax.device_put(mask_neg),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        B = x.shape[0]
        # fresh device copy of the staging block; the engine donates it
        staged = jnp.asarray(self._pad_x(x))
        sums = self._fn(
            prog["lit_idx"], prog["last"],
            prog["mask_pos"], prog["mask_neg"], staged,
        )
        return np.asarray(sums)[: prog["n_classes"], :B].T


class ShardedExecutor(_ExecutorBase):
    """dist.tm_sharded clause-major executor on a (data, model) mesh.

    Built once at CAPACITY shape (classes padded to the model axis, clause
    tables at clause/include capacity); programming a model fills the
    fixed-shape tables, so swaps never touch the compiled shard_map.
    """

    name = "sharded"

    def __init__(self, capacity: ServeCapacity, mesh=None):
        super().__init__(capacity)
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        self.mesh = mesh
        cfg = TMShardedConfig(
            name="serve", n_classes=capacity.class_capacity,
            n_clauses=capacity.clause_capacity,
            n_features=capacity.feature_capacity,
            batch=capacity.batch_capacity,
            include_cap=capacity.include_capacity,
        )
        fn, _ = build_tm_sharded(cfg, mesh)
        # route through _private_jit like every other backend so the
        # compile_cache_size() == 1 contract is enforced uniformly (a bare
        # jax.jit over the closure worked, but only by accident of
        # build_tm_sharded returning a fresh callable)
        self._fn = _private_jit(fn)
        self._Mp = _pad_to(
            capacity.class_capacity, _axis_sizes(mesh).get("model", 1)
        )

    def program(self, model: CompressedModel) -> Dict[str, Any]:
        c = self.capacity
        plan = decode_to_plan(model)
        _check(model.n_classes <= c.class_capacity, "n_classes",
               model.n_classes, c.class_capacity, "class_capacity")
        _check(model.n_features <= c.feature_capacity, "n_features",
               model.n_features, c.feature_capacity, "feature_capacity")
        try:
            idx, pol = fill_clause_tables(
                plan, self._Mp, c.clause_capacity, c.include_capacity,
                2 * c.feature_capacity,
            )
        except ValueError as e:
            raise ValueError(
                f"{e}; resynthesize with a larger "
                f"ServeCapacity.clause_capacity / include_capacity"
            ) from None
        return {
            "idx": jnp.asarray(idx), "pol": jnp.asarray(pol),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        c = self.capacity
        B = x.shape[0]
        lits = np.asarray(
            literals(jnp.asarray(self._pad_x(x), bool))
        ).astype(np.int8)  # [B_cap, 2*F_cap]
        lits1 = np.concatenate(
            [lits, np.ones((c.batch_capacity, 1), np.int8)], axis=1
        )
        sums = self._fn(prog["idx"], prog["pol"], jnp.asarray(lits1))
        return np.asarray(sums)[:B, : prog["n_classes"]]


BACKENDS = {
    "interp": InterpExecutor,
    "plan": PlanExecutor,
    "sharded": ShardedExecutor,
    "popcount": PopcountExecutor,
}


def make_executor(
    backend: str | _ExecutorBase, capacity: ServeCapacity, mesh=None
) -> _ExecutorBase:
    """'interp' | 'plan' | 'sharded' | 'popcount' (or a built instance)."""
    if isinstance(backend, _ExecutorBase):
        return backend
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; choose from {sorted(BACKENDS)}"
        )
    if backend == "sharded":
        return ShardedExecutor(capacity, mesh=mesh)
    return BACKENDS[backend](capacity)
