"""The scheduler-owned continuous-batching flush loop.

Historically ``TMServer`` was caller-driven: ``submit()`` queued rows and
nothing ran until someone called ``flush()``.  The ``Scheduler`` inverts
that: one asyncio task per server (run on a dedicated daemon-thread event
loop so synchronous callers never need a loop of their own) wakes on
every submit — or after ``max_wait_ms`` of batching window — forms the
best batch under ``batch_capacity`` per slot (strict priority order, EDF
within a lane, expired requests shed), runs the engine, demuxes, and
asserts the engine never recompiled.  The same batch-formation/execution
body backs the synchronous ``flush()`` path, so the sync API is now a
*delegate* of the scheduler rather than a separate driver.

Admission control: each (slot, lane) has a bounded queue depth in rows;
``admit`` raises the structured ``Overloaded`` error when a submit would
exceed it.  Default depths shrink with priority (critical gets 8x the
low-lane budget), so under sustained overload low-priority traffic is
rejected first while critical keeps being admitted — the edge-SLO shape
of MATADOR-style real-time deployments.

Thread discipline: two locks at two granularities.  The *batcher* owns a
fine-grained re-entrant lock serializing every lane-heap read/mutation
(submit-side enqueues race the loop's batch formation otherwise — see
``Batcher``); admission control composes on it so the depth check and
the enqueue are one atomic section.  The *scheduler* lock serializes the
batch body (formation + engine run + demux) between the loop thread and
synchronous callers (flush, hot-swap drains, rollback).  Hot-swap holds
the scheduler lock across drain + install, so the drain-under-the-old-
program guarantee holds with the loop running.  The loop body itself is
exception-tolerant: an unexpected error is logged and the loop keeps
running rather than silently stranding every pending request.
"""

from __future__ import annotations

import asyncio
import logging
import threading
import time
from typing import Dict, Optional

import numpy as np

from .batching import Batcher, PRIORITIES, PRIORITY_RANK

logger = logging.getLogger(__name__)

# default per-lane queue-depth budget, in multiples of batch_capacity rows
# (critical admits 8x what low does: overload rejects the low lanes first)
DEFAULT_LANE_DEPTH_BATCHES = {
    "critical": 32, "high": 16, "normal": 8, "low": 4,
}


class Overloaded(RuntimeError):
    """Admission control rejected a submit: the lane's queue is full.

    Structured fields (``slot``, ``priority``, ``pending_rows``,
    ``limit_rows``) let callers implement backoff/retry policies without
    parsing the message."""

    def __init__(
        self, slot: str, priority: str, pending_rows: int, limit_rows: int
    ):
        self.slot = slot
        self.priority = priority
        self.pending_rows = pending_rows
        self.limit_rows = limit_rows
        super().__init__(
            f"slot {slot!r} {priority} lane overloaded: {pending_rows} rows "
            f"queued >= depth limit {limit_rows} — request rejected "
            f"(shed load or retry with backoff)"
        )


class EngineFault(RuntimeError):
    """The batch body raised mid-execution: the engine (or demux) failed
    the whole batch, and every request in it was failed with THIS error
    instead of being left to block until its own timeout.

    Structured fields: ``slot`` (which model slot's batch died) and
    ``cause`` (the original exception).  The scheduler loop itself
    survives — only the batch's requests fail."""

    def __init__(self, slot: str, cause: BaseException):
        self.slot = slot
        self.cause = cause
        super().__init__(
            f"engine batch for slot {slot!r} failed: "
            f"{type(cause).__name__}: {cause} — the batch's requests were "
            f"failed with this error; the serving loop keeps running"
        )


class Scheduler:
    """Continuous-batching driver for one ``TMServer``.

    Constructed unconditionally by the server; until ``start()`` is
    called no loop exists and the sync ``flush()`` path drives the exact
    same ``run_slot_batch`` body (so behavior is identical, minus the
    wake timer)."""

    def __init__(
        self,
        server,
        *,
        max_wait_ms: float = 2.0,
        lane_depth_rows: Optional[Dict[str, int]] = None,
    ):
        self.server = server
        self.max_wait_ms = float(max_wait_ms)
        cap = server.batcher.batch_capacity
        depths = {
            p: DEFAULT_LANE_DEPTH_BATCHES[p] * cap for p in PRIORITIES
        }
        if lane_depth_rows:
            unknown = set(lane_depth_rows) - set(PRIORITIES)
            if unknown:
                raise ValueError(
                    f"unknown lanes in lane_depth_rows: {sorted(unknown)}; "
                    f"expected {PRIORITIES}"
                )
            depths.update(lane_depth_rows)
        self.lane_depth_rows = depths
        # one lock serializes batcher+engine access between the loop
        # thread and sync callers (flush / hot-swap drain / rollback)
        self.lock = threading.RLock()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._wake: Optional[asyncio.Event] = None
        self._stop = False
        self._started_evt = threading.Event()

    # -- lifecycle -----------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> None:
        """Start the continuous-batching loop (idempotent).

        The loop is an asyncio task on a dedicated daemon thread:
        synchronous callers keep their blocking API, async callers
        ``await handle.async_result()``, and submit-side wakes cross the
        thread boundary via ``call_soon_threadsafe``."""
        if self.running:
            return
        self._stop = False
        self._started_evt.clear()
        self._thread = threading.Thread(
            target=self._thread_main, name="tm-scheduler", daemon=True
        )
        self._thread.start()
        self._started_evt.wait()

    def stop(self, drain: bool = True) -> None:
        """Stop the loop; by default drain whatever is still queued
        through the sync path first so no admitted request is stranded."""
        if self.running:
            self._stop = True
            self.wake()
            self._thread.join()
        self._thread = None
        self._loop = None
        self._wake = None
        if drain:
            self.drain_all()

    def _thread_main(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        self._wake = asyncio.Event()
        self._started_evt.set()
        try:
            loop.run_until_complete(self._run())
        finally:
            asyncio.set_event_loop(None)
            loop.close()

    def wake(self) -> None:
        """Submit-side kick: schedule the wake event on the loop thread."""
        loop, wake = self._loop, self._wake
        if loop is None or wake is None:
            return
        try:
            loop.call_soon_threadsafe(wake.set)
        except RuntimeError:
            pass  # loop already closed (stop raced a late submit)

    # -- admission control ---------------------------------------------------

    def admit(self, slot: str, priority: str, rows: int) -> None:
        """Raise ``Overloaded`` when ``rows`` more rows would blow the
        (slot, lane) queue-depth budget."""
        if priority not in PRIORITY_RANK:
            raise ValueError(
                f"unknown priority {priority!r}; expected one of {PRIORITIES}"
            )
        limit = self.lane_depth_rows[priority]
        pending = self.server.batcher.pending_rows(slot, priority)
        if pending + rows > limit:
            self.server.metrics.record_admission_reject(priority)
            raise Overloaded(slot, priority, pending, limit)

    def admit_and_enqueue(self, handle, x: np.ndarray) -> None:
        """Atomic admission + enqueue: depth check and heap push happen
        under the batcher lock, so N concurrent submits cannot all pass
        the same check and collectively blow the lane budget."""
        batcher = self.server.batcher
        with batcher.lock:
            self.admit(handle.slot, handle.priority, x.shape[0])
            batcher.enqueue(handle, x)

    # -- the batch body (shared by the loop and the sync flush path) ---------

    def run_slot_batch(self, slot: str) -> int:
        """Form + execute + demux ONE engine batch for ``slot``; returns
        the number of rows served.  Asserts zero recompilation after the
        batch — the no-resynthesis invariant holds per scheduler-formed
        batch, not just per sync flush."""
        server = self.server
        with self.lock:
            if not server.batcher.pending_rows(slot):
                return 0
            entry = server.registry.get(slot)
            X, spans = server.batcher.next_batch(
                slot, out=server.executor.staging
            )
            self._record_shed()
            if not spans:  # everything queued had already expired
                return 0
            t0 = time.perf_counter()
            try:
                sums = server.executor.class_sums(entry.program, X)
                dt = time.perf_counter() - t0
                preds = np.argmax(sums, axis=1).astype(np.int32)
            except Exception as cause:
                # a raising batch body must not strand its requests until
                # their own timeouts: fail every handle in the batch with
                # a structured error (slot + cause) and keep the loop —
                # and the other slots' traffic — alive.
                fault = EngineFault(slot, cause)
                now = time.perf_counter()
                for handle, _, _, _ in spans:
                    handle._fail(fault, now)
                logger.exception(
                    "engine batch for slot %r failed; %d request(s) "
                    "failed with EngineFault", slot, len(spans),
                )
                return X.shape[0]
            completed = Batcher.demux(spans, preds, sums)
            server.metrics.record_batch(
                X.shape[0], server.capacity.batch_capacity, dt, completed
            )
            for handle, _, _, _ in spans:
                if handle.failed:
                    continue  # a prior batch already failed this request
                if handle.done and handle.latency_s is not None:
                    server.metrics.record_request_latency(handle.latency_s)
                    server.metrics.record_lane_completion(
                        handle.priority,
                        handle.queue_delay_s or 0.0,
                        handle.latency_s,
                        missed=handle.missed_deadline,
                    )
            server._check_no_recompile()
            return X.shape[0]

    def drain_slot(self, slot: str) -> None:
        """Serve every queued row for ``slot`` (the sync flush body and
        the hot-swap drain discipline)."""
        while self.server.batcher.pending_rows(slot):
            self.run_slot_batch(slot)

    def drain_all(self) -> None:
        for slot in self.server.batcher.pending_slots():
            self.drain_slot(slot)

    def _record_shed(self) -> None:
        for handle in self.server.batcher.drain_shed():
            self.server.metrics.record_shed(handle.priority)

    # -- the loop ------------------------------------------------------------

    def _slot_due(self, slot: str, now: float) -> bool:
        """A slot is due when a full batch is waiting, the batching
        window expired, or the earliest queued deadline is at risk."""
        batcher = self.server.batcher
        if batcher.pending_rows(slot) >= batcher.batch_capacity:
            return True
        oldest = batcher.oldest_enqueued_at(slot)
        if oldest is not None and now - oldest >= self.max_wait_ms / 1e3:
            return True
        dl = batcher.earliest_deadline(slot)
        # serve deadlined work a window early rather than shed it late
        return dl is not None and dl - now <= self.max_wait_ms / 1e3

    def _next_due_in(self, now: float) -> float:
        """Seconds until some slot becomes due (sleep bound).

        Bounded by both the batching window of the oldest enqueue AND
        the earliest queued deadline minus a window — ``_slot_due``
        promises to serve deadline-at-risk work a window early, so the
        sleep must wake in time to honor it (a deadline landing just
        after a sleep starts must not be served/shed a window late)."""
        batcher = self.server.batcher
        window = self.max_wait_ms / 1e3
        due_in = window
        for slot in batcher.pending_slots():
            oldest = batcher.oldest_enqueued_at(slot)
            if oldest is not None:
                due_in = min(due_in, max(0.0, oldest + window - now))
            dl = batcher.earliest_deadline(slot)
            if dl is not None:
                due_in = min(due_in, max(0.0, dl - window - now))
        return max(due_in, 1e-4)

    async def _run(self) -> None:
        while not self._stop:
            try:
                now = time.perf_counter()
                served = 0
                for slot in self.server.batcher.pending_slots():
                    if self._slot_due(slot, now):
                        served += self.run_slot_batch(slot)
                if served:
                    # keep draining back-to-back under load, but yield
                    # so cross-thread wakes/cancellations get a turn
                    await asyncio.sleep(0)
                    continue
                try:
                    await asyncio.wait_for(
                        self._wake.wait(), self._next_due_in(now)
                    )
                except (asyncio.TimeoutError, TimeoutError):
                    pass
                self._wake.clear()
            except Exception:
                # a dead loop thread strands every pending request, so
                # never let one bad iteration kill it: log loudly and
                # keep serving (the recompile assertion included — the
                # invariant violation is reported, traffic still moves)
                logger.exception(
                    "tm-scheduler loop iteration failed; continuing"
                )
                await asyncio.sleep(self.max_wait_ms / 1e3)
