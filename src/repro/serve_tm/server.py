"""Multi-tenant, dynamically-batched TM serving over the runtime-tunable
accelerator (the ROADMAP's "serve heavy traffic" north star applied to the
paper's Fig-4/Fig-8 engine).

    server = TMServer(CapacityPlan(...), backend="plan")
    server.register("gas", model)            # program a named slot
    h = server.submit("gas", x)              # queue {0,1}[b, F] datapoints
    server.flush()                           # batch + run + demux
    preds = h.result()

    # or scheduler-owned continuous batching (the async front door):
    server.start()                           # flush loop runs itself
    h = await server.async_submit("gas", x, priority="critical",
                                  timeout_ms=50)
    preds = await h.async_result()
    server.stop()

New deployments should prefer the ``repro.accel.Accelerator`` façade,
which negotiates capacity from the model population and adds the
portable ``TMProgram`` artifact path; ``TMServer`` remains the serving
core underneath it.  Engines come from the ``repro.accel`` plugin
registry: pass ``backend=<name>`` to pin one, a built engine via
``engine=``, or neither to auto-select the fastest eligible plugin.

Tenancy: each slot is one model; requests are batched PER SLOT (models
cannot share an engine pass) but all slots share the single compiled
engine — the multi-tenant generalization of the paper's one-engine-many-
models claim.  ``register`` on a live slot is the hot-swap/recalibration
path: queued traffic for that slot is drained under the OLD program first,
then the new model is installed; the engine is never recompiled, and
every scheduler-formed batch asserts ``compile_cache_size() == 1``.
``register`` also accepts a ``TMProgram`` artifact or its serialized
bytes (reprogram-over-the-wire).

Control flow: batch formation and execution are OWNED by the
``Scheduler`` (serve_tm/scheduler.py).  Without ``start()`` nothing
changes for callers — ``flush()`` drives the scheduler's batch body
synchronously, exactly the old semantics.  With ``start()`` a
continuous-batching asyncio loop forms batches itself (priority lanes,
EDF, deadline shedding, admission control); the sync API keeps working
and serializes against the loop through the scheduler's lock, and
hot-swap/rollback hold that lock across drain + install so in-flight
traffic always completes under the program it was submitted against.
"""

from __future__ import annotations

import itertools
import time
from typing import Dict, Optional

import numpy as np

from ..accel.capacity import CapacityPlan
from ..accel.engine import EngineBase, make_engine, select_engine
from .batching import RequestHandle
from .metrics import ServeMetrics
from .registry import DEFAULT_HISTORY_DEPTH, Installable, ModelRegistry, SlotEntry
from .scheduler import Scheduler


class TMServer:
    def __init__(
        self,
        capacity: Optional[CapacityPlan] = None,
        backend: "Optional[str | EngineBase]" = None,
        mesh=None,
        *,
        engine: "Optional[str | EngineBase]" = None,
        engine_options: Optional[dict] = None,
        history_depth: int = DEFAULT_HISTORY_DEPTH,
        max_wait_ms: float = 2.0,
        lane_depth_rows: Optional[Dict[str, int]] = None,
    ):
        from .batching import Batcher  # deferred: keep import cycle simple

        self.capacity = capacity if capacity is not None else CapacityPlan()
        chosen = engine if engine is not None else backend
        if chosen is None:
            chosen = select_engine(self.capacity, mesh=mesh)
        self.executor = make_engine(
            chosen, self.capacity, mesh=mesh, **(engine_options or {})
        )
        self.registry = ModelRegistry(
            self.executor, history_depth=history_depth
        )
        self.batcher = Batcher(self.capacity.batch_capacity)
        self.metrics = ServeMetrics()
        self.scheduler = Scheduler(
            self, max_wait_ms=max_wait_ms, lane_depth_rows=lane_depth_rows
        )
        # itertools.count.__next__ is atomic in CPython: concurrent
        # submits (loop thread + N callers) never mint duplicate rids
        self._rid = itertools.count()

    # -- the continuous-batching lifecycle -----------------------------------

    def start(self) -> None:
        """Start the scheduler's continuous-batching loop (idempotent).
        Submitted requests are served without anyone calling flush()."""
        self.scheduler.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the loop; queued traffic is drained synchronously first
        (``drain=False`` strands it for a later flush())."""
        self.scheduler.stop(drain=drain)

    @property
    def scheduler_running(self) -> bool:
        return self.scheduler.running

    # -- programming (the Fig-8 reprogram/recalibration path) ---------------

    def register(
        self,
        slot: str,
        model: Installable,
        provenance: str = "install",
    ) -> SlotEntry:
        """Install ``model`` into ``slot``; hot-swaps live slots.

        ``model`` may be a ``CompressedModel``, a ``TMProgram`` artifact,
        or artifact bytes fresh off the wire.  Traffic already queued for
        the slot is drained under the OLD program first (in-flight
        requests keep the model they were submitted against), then the
        swap is pure data movement.  The scheduler lock is held across
        drain + install, so a running loop can never interleave a
        new-program batch into the drain.  ``provenance`` records who
        produced the model (e.g. the recal pipeline tags its swaps
        ``recal:<reason>``).
        """
        with self.scheduler.lock:
            if slot in self.registry and self.batcher.pending_rows(slot):
                self.scheduler.drain_slot(slot)
            t0 = time.perf_counter()
            entry = self.registry.install(slot, model, provenance=provenance)
            self.metrics.record_swap(time.perf_counter() - t0)
            return entry

    def rollback(self, slot: str) -> SlotEntry:
        """Reinstall ``slot``'s previous model (recal safety net).

        Same drain discipline as ``register``: queued traffic finishes
        under the CURRENT program, then the previous entry's programmed
        buffers are swapped back in verbatim.
        """
        with self.scheduler.lock:
            if self.batcher.pending_rows(slot):
                self.scheduler.drain_slot(slot)
            t0 = time.perf_counter()
            entry = self.registry.rollback(slot)
            self.metrics.record_swap(time.perf_counter() - t0)
            self.metrics.record_rollback()
            return entry

    # -- traffic -------------------------------------------------------------

    def _make_handle(
        self,
        slot: str,
        x: np.ndarray,
        priority: str,
        timeout_ms: Optional[float],
    ) -> "tuple[RequestHandle, np.ndarray]":
        entry = self.registry.get(slot)
        x = np.asarray(x, dtype=np.uint8)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[0] == 0:
            raise ValueError(f"expected {{0,1}}[b, F] features, got {x.shape}")
        if x.shape[1] != entry.n_features:
            raise ValueError(
                f"request has {x.shape[1]} features; slot {slot!r} v"
                f"{entry.version} expects {entry.n_features}"
            )
        if x.max(initial=0) > 1:
            raise ValueError("features must be Boolean {0,1}")
        deadline = None
        if timeout_ms is not None:
            deadline = time.perf_counter() + timeout_ms / 1e3
        handle = RequestHandle(
            next(self._rid), slot, x.shape[0],
            priority=priority, deadline=deadline,
        )
        handle.driver = (
            "scheduler" if self.scheduler.running else "flush"
        )
        return handle, x

    def submit(
        self,
        slot: str,
        x: np.ndarray,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ) -> RequestHandle:
        """Queue {0,1}[b, F] (or [F]) datapoints against ``slot``.

        With a running scheduler the request is served by the loop (no
        flush() needed — block on ``handle.wait()`` or await
        ``handle.async_result()``); otherwise it waits for the next
        flush().  ``priority`` picks the lane, ``timeout_ms`` stamps a
        deadline after which the request is shed instead of served.

        ``enqueue`` is internally serialized against the scheduler
        loop's batch formation (the batcher lock), so callers may submit
        from any thread while the loop runs."""
        handle, x = self._make_handle(slot, x, priority, timeout_ms)
        self.batcher.enqueue(handle, x)
        if self.scheduler.running:
            self.scheduler.wake()
        return handle

    async def async_submit(
        self,
        slot: str,
        x: np.ndarray,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ) -> RequestHandle:
        """Admission-controlled submit for async callers.

        Raises the structured ``Overloaded`` when the (slot, lane) queue
        depth budget is exhausted — under sustained overload the low
        lanes reject first.  The depth check and the enqueue are one
        atomic section (batcher lock), so concurrent submitters cannot
        collectively exceed the lane budget.  Await the returned
        handle's ``async_result()`` for completion."""
        handle, xv = self._make_handle(slot, x, priority, timeout_ms)
        self.scheduler.admit_and_enqueue(handle, xv)
        if self.scheduler.running:
            self.scheduler.wake()
        return handle

    def flush(self) -> None:
        """Drain every slot's queue through the engine (the sync driver;
        a running scheduler loop makes this a no-op-ish safety valve —
        both drive the same scheduler batch body under one lock)."""
        self.scheduler.drain_all()

    def infer(self, slot: str, x: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit + drain -> int32[b] predictions."""
        handle = self.submit(slot, x)
        self.scheduler.drain_slot(slot)
        return handle.result()

    def class_sums(self, slot: str, x: np.ndarray) -> np.ndarray:
        """Direct (unbatched-queue) class sums for ``x`` — the oracle hook
        tests use for bit-exactness; does not touch the request queue."""
        entry = self.registry.get(slot)
        return self.executor.class_sums(entry.program, np.asarray(x, np.uint8))

    # -- the ServingNode boundary (what fleets/recal loops operate on) -------

    def slots(self) -> "list[str]":
        return self.registry.names()

    def validate_model(self, model) -> None:
        """The exact will-it-fit check this node's engine applies on
        install (raises ``CapacityExceeded``) — the node-boundary gate a
        publication/rollout runs so a passed artifact can never crash the
        hot-swap."""
        self.executor.validate_model(model)

    def queue_depth(
        self, slot: Optional[str] = None, priority: Optional[str] = None
    ) -> int:
        """Pending rows queued on this node (the router's load signal).
        ``slot``/``priority`` narrow the count; None sums everything."""
        if slot is not None:
            return self.batcher.pending_rows(slot, priority)
        return sum(
            self.batcher.pending_rows(s, priority)
            for s in self.batcher.pending_slots()
        )

    def metrics_snapshot(self) -> dict:
        """The per-lane ``ServeMetrics.summary()`` dict (schema pinned by
        serve_tm/schema.py) — what a fleet aggregates across nodes."""
        return self.metrics.summary()

    def installed_checksum(self, slot: str) -> Optional[int]:
        """CRC-32 of the artifact ``slot`` is running (None when the slot
        was programmed from a bare model rather than a ``TMProgram``).
        Rollout gating audits this against the shipped artifact."""
        artifact = self.registry.get(slot).artifact
        return None if artifact is None else artifact.checksum

    def installed_artifact(self, slot: str):
        """The ``TMProgram`` artifact ``slot`` is running, if it was
        installed from one (hot-slot replication re-ships it)."""
        return self.registry.get(slot).artifact

    # -- internals -----------------------------------------------------------

    def compile_cache_size(self) -> int:
        """# compiled variants of this server's engine (must stay 1)."""
        return self.executor.compile_cache_size()

    def _check_no_recompile(self) -> None:
        n = self.compile_cache_size()
        if n > 1:
            raise RuntimeError(
                f"engine recompiled: {n} compiled variants (expected 1) — "
                f"a model swap must be pure data movement"
            )
