"""Multi-tenant, dynamically-batched TM serving over the runtime-tunable
accelerator (the ROADMAP's "serve heavy traffic" north star applied to the
paper's Fig-4/Fig-8 engine).

    server = TMServer(CapacityPlan(...), backend="plan")
    server.register("gas", model)            # program a named slot
    h = server.submit("gas", x)              # queue {0,1}[b, F] datapoints
    server.flush()                           # batch + run + demux
    preds = h.result()

New deployments should prefer the ``repro.accel.Accelerator`` façade,
which negotiates capacity from the model population and adds the
portable ``TMProgram`` artifact path; ``TMServer`` remains the serving
core underneath it.  Engines come from the ``repro.accel`` plugin
registry: pass ``backend=<name>`` to pin one, a built engine via
``engine=``, or neither to auto-select the fastest eligible plugin.

Tenancy: each slot is one model; requests are batched PER SLOT (models
cannot share an engine pass) but all slots share the single compiled
engine — the multi-tenant generalization of the paper's one-engine-many-
models claim.  ``register`` on a live slot is the hot-swap/recalibration
path: queued traffic for that slot is drained under the OLD program first,
then the new model is installed; the engine is never recompiled, and
``flush`` asserts ``compile_cache_size() == 1`` after every drain.
``register`` also accepts a ``TMProgram`` artifact or its serialized
bytes (reprogram-over-the-wire).
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from ..accel.capacity import CapacityPlan
from ..accel.engine import EngineBase, make_engine, select_engine
from .batching import Batcher, RequestHandle
from .metrics import ServeMetrics
from .registry import DEFAULT_HISTORY_DEPTH, Installable, ModelRegistry, SlotEntry


class TMServer:
    def __init__(
        self,
        capacity: Optional[CapacityPlan] = None,
        backend: "Optional[str | EngineBase]" = None,
        mesh=None,
        *,
        engine: "Optional[str | EngineBase]" = None,
        engine_options: Optional[dict] = None,
        history_depth: int = DEFAULT_HISTORY_DEPTH,
    ):
        self.capacity = capacity if capacity is not None else CapacityPlan()
        chosen = engine if engine is not None else backend
        if chosen is None:
            chosen = select_engine(self.capacity, mesh=mesh)
        self.executor = make_engine(
            chosen, self.capacity, mesh=mesh, **(engine_options or {})
        )
        self.registry = ModelRegistry(
            self.executor, history_depth=history_depth
        )
        self.batcher = Batcher(self.capacity.batch_capacity)
        self.metrics = ServeMetrics()
        self._next_rid = 0

    # -- programming (the Fig-8 reprogram/recalibration path) ---------------

    def register(
        self,
        slot: str,
        model: Installable,
        provenance: str = "install",
    ) -> SlotEntry:
        """Install ``model`` into ``slot``; hot-swaps live slots.

        ``model`` may be a ``CompressedModel``, a ``TMProgram`` artifact,
        or artifact bytes fresh off the wire.  Traffic already queued for
        the slot is drained under the OLD program first (in-flight
        requests keep the model they were submitted against), then the
        swap is pure data movement.  ``provenance`` records who produced
        the model (e.g. the recal pipeline tags its swaps
        ``recal:<reason>``).
        """
        if slot in self.registry and self.batcher.pending_rows(slot):
            self._flush_slot(slot)
        t0 = time.perf_counter()
        entry = self.registry.install(slot, model, provenance=provenance)
        self.metrics.record_swap(time.perf_counter() - t0)
        return entry

    def rollback(self, slot: str) -> SlotEntry:
        """Reinstall ``slot``'s previous model (recal safety net).

        Same drain discipline as ``register``: queued traffic finishes
        under the CURRENT program, then the previous entry's programmed
        buffers are swapped back in verbatim.
        """
        if self.batcher.pending_rows(slot):
            self._flush_slot(slot)
        t0 = time.perf_counter()
        entry = self.registry.rollback(slot)
        self.metrics.record_swap(time.perf_counter() - t0)
        self.metrics.record_rollback()
        return entry

    # -- traffic -------------------------------------------------------------

    def submit(self, slot: str, x: np.ndarray) -> RequestHandle:
        """Queue {0,1}[b, F] (or [F]) datapoints against ``slot``."""
        entry = self.registry.get(slot)
        x = np.asarray(x, dtype=np.uint8)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[0] == 0:
            raise ValueError(f"expected {{0,1}}[b, F] features, got {x.shape}")
        if x.shape[1] != entry.n_features:
            raise ValueError(
                f"request has {x.shape[1]} features; slot {slot!r} v"
                f"{entry.version} expects {entry.n_features}"
            )
        if x.max(initial=0) > 1:
            raise ValueError("features must be Boolean {0,1}")
        handle = RequestHandle(self._next_rid, slot, x.shape[0])
        self._next_rid += 1
        self.batcher.enqueue(handle, x)
        return handle

    def flush(self) -> None:
        """Drain every slot's queue through the engine."""
        for slot in self.batcher.pending_slots():
            self._flush_slot(slot)

    def infer(self, slot: str, x: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit + flush -> int32[b] predictions."""
        handle = self.submit(slot, x)
        self._flush_slot(slot)
        return handle.result()

    def class_sums(self, slot: str, x: np.ndarray) -> np.ndarray:
        """Direct (unbatched-queue) class sums for ``x`` — the oracle hook
        tests use for bit-exactness; does not touch the request queue."""
        entry = self.registry.get(slot)
        return self.executor.class_sums(entry.program, np.asarray(x, np.uint8))

    # -- internals -----------------------------------------------------------

    def _flush_slot(self, slot: str) -> None:
        entry = self.registry.get(slot)
        while self.batcher.pending_rows(slot):
            # pack rows straight into the engine's staging array: the
            # flush path performs no per-batch feature allocation
            X, spans = self.batcher.next_batch(
                slot, out=self.executor.staging
            )
            t0 = time.perf_counter()
            sums = self.executor.class_sums(entry.program, X)
            dt = time.perf_counter() - t0
            preds = np.argmax(sums, axis=1).astype(np.int32)
            completed = Batcher.demux(spans, preds, sums)
            self.metrics.record_batch(
                X.shape[0], self.capacity.batch_capacity, dt, completed
            )
            for handle, _, _, _ in spans:
                if handle.done and handle.latency_s is not None:
                    self.metrics.record_request_latency(handle.latency_s)
        self._check_no_recompile()

    def compile_cache_size(self) -> int:
        """# compiled variants of this server's engine (must stay 1)."""
        return self.executor.compile_cache_size()

    def _check_no_recompile(self) -> None:
        n = self.compile_cache_size()
        if n > 1:
            raise RuntimeError(
                f"engine recompiled: {n} compiled variants (expected 1) — "
                f"a model swap must be pure data movement"
            )
