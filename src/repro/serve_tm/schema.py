"""The single source of truth for the ``ServeMetrics.summary()`` schema.

Three places render or validate this schema and used to drift silently:

  * ``ServeMetrics.summary()`` (serve_tm/metrics.py) builds the dict;
  * ``benchmarks/check_regression.py`` validates every per-backend
    summary inside ``BENCH_tm_serve.json`` against it;
  * the docs/accel.md "Serving metrics" table documents it for humans.

The golden-schema test (tests/test_api_and_schema.py) pins all three to
the constants below: ``summary()`` must produce EXACTLY these keys, the
regression gate must require them, and every key must appear in the docs
table.  Change the schema here first; the test tells you what else to
touch.

This module is deliberately import-free pure data: the regression gate
loads it by file path (no package init, no jax) so it stays runnable as
a standalone script.
"""

# priority lanes, in service order (batching.PRIORITIES re-exports this)
LANES = ("critical", "high", "normal", "low")

# top-level summary() keys
SUMMARY_KEYS = (
    "batches",
    "rows",
    "requests_completed",
    "swaps",
    "fill_ratio",
    "throughput_dps",
    "engine_us",
    "request_latency_us",
    "swap_us",
    "recals",
    "rollbacks",
    "recal_train_s",
    "recal_compress_s",
    "sheds",
    "admission_rejects",
    "deadline_misses",
    "retries",
    "failovers",
    "quarantines",
    "probes",
    "lanes",
)

# keys of each lanes.<lane> sub-dict
LANE_KEYS = (
    "completed",
    "shed",
    "rejected",
    "deadline_miss",
    "queue_delay_us",
    "latency_us",
    "slo_attainment",
)

# percentile sub-dicts: which keys carry {p50, p95, p99} vs {p50, p99}
PCT3_KEYS = ("engine_us", "request_latency_us", "swap_us",
             "recal_train_s", "recal_compress_s")
PCT2_KEYS = ("queue_delay_us", "latency_us")  # inside each lane

# keys of the fleet-level ServeMetrics.aggregate() dict (repro.fleet
# pools render this for BENCH_tm_fleet.json; validated the same way)
AGGREGATE_KEYS = (
    "nodes",
    "batches",
    "rows",
    "requests_completed",
    "swaps",
    "sheds",
    "admission_rejects",
    "deadline_misses",
    "retries",
    "failovers",
    "quarantines",
    "probes",
    "recals",
    "rollbacks",
    "throughput_dps",
    "fill_ratio",
    "lanes",
)

# keys of each aggregate lanes.<lane> sub-dict (counters only: node
# snapshots carry percentiles, which cannot be merged after the fact)
AGGREGATE_LANE_KEYS = (
    "completed",
    "shed",
    "rejected",
    "deadline_miss",
    "slo_attainment",
)

# fleet health: circuit-breaker states and the per-node dict
# fleet.FleetHealth.summary() renders (validated inside the chaos
# scenario of BENCH_tm_fleet.json; pinned by the golden-schema test)
HEALTH_STATES = ("healthy", "degraded", "quarantined", "half_open")

HEALTH_NODE_KEYS = (
    "state",
    "successes",
    "failures",
    "consecutive_failures",
    "error_rate",
    "retries",
    "failovers",
    "overloads",
    "quarantines",
    "probes",
)
