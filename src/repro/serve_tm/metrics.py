"""Latency/throughput instrumentation for the serving subsystem.

Counters are recorded per engine batch (rows served, capacity fill,
engine wall time), per completed request (queue-to-done latency), per
model swap, and — since the scheduler-owned continuous-batching runtime —
per priority LANE: queue-delay and end-to-end latency percentiles,
deadline misses (completed late), sheds (expired before service) and
admission rejects, plus SLO attainment.  ``summary()`` renders the
JSON-friendly dict that ``benchmarks/tm_serve.py`` emits into
BENCH_tm_serve.json.

``summary()`` schema (pinned by serve_tm/schema.py — the single source
of truth the golden-schema test, benchmarks/check_regression.py and the
docs/accel.md table are all held to):

  batches, rows, requests_completed, swaps      int counters
  fill_ratio                                    rows / padded engine rows
  throughput_dps                                rows / engine seconds
  engine_us / request_latency_us / swap_us      {p50, p95, p99}
  recals, rollbacks, recal_*_s                  Fig-8 loop counters
  sheds, admission_rejects, deadline_misses     totals across lanes
  retries, failovers, quarantines, probes       fleet health/retry path
                                                (a router records them on
                                                the node that finally
                                                served the request)
  lanes.<lane>.completed|shed|rejected|deadline_miss    int counters
  lanes.<lane>.queue_delay_us|latency_us        {p50, p99}
  lanes.<lane>.slo_attainment                   completed-in-deadline /
                                                (completed + shed); 1.0
                                                when nothing carried a
                                                deadline
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from .batching import PRIORITIES


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(xs)
    return {
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
    }


def _pcts2(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p99": 0.0}
    a = np.asarray(xs)
    return {
        "p50": float(np.percentile(a, 50)),
        "p99": float(np.percentile(a, 99)),
    }


class ServeMetrics:
    def __init__(self):
        self.batches = 0
        self.rows = 0            # real datapoints served
        self.padded_rows = 0     # engine rows incl. capacity padding
        self.requests_completed = 0
        self.swaps = 0
        self.recals = 0          # completed recalibration pipeline runs
        self.rollbacks = 0       # post-swap validation failures
        # fleet health/retry path (recorded by a fleet.Router, on the
        # node that finally served the request)
        self.retries = 0         # requests served only after backoff
        self.failovers = 0       # requests served after another node failed
        self.quarantines = 0     # circuit-breaker opened on this node
        self.probes = 0          # half-open probes admitted to this node
        self.engine_s: List[float] = []
        self.request_latency_s: List[float] = []
        self.swap_s: List[float] = []
        self.recal_train_s: List[float] = []
        self.recal_compress_s: List[float] = []
        # per-priority-lane accounting (the async front door)
        self.lane_completed = {p: 0 for p in PRIORITIES}
        self.lane_shed = {p: 0 for p in PRIORITIES}
        self.lane_rejected = {p: 0 for p in PRIORITIES}
        self.lane_deadline_miss = {p: 0 for p in PRIORITIES}
        self.lane_in_slo = {p: 0 for p in PRIORITIES}
        self.lane_queue_delay_s = {p: [] for p in PRIORITIES}
        self.lane_latency_s = {p: [] for p in PRIORITIES}

    def record_batch(
        self, rows: int, capacity: int, elapsed_s: float, completed: int
    ) -> None:
        self.batches += 1
        self.rows += rows
        self.padded_rows += capacity
        self.engine_s.append(elapsed_s)
        self.requests_completed += completed

    def record_request_latency(self, latency_s: float) -> None:
        self.request_latency_s.append(latency_s)

    def record_lane_completion(
        self,
        lane: str,
        queue_delay_s: float,
        latency_s: float,
        missed: bool = False,
    ) -> None:
        """One request finished in ``lane``; ``missed`` marks a request
        that completed but AFTER its deadline (served-late SLO miss, as
        opposed to a shed, which never got served at all)."""
        self.lane_completed[lane] += 1
        self.lane_queue_delay_s[lane].append(queue_delay_s)
        self.lane_latency_s[lane].append(latency_s)
        if missed:
            self.lane_deadline_miss[lane] += 1
        else:
            self.lane_in_slo[lane] += 1

    def record_shed(self, lane: str) -> None:
        """A queued request expired (deadline passed) before service."""
        self.lane_shed[lane] += 1

    def record_admission_reject(self, lane: str) -> None:
        """Admission control refused a submit (lane queue depth full)."""
        self.lane_rejected[lane] += 1

    def record_swap(self, elapsed_s: float) -> None:
        self.swaps += 1
        self.swap_s.append(elapsed_s)

    def record_recal(self, train_s: float, compress_s: float) -> None:
        """One completed recalibration (train + compress + publish)."""
        self.recals += 1
        self.recal_train_s.append(train_s)
        self.recal_compress_s.append(compress_s)

    def record_rollback(self) -> None:
        self.rollbacks += 1

    def record_retry(self) -> None:
        """A request landed here only after at least one backoff sweep."""
        self.retries += 1

    def record_failover(self) -> None:
        """A request landed here after another node failed it first."""
        self.failovers += 1

    def record_quarantine(self) -> None:
        """The fleet circuit breaker quarantined this node."""
        self.quarantines += 1

    def record_probe(self) -> None:
        """A half-open probe request was admitted to this node."""
        self.probes += 1

    def _lane_summary(self, lane: str) -> Dict:
        completed = self.lane_completed[lane]
        shed = self.lane_shed[lane]
        terminal = completed + shed
        return {
            "completed": completed,
            "shed": shed,
            "rejected": self.lane_rejected[lane],
            "deadline_miss": self.lane_deadline_miss[lane],
            "queue_delay_us": {
                k: v * 1e6
                for k, v in _pcts2(self.lane_queue_delay_s[lane]).items()
            },
            "latency_us": {
                k: v * 1e6
                for k, v in _pcts2(self.lane_latency_s[lane]).items()
            },
            # served within deadline (no deadline counts as attained)
            # over everything that reached a terminal state
            "slo_attainment": (
                self.lane_in_slo[lane] / terminal if terminal else 1.0
            ),
        }

    @classmethod
    def aggregate(cls, snapshots: "List[Dict]") -> Dict:
        """Fleet-level rollup of per-node ``summary()`` snapshots (the
        ``ServingNode.metrics_snapshot()`` dicts a pool collects).

        Counters sum across nodes.  ``throughput_dps`` is the fleet's
        aggregate serving capacity: nodes execute in PARALLEL (each is
        its own accelerator), so the fleet rate is the SUM of per-node
        rates (rows_i / engine_seconds_i), not total-rows over
        total-engine-seconds — the latter would model nodes taking
        turns.  Per-node engine seconds are recovered from each
        snapshot's own rows/throughput ratio.  Percentiles are NOT
        merged (they can't be, from summaries); read them per node.
        Schema pinned as ``AGGREGATE_KEYS`` in serve_tm/schema.py."""
        agg: Dict = {"nodes": len(snapshots)}
        for key in ("batches", "rows", "requests_completed", "swaps",
                    "sheds", "admission_rejects", "deadline_misses",
                    "retries", "failovers", "quarantines", "probes",
                    "recals", "rollbacks"):
            agg[key] = sum(int(s[key]) for s in snapshots)
        agg["throughput_dps"] = float(sum(
            s["throughput_dps"] for s in snapshots
        ))
        padded = sum(
            s["rows"] / s["fill_ratio"] for s in snapshots
            if s["fill_ratio"] > 0
        )
        agg["fill_ratio"] = agg["rows"] / padded if padded else 0.0
        lanes: Dict = {}
        for lane in PRIORITIES:
            stats = [s["lanes"][lane] for s in snapshots]
            completed = sum(t["completed"] for t in stats)
            shed = sum(t["shed"] for t in stats)
            in_slo = sum(
                round(t["slo_attainment"] * (t["completed"] + t["shed"]))
                for t in stats
            )
            lanes[lane] = {
                "completed": completed,
                "shed": shed,
                "rejected": sum(t["rejected"] for t in stats),
                "deadline_miss": sum(t["deadline_miss"] for t in stats),
                "slo_attainment": (
                    in_slo / (completed + shed) if completed + shed else 1.0
                ),
            }
        agg["lanes"] = lanes
        return agg

    def summary(self) -> Dict:
        engine_total = sum(self.engine_s)
        return {
            "batches": self.batches,
            "rows": self.rows,
            "requests_completed": self.requests_completed,
            "swaps": self.swaps,
            "fill_ratio": (
                self.rows / self.padded_rows if self.padded_rows else 0.0
            ),
            "throughput_dps": (
                self.rows / engine_total if engine_total > 0 else 0.0
            ),
            "engine_us": {
                k: v * 1e6 for k, v in _pcts(self.engine_s).items()
            },
            "request_latency_us": {
                k: v * 1e6 for k, v in _pcts(self.request_latency_s).items()
            },
            "swap_us": {k: v * 1e6 for k, v in _pcts(self.swap_s).items()},
            "recals": self.recals,
            "rollbacks": self.rollbacks,
            "recal_train_s": {
                k: float(v) for k, v in _pcts(self.recal_train_s).items()
            },
            "recal_compress_s": {
                k: float(v) for k, v in _pcts(self.recal_compress_s).items()
            },
            "sheds": sum(self.lane_shed.values()),
            "admission_rejects": sum(self.lane_rejected.values()),
            "deadline_misses": sum(self.lane_deadline_miss.values()),
            "retries": self.retries,
            "failovers": self.failovers,
            "quarantines": self.quarantines,
            "probes": self.probes,
            "lanes": {p: self._lane_summary(p) for p in PRIORITIES},
        }
