"""Latency/throughput instrumentation for the serving subsystem.

Counters are recorded per engine batch (rows served, capacity fill,
engine wall time), per completed request (queue-to-done latency) and per
model swap.  ``summary()`` renders the JSON-friendly dict that
``benchmarks/tm_serve.py`` emits into BENCH_tm_serve.json.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np


def _pcts(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(xs)
    return {
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
    }


class ServeMetrics:
    def __init__(self):
        self.batches = 0
        self.rows = 0            # real datapoints served
        self.padded_rows = 0     # engine rows incl. capacity padding
        self.requests_completed = 0
        self.swaps = 0
        self.recals = 0          # completed recalibration pipeline runs
        self.rollbacks = 0       # post-swap validation failures
        self.engine_s: List[float] = []
        self.request_latency_s: List[float] = []
        self.swap_s: List[float] = []
        self.recal_train_s: List[float] = []
        self.recal_compress_s: List[float] = []

    def record_batch(
        self, rows: int, capacity: int, elapsed_s: float, completed: int
    ) -> None:
        self.batches += 1
        self.rows += rows
        self.padded_rows += capacity
        self.engine_s.append(elapsed_s)
        self.requests_completed += completed

    def record_request_latency(self, latency_s: float) -> None:
        self.request_latency_s.append(latency_s)

    def record_swap(self, elapsed_s: float) -> None:
        self.swaps += 1
        self.swap_s.append(elapsed_s)

    def record_recal(self, train_s: float, compress_s: float) -> None:
        """One completed recalibration (train + compress + publish)."""
        self.recals += 1
        self.recal_train_s.append(train_s)
        self.recal_compress_s.append(compress_s)

    def record_rollback(self) -> None:
        self.rollbacks += 1

    def summary(self) -> Dict:
        engine_total = sum(self.engine_s)
        return {
            "batches": self.batches,
            "rows": self.rows,
            "requests_completed": self.requests_completed,
            "swaps": self.swaps,
            "fill_ratio": (
                self.rows / self.padded_rows if self.padded_rows else 0.0
            ),
            "throughput_dps": (
                self.rows / engine_total if engine_total > 0 else 0.0
            ),
            "engine_us": {
                k: v * 1e6 for k, v in _pcts(self.engine_s).items()
            },
            "request_latency_us": {
                k: v * 1e6 for k, v in _pcts(self.request_latency_s).items()
            },
            "swap_us": {k: v * 1e6 for k, v in _pcts(self.swap_s).items()},
            "recals": self.recals,
            "rollbacks": self.rollbacks,
            "recal_train_s": {
                k: float(v) for k, v in _pcts(self.recal_train_s).items()
            },
            "recal_compress_s": {
                k: float(v) for k, v in _pcts(self.recal_compress_s).items()
            },
        }
