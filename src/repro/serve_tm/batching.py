"""Request queue + dynamic batcher for the TM serving subsystem.

Independent inference requests (each a {0,1}[b, F] block of datapoints for
one model slot) are coalesced into engine batches of at most
``batch_capacity`` rows — the 32-datapoint bit-packed words the engine
natively consumes.  A partial trailing word is padded inside the engine
(``pack_features``); here we only track the fill ratio.  Large requests
transparently span multiple engine batches; predictions are demultiplexed
back into each request's ``RequestHandle`` row by row.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

WORD = 32  # datapoints per bit-packed word (paper batching)


class RequestHandle:
    """Per-request future: filled row-by-row as engine batches complete."""

    def __init__(self, rid: int, slot: str, n_rows: int):
        self.rid = rid
        self.slot = slot
        self.n_rows = n_rows
        self.predictions = np.full(n_rows, -1, np.int32)
        self.class_sums: Optional[np.ndarray] = None  # int32[n_rows, M]
        self.enqueued_at = time.perf_counter()
        self.completed_at: Optional[float] = None
        self._filled = 0

    @property
    def done(self) -> bool:
        return self._filled >= self.n_rows

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.enqueued_at

    def result(self) -> np.ndarray:
        if not self.done:
            raise RuntimeError(
                f"request {self.rid} has {self.n_rows - self._filled} rows "
                f"pending; call TMServer.flush() first"
            )
        return self.predictions

    def _fill(
        self, lo: int, preds: np.ndarray, sums: Optional[np.ndarray] = None
    ) -> None:
        self.predictions[lo : lo + preds.shape[0]] = preds
        if sums is not None:
            if self.class_sums is None:
                self.class_sums = np.zeros(
                    (self.n_rows, sums.shape[1]), sums.dtype
                )
            self.class_sums[lo : lo + sums.shape[0]] = sums
        self._filled += preds.shape[0]
        if self.done:
            self.completed_at = time.perf_counter()


class _Pending:
    """A queued request plus its consumption offset (requests larger than
    one engine batch are drained incrementally)."""

    __slots__ = ("handle", "x", "offset")

    def __init__(self, handle: RequestHandle, x: np.ndarray):
        self.handle = handle
        self.x = x
        self.offset = 0

    @property
    def remaining(self) -> int:
        return self.x.shape[0] - self.offset


# (handle, batch_lo, batch_hi, request_lo): rows [lo, hi) of the engine
# batch belong to rows [request_lo, ...) of the request.
Span = Tuple[RequestHandle, int, int, int]


class Batcher:
    """Per-slot FIFO queues + greedy coalescing into engine batches."""

    def __init__(self, batch_capacity: int):
        if batch_capacity % WORD != 0:
            raise ValueError(
                f"batch_capacity {batch_capacity} must be a multiple of "
                f"{WORD} (bit-packed words)"
            )
        self.batch_capacity = batch_capacity
        self._queues: Dict[str, Deque[_Pending]] = {}

    def enqueue(self, handle: RequestHandle, x: np.ndarray) -> None:
        self._queues.setdefault(handle.slot, deque()).append(
            _Pending(handle, x)
        )

    def pending_slots(self) -> List[str]:
        return [s for s, q in self._queues.items() if q]

    def pending_rows(self, slot: str) -> int:
        return sum(p.remaining for p in self._queues.get(slot, ()))

    def next_batch(
        self, slot: str, out: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, List[Span]]:
        """Pop up to ``batch_capacity`` rows off the slot's queue.

        Returns the coalesced feature block plus the spans needed to demux
        predictions back per-request.  Raises on an empty queue.

        With ``out`` (an engine staging array of at least
        ``[batch_capacity, F]``), request rows are packed straight into it
        — no per-batch concatenate/allocation — the remainder of ``out``
        is zeroed (the engines consume one fixed zero-padded operand
        shape), and the returned block is the view ``out[:rows, :F]``.
        """
        q = self._queues.get(slot)
        if not q:
            raise ValueError(f"no pending requests for slot {slot!r}")
        n_features = q[0].x.shape[1]
        if out is not None:
            if (out.shape[0] < self.batch_capacity
                    or out.shape[1] < n_features):
                raise ValueError(
                    f"staging array {out.shape} too small for "
                    f"{self.batch_capacity} rows x {n_features} features"
                )
            out.fill(0)
        parts: List[np.ndarray] = []
        spans: List[Span] = []
        rows = 0
        while q and rows < self.batch_capacity:
            p = q[0]
            take = min(p.remaining, self.batch_capacity - rows)
            block = p.x[p.offset : p.offset + take]
            if out is None:
                parts.append(block)
            else:
                out[rows : rows + take, :n_features] = block
            spans.append((p.handle, rows, rows + take, p.offset))
            rows += take
            p.offset += take
            if p.remaining == 0:
                q.popleft()
        if out is not None:
            return out[:rows, :n_features], spans
        return np.concatenate(parts, axis=0), spans

    @staticmethod
    def demux(
        spans: List[Span],
        preds: np.ndarray,
        sums: Optional[np.ndarray] = None,
    ) -> int:
        """Scatter engine predictions (and, when given, the class-sum rows
        the drift monitor taps) back into the request handles.  Returns how
        many requests COMPLETED with this batch."""
        completed = 0
        for handle, lo, hi, req_lo in spans:
            handle._fill(
                req_lo, preds[lo:hi], None if sums is None else sums[lo:hi]
            )
            if handle.done:
                completed += 1
        return completed
