"""Request queue + priority-lane dynamic batcher for the TM serving
subsystem.

Independent inference requests (each a {0,1}[b, F] block of datapoints for
one model slot) are coalesced into engine batches of at most
``batch_capacity`` rows — the 32-datapoint bit-packed words the engine
natively consumes.  A partial trailing word is padded inside the engine
(``pack_features``); here we only track the fill ratio.  Large requests
transparently span multiple engine batches; predictions are demultiplexed
back into each request's ``RequestHandle`` row by row.

Requests carry a *priority* (one of ``PRIORITIES``: critical > high >
normal > low) and an optional absolute *deadline*.  Each slot keeps one
lane per priority; batch formation walks the lanes strictly in priority
order and, within a lane, earliest-deadline-first (deadline-less requests
are FIFO behind every deadlined one with an earlier stamp).  A request
whose deadline has already passed is never placed into a batch — it is
*shed*: moved to the ``expired`` terminal state and reported through
``drain_shed`` so the scheduler can count it.

``RequestHandle`` completion is observable three ways: the non-blocking
``result()`` (raises while pending), the blocking ``wait(timeout=)``, and
the awaitable ``async_result()`` — the scheduler loop completes handles
from its own thread and signals waiters on whatever event loop they
registered from.
"""

from __future__ import annotations

import asyncio
import heapq
import math
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

WORD = 32  # datapoints per bit-packed word (paper batching)

# service order: batch formation drains lanes left to right (the lane
# list itself lives in schema.py — the summary()-schema source of truth)
from .schema import LANES as PRIORITIES  # noqa: E402

PRIORITY_RANK = {p: i for i, p in enumerate(PRIORITIES)}


class DeadlineExceeded(RuntimeError):
    """A request expired (deadline passed) before its rows were served.

    Carries the request id, slot, priority and the deadline that was
    missed, so callers can log/shed without string parsing."""

    def __init__(self, rid: int, slot: str, priority: str, deadline: float):
        self.rid = rid
        self.slot = slot
        self.priority = priority
        self.deadline = deadline
        super().__init__(
            f"request {rid} (slot {slot!r}, {priority} lane) expired: "
            f"deadline passed before its rows were served"
        )


class RequestHandle:
    """Per-request future: filled row-by-row as engine batches complete.

    Terminal states: ``done`` (all rows served), ``expired`` (the
    scheduler shed it past its deadline) or ``failed`` (the batch body
    raised, or the node serving it died — ``error`` carries the
    structured exception and ``result()``/``wait()``/``async_result()``
    re-raise it).  ``driver`` records who owns
    completion — ``"flush"`` (the caller-driven sync path) or
    ``"scheduler"`` (a running continuous-batching loop) — so the
    pending-result error can say what to actually do.
    """

    def __init__(
        self,
        rid: int,
        slot: str,
        n_rows: int,
        priority: str = "normal",
        deadline: Optional[float] = None,
    ):
        if priority not in PRIORITY_RANK:
            raise ValueError(
                f"unknown priority {priority!r}; expected one of {PRIORITIES}"
            )
        self.rid = rid
        self.slot = slot
        self.n_rows = n_rows
        self.priority = priority
        self.deadline = deadline  # absolute time.perf_counter() stamp
        self.driver = "flush"
        self.predictions = np.full(n_rows, -1, np.int32)
        self.class_sums: Optional[np.ndarray] = None  # int32[n_rows, M]
        self.enqueued_at = time.perf_counter()
        self.dequeued_at: Optional[float] = None  # first rows entered a batch
        self.completed_at: Optional[float] = None
        self.expired_at: Optional[float] = None
        self.failed_at: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._filled = 0
        self._lock = threading.Lock()
        self._terminal_evt = threading.Event()
        self._async_waiters: List[Tuple[asyncio.AbstractEventLoop,
                                        asyncio.Event]] = []

    @property
    def done(self) -> bool:
        return self._filled >= self.n_rows

    @property
    def expired(self) -> bool:
        return self.expired_at is not None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def status(self) -> str:
        if self.failed:
            return "failed"
        if self.expired:
            return "expired"
        return "done" if self.done else "pending"

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.enqueued_at

    @property
    def queue_delay_s(self) -> Optional[float]:
        """Enqueue -> first rows placed into an engine batch."""
        if self.dequeued_at is None:
            return None
        return self.dequeued_at - self.enqueued_at

    @property
    def missed_deadline(self) -> bool:
        """Completed, but after the deadline (served-late SLO miss)."""
        return (
            self.deadline is not None
            and self.completed_at is not None
            and self.completed_at > self.deadline
        )

    def result(self) -> np.ndarray:
        if self.failed:
            raise self.error
        if self.expired:
            raise DeadlineExceeded(
                self.rid, self.slot, self.priority, self.deadline
            )
        if not self.done:
            if self.driver == "scheduler":
                remedy = (
                    "the scheduler loop owns it — await async_result() "
                    "or block on wait()"
                )
            else:
                remedy = "call TMServer.flush() to run the sync driver"
            raise RuntimeError(
                f"request {self.rid} for slot {self.slot!r} has "
                f"{self.n_rows - self._filled} rows pending; {remedy}"
            )
        return self.predictions

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until terminal (a running scheduler completes or sheds
        the request from its own thread), then return ``result()``."""
        if not self._terminal_evt.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} (slot {self.slot!r}) still pending "
                f"after {timeout}s"
            )
        return self.result()

    async def async_result(
        self, timeout: Optional[float] = None
    ) -> np.ndarray:
        """Await completion; raises ``DeadlineExceeded`` if shed."""
        with self._lock:
            if not self._terminal_evt.is_set():
                loop = asyncio.get_running_loop()
                evt = asyncio.Event()
                self._async_waiters.append((loop, evt))
            else:
                evt = None
        if evt is not None:
            if timeout is None:
                await evt.wait()
            else:
                await asyncio.wait_for(evt.wait(), timeout)
        return self.result()

    def _signal_terminal(self) -> None:
        with self._lock:
            self._terminal_evt.set()
            waiters, self._async_waiters = self._async_waiters, []
        for loop, evt in waiters:
            try:
                loop.call_soon_threadsafe(evt.set)
            except RuntimeError:
                pass  # waiter's loop already closed; nothing to notify

    def _fill(
        self, lo: int, preds: np.ndarray, sums: Optional[np.ndarray] = None
    ) -> None:
        self.predictions[lo : lo + preds.shape[0]] = preds
        if sums is not None:
            if self.class_sums is None:
                self.class_sums = np.zeros(
                    (self.n_rows, sums.shape[1]), sums.dtype
                )
            self.class_sums[lo : lo + sums.shape[0]] = sums
        self._filled += preds.shape[0]
        if self.done:
            self.completed_at = time.perf_counter()
            self._signal_terminal()

    def _expire(self, now: float) -> None:
        self.expired_at = now
        self._signal_terminal()

    def _fail(self, exc: BaseException, now: Optional[float] = None) -> None:
        """Terminal failure: the batch body raised or the serving node
        died.  Waiters unblock and re-raise ``exc`` from ``result()``."""
        if self._terminal_evt.is_set():
            return  # already terminal — never overwrite a served result
        self.error = exc
        self.failed_at = time.perf_counter() if now is None else now
        self._signal_terminal()


class _Pending:
    """A queued request plus its consumption offset (requests larger than
    one engine batch are drained incrementally)."""

    __slots__ = ("handle", "x", "offset")

    def __init__(self, handle: RequestHandle, x: np.ndarray):
        self.handle = handle
        self.x = x
        self.offset = 0

    @property
    def remaining(self) -> int:
        return self.x.shape[0] - self.offset


# (handle, batch_lo, batch_hi, request_lo): rows [lo, hi) of the engine
# batch belong to rows [request_lo, ...) of the request.
Span = Tuple[RequestHandle, int, int, int]

# heap entry: (deadline-or-inf, arrival seq, pending) — EDF within a lane,
# FIFO among deadline-less requests
_LaneEntry = Tuple[float, int, _Pending]


class Batcher:
    """Per-slot priority lanes + greedy coalescing into engine batches.

    Lanes are drained strictly in ``PRIORITIES`` order; within a lane the
    earliest deadline wins (FIFO for deadline-less requests).  Expired
    requests are shed at formation time, never batched.

    ``lock`` serializes every heap read/mutation: submit-side enqueues
    run on caller threads while the scheduler loop forms batches on its
    own thread, and heapq's peek-then-pop is not atomic — without the
    lock a concurrent push can re-order the heap root mid-formation and
    the wrong request gets popped (silently dropped, its handle never
    terminal).  The lock is re-entrant so the scheduler can compose
    multi-step atomic sections (admission check + enqueue) on top of the
    self-locking public methods.
    """

    def __init__(self, batch_capacity: int):
        if batch_capacity % WORD != 0:
            raise ValueError(
                f"batch_capacity {batch_capacity} must be a multiple of "
                f"{WORD} (bit-packed words)"
            )
        self.batch_capacity = batch_capacity
        self.lock = threading.RLock()
        # slot -> priority -> EDF heap of pending requests
        self._lanes: Dict[str, Dict[str, List[_LaneEntry]]] = {}
        self._seq = 0
        self._shed: List[RequestHandle] = []

    def _slot_lanes(self, slot: str) -> Dict[str, List[_LaneEntry]]:
        return self._lanes.setdefault(
            slot, {p: [] for p in PRIORITIES}
        )

    def enqueue(self, handle: RequestHandle, x: np.ndarray) -> None:
        key = math.inf if handle.deadline is None else handle.deadline
        with self.lock:
            self._seq += 1
            heapq.heappush(
                self._slot_lanes(handle.slot)[handle.priority],
                (key, self._seq, _Pending(handle, x)),
            )

    def pending_slots(self) -> List[str]:
        with self.lock:
            return [
                s for s, lanes in self._lanes.items()
                if any(lanes[p] for p in PRIORITIES)
            ]

    def pending_rows(self, slot: str, priority: Optional[str] = None) -> int:
        with self.lock:
            lanes = self._lanes.get(slot)
            if not lanes:
                return 0
            sel = (priority,) if priority is not None else PRIORITIES
            return sum(
                e[2].remaining for p in sel for e in lanes.get(p, ())
            )

    def oldest_enqueued_at(self, slot: str) -> Optional[float]:
        """Enqueue stamp of the oldest pending request (batching-window
        age the scheduler's max_wait timer is measured against)."""
        with self.lock:
            lanes = self._lanes.get(slot)
            if not lanes:
                return None
            stamps = [
                e[2].handle.enqueued_at
                for p in PRIORITIES for e in lanes.get(p, ())
            ]
            return min(stamps) if stamps else None

    def earliest_deadline(self, slot: str) -> Optional[float]:
        with self.lock:
            lanes = self._lanes.get(slot)
            if not lanes:
                return None
            best = math.inf
            for p in PRIORITIES:
                if lanes[p]:
                    best = min(best, lanes[p][0][0])
            return None if best is math.inf else best

    def next_batch(
        self,
        slot: str,
        out: Optional[np.ndarray] = None,
        now: Optional[float] = None,
    ) -> Tuple[np.ndarray, List[Span]]:
        """Pop up to ``batch_capacity`` rows off the slot's lanes.

        Lanes are consumed in strict priority order; within a lane,
        earliest deadline first.  Requests whose deadline has passed (vs
        ``now``, injectable for tests) are shed — marked expired,
        reported via ``drain_shed`` — and NEVER included.  Returns the
        coalesced feature block plus the spans needed to demux
        predictions back per-request; raises on an empty queue (a batch
        where every queued request expired returns an empty block and no
        spans).

        With ``out`` (an engine staging array of at least
        ``[batch_capacity, F]``), request rows are packed straight into it
        — no per-batch concatenate/allocation — the remainder of ``out``
        is zeroed (the engines consume one fixed zero-padded operand
        shape), and the returned block is the view ``out[:rows, :F]``.
        """
        with self.lock:
            lanes = self._lanes.get(slot)
            if not lanes or not any(lanes[p] for p in PRIORITIES):
                raise ValueError(f"no pending requests for slot {slot!r}")
            if now is None:
                now = time.perf_counter()
            n_features = 0
            for p in PRIORITIES:
                if lanes[p]:
                    n_features = lanes[p][0][2].x.shape[1]
                    break
            if out is not None:
                if (out.shape[0] < self.batch_capacity
                        or out.shape[1] < n_features):
                    raise ValueError(
                        f"staging array {out.shape} too small for "
                        f"{self.batch_capacity} rows x {n_features} features"
                    )
                out.fill(0)
            parts: List[np.ndarray] = []
            spans: List[Span] = []
            rows = 0
            for priority in PRIORITIES:
                lane = lanes[priority]
                while lane and rows < self.batch_capacity:
                    key, seq, p = lane[0]
                    if key <= now:  # deadline passed: shed, never batch
                        heapq.heappop(lane)
                        p.handle._expire(now)
                        self._shed.append(p.handle)
                        continue
                    take = min(p.remaining, self.batch_capacity - rows)
                    block = p.x[p.offset : p.offset + take]
                    if out is None:
                        parts.append(block)
                    else:
                        out[rows : rows + take, :n_features] = block
                    if p.handle.dequeued_at is None:
                        p.handle.dequeued_at = now
                    spans.append((p.handle, rows, rows + take, p.offset))
                    rows += take
                    p.offset += take
                    if p.remaining == 0:
                        heapq.heappop(lane)
                if rows >= self.batch_capacity:
                    break
            if not spans:  # everything queued had expired
                empty = np.empty((0, n_features), np.uint8)
                return (
                    out[:0, :n_features] if out is not None else empty
                ), []
            if out is not None:
                return out[:rows, :n_features], spans
            return np.concatenate(parts, axis=0), spans

    def drain_shed(self) -> List[RequestHandle]:
        """Handles shed (expired) since the last call — the scheduler
        feeds these into the per-lane shed counters."""
        with self.lock:
            shed, self._shed = self._shed, []
        return shed

    @staticmethod
    def demux(
        spans: List[Span],
        preds: np.ndarray,
        sums: Optional[np.ndarray] = None,
    ) -> int:
        """Scatter engine predictions (and, when given, the class-sum rows
        the drift monitor taps) back into the request handles.  Returns how
        many requests COMPLETED with this batch."""
        completed = 0
        for handle, lo, hi, req_lo in spans:
            handle._fill(
                req_lo, preds[lo:hi], None if sums is None else sums[lo:hi]
            )
            if handle.done:
                completed += 1
        return completed
