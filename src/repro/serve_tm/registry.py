"""Named model slots with hot-swap — the Fig-8 reprogram step as an API.

A slot holds one programmed model (the engine's fixed-capacity buffers).
Installing into an existing slot is the runtime recalibration path: pure
data movement, version bump, no recompilation (the server asserts the
engine's compile cache stays at 1).

``install`` accepts a bare ``CompressedModel``, a ``TMProgram`` artifact,
or the artifact's raw ``to_bytes()`` blob — the reprogram-over-the-wire
path: a training node ships bytes, the serving node integrity-checks and
installs them, and the slot entry records which artifact (checksum and
capacity stamp) it is running.

Every install records *provenance* (who produced the model: initial
deploy, a recal pipeline, a rollback) and the previous entries are kept in
a bounded per-slot history (depth is a constructor argument), so the recal
controller can roll a bad swap back WITHOUT re-programming: the old
entry's buffers are still alive and are reinstalled as-is.  A rollback's
provenance nests the restored entry's own provenance, so a
rollback-of-a-rollback reads as the full chain, e.g.
``rollback:v4->v3(rollback:v2->v1(deploy))``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

from ..accel.program import TMProgram
from ..core.compress import CompressedModel

# default retained previous versions per slot (override per registry)
DEFAULT_HISTORY_DEPTH = 4

Installable = Union[CompressedModel, TMProgram, bytes]


@dataclasses.dataclass
class SlotEntry:
    name: str
    model: CompressedModel
    program: Any  # backend-specific fixed-capacity buffers
    version: int
    installed_at: float
    provenance: str = "install"
    artifact: Optional[TMProgram] = None  # set when installed from one

    @property
    def n_classes(self) -> int:
        return self.model.n_classes

    @property
    def n_features(self) -> int:
        return self.model.n_features


class ModelRegistry:
    """slot name -> programmed model, for one engine."""

    def __init__(self, executor, history_depth: int = DEFAULT_HISTORY_DEPTH):
        if history_depth < 1:
            raise ValueError(
                f"history_depth must be >= 1 (rollback needs at least one "
                f"retained version), got {history_depth}"
            )
        self._executor = executor
        self.history_depth = history_depth
        self._slots: Dict[str, SlotEntry] = {}
        self._history: Dict[str, List[SlotEntry]] = {}

    def install(
        self, name: str, model: Installable, provenance: str = "install"
    ) -> SlotEntry:
        """Program ``model`` into ``name`` (create or hot-swap).

        ``model`` may be a ``TMProgram`` artifact or its serialized bytes
        (integrity-checked by ``TMProgram.from_bytes``); the underlying
        ``CompressedModel`` is what gets programmed.
        """
        artifact: Optional[TMProgram] = None
        if isinstance(model, (bytes, bytearray, memoryview)):
            model = TMProgram.from_bytes(model)
        if isinstance(model, TMProgram):
            artifact = model
            model = artifact.model
        prev = self._slots.get(name)
        entry = SlotEntry(
            name=name,
            model=model,
            program=self._executor.program(model),
            version=(prev.version + 1) if prev else 1,
            installed_at=time.time(),
            provenance=provenance,
            artifact=artifact,
        )
        if prev is not None:
            self._push_history(name, prev)
        self._slots[name] = entry
        return entry

    def rollback(self, name: str) -> SlotEntry:
        """Reinstall the slot's previous model (the recal safety net).

        Pure data movement squared: the previous entry's programmed
        buffers are reused verbatim — no decode, no reprogram.  The
        version still advances monotonically so observers can tell a
        rollback from time going backwards, and the provenance nests the
        restored entry's own provenance (the full chain survives repeated
        rollbacks).
        """
        hist = self._history.get(name)
        if not hist:
            raise KeyError(
                f"slot {name!r} has no previous version to roll back to"
            )
        prev = hist.pop()
        cur = self.get(name)
        entry = SlotEntry(
            name=name,
            model=prev.model,
            program=prev.program,
            version=cur.version + 1,
            installed_at=time.time(),
            provenance=(
                f"rollback:v{cur.version}->v{prev.version}"
                f"({prev.provenance})"
            ),
            artifact=prev.artifact,
        )
        self._push_history(name, cur)
        self._slots[name] = entry
        return entry

    def _push_history(self, name: str, entry: SlotEntry) -> None:
        hist = self._history.setdefault(name, [])
        hist.append(entry)
        del hist[: -self.history_depth]

    def previous(self, name: str) -> Optional[SlotEntry]:
        """The entry a ``rollback(name)`` would reinstall (None if none)."""
        hist = self._history.get(name)
        return hist[-1] if hist else None

    def history(self, name: str) -> List[SlotEntry]:
        """Retained previous entries, oldest first (excludes the live one)."""
        return list(self._history.get(name, ()))

    def get(self, name: str) -> SlotEntry:
        if name not in self._slots:
            raise KeyError(
                f"no model registered in slot {name!r}; call "
                f"TMServer.register({name!r}, model) first "
                f"(known slots: {sorted(self._slots) or 'none'})"
            )
        return self._slots[name]

    def names(self) -> List[str]:
        return sorted(self._slots)

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    def __len__(self) -> int:
        return len(self._slots)
