"""Named model slots with hot-swap — the Fig-8 reprogram step as an API.

A slot holds one programmed model (the executor backend's fixed-capacity
buffers).  Installing into an existing slot is the runtime recalibration
path: pure data movement, version bump, no recompilation (the server
asserts the executor's compile cache stays at 1).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

from ..core.compress import CompressedModel


@dataclasses.dataclass
class SlotEntry:
    name: str
    model: CompressedModel
    program: Any  # backend-specific fixed-capacity buffers
    version: int
    installed_at: float

    @property
    def n_classes(self) -> int:
        return self.model.n_classes

    @property
    def n_features(self) -> int:
        return self.model.n_features


class ModelRegistry:
    """slot name -> programmed model, for one executor backend."""

    def __init__(self, executor):
        self._executor = executor
        self._slots: Dict[str, SlotEntry] = {}

    def install(self, name: str, model: CompressedModel) -> SlotEntry:
        """Program ``model`` into ``name`` (create or hot-swap)."""
        prev = self._slots.get(name)
        entry = SlotEntry(
            name=name,
            model=model,
            program=self._executor.program(model),
            version=(prev.version + 1) if prev else 1,
            installed_at=time.time(),
        )
        self._slots[name] = entry
        return entry

    def get(self, name: str) -> SlotEntry:
        if name not in self._slots:
            raise KeyError(
                f"no model registered in slot {name!r}; call "
                f"TMServer.register({name!r}, model) first "
                f"(known slots: {sorted(self._slots) or 'none'})"
            )
        return self._slots[name]

    def names(self) -> List[str]:
        return sorted(self._slots)

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    def __len__(self) -> int:
        return len(self._slots)
