"""Multi-tenant batched serving over the runtime-tunable TM accelerator.

Layers:
  executors.py   ServeCapacity + the four engine backends
                 (interp / plan / sharded / popcount), one private jit
                 cache each
  batching.py    request queue, 32-datapoint-word coalescing, demux
  registry.py    named model slots with hot-swap (Fig-8 recalibration)
  metrics.py     latency/throughput instrumentation
  server.py      TMServer — the public API tying it together
"""

from .batching import Batcher, RequestHandle
from .executors import (
    BACKENDS,
    InterpExecutor,
    PlanExecutor,
    PopcountExecutor,
    ServeCapacity,
    ShardedExecutor,
    make_executor,
)
from .metrics import ServeMetrics
from .registry import ModelRegistry, SlotEntry
from .server import TMServer

__all__ = [
    "BACKENDS",
    "Batcher",
    "InterpExecutor",
    "ModelRegistry",
    "PlanExecutor",
    "PopcountExecutor",
    "RequestHandle",
    "ServeCapacity",
    "ServeMetrics",
    "ShardedExecutor",
    "SlotEntry",
    "TMServer",
    "make_executor",
]
