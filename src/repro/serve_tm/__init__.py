"""Multi-tenant batched serving over the runtime-tunable TM accelerator.

The engine/capacity layer lives in ``repro.accel`` (the public façade:
``Accelerator``, ``CapacityPlan``, ``TMProgram``, the ``Engine`` plugin
registry); this package is the serving machinery on top of it:

  batching.py    request queue, 32-datapoint-word coalescing, demux
  registry.py    named model slots with hot-swap + bounded history
                 (Fig-8 recalibration; accepts TMProgram artifacts)
  metrics.py     latency/throughput instrumentation
  server.py      TMServer — multi-tenant submit/flush/infer
  executors.py   DEPRECATED shim: the old ServeCapacity/executor names,
                 re-exported from repro.accel
"""

from .batching import Batcher, RequestHandle
from .executors import (
    BACKENDS,
    InterpExecutor,
    PlanExecutor,
    PopcountExecutor,
    ServeCapacity,
    ShardedExecutor,
    make_executor,
)
from .metrics import ServeMetrics
from .registry import ModelRegistry, SlotEntry
from .server import TMServer

__all__ = [
    "BACKENDS",
    "Batcher",
    "InterpExecutor",
    "ModelRegistry",
    "PlanExecutor",
    "PopcountExecutor",
    "RequestHandle",
    "ServeCapacity",
    "ServeMetrics",
    "ShardedExecutor",
    "SlotEntry",
    "TMServer",
    "make_executor",
]
