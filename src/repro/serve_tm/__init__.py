"""Multi-tenant batched serving over the runtime-tunable TM accelerator.

The engine/capacity layer lives in ``repro.accel`` (the public façade:
``Accelerator``, ``CapacityPlan``, ``TMProgram``, the ``Engine`` plugin
registry); this package is the serving machinery on top of it:

  batching.py    priority-lane request queues (critical/high/normal/low),
                 EDF batch formation, deadline shedding, 32-datapoint-word
                 coalescing, demux; awaitable RequestHandle
  scheduler.py   the continuous-batching flush loop (one asyncio task per
                 server) + admission control (structured Overloaded)
  registry.py    named model slots with hot-swap + bounded history
                 (Fig-8 recalibration; accepts TMProgram artifacts)
  metrics.py     latency/throughput instrumentation incl. per-lane
                 percentiles, sheds, rejects, SLO attainment
  server.py      TMServer — multi-tenant submit/flush/infer plus the
                 async front door (start/stop, async_submit)
  node.py        ServingNode — the node boundary repro.fleet routes over
                 and repro.recal publishes through
  schema.py      the ServeMetrics.summary() key schema (single source of
                 truth for the golden test / regression gate / docs)
  executors.py   DEPRECATED shim: the old ServeCapacity/executor names,
                 re-exported from repro.accel (warns on import)

The structured exceptions are stable public API here and on
``repro.accel``: ``Overloaded`` (admission control), ``DeadlineExceeded``
(a shed request), ``CapacityExceeded`` (a model that doesn't fit the
synthesis-time envelope), ``EngineFault`` (a batch body that raised,
failing its requests) and ``NodeDown`` (a node that stopped responding;
``repro.fleet`` raises and routes around it).

The legacy executor names below are re-exported from ``repro.accel``
directly (NOT via the shim) so importing this package stays silent;
importing ``repro.serve_tm.executors`` itself raises the deprecation
warning.
"""

from ..accel.capacity import CapacityExceeded
from ..accel.capacity import CapacityPlan as ServeCapacity
from ..accel.engine import ENGINES as BACKENDS
from ..accel.engine import make_engine as make_executor
from ..accel.engines import (
    InterpEngine as InterpExecutor,
    PlanEngine as PlanExecutor,
    PopcountEngine as PopcountExecutor,
    ShardedEngine as ShardedExecutor,
)
from .batching import (
    Batcher,
    DeadlineExceeded,
    PRIORITIES,
    RequestHandle,
)
from .metrics import ServeMetrics
from .node import NodeDown, ServingNode
from .registry import ModelRegistry, SlotEntry
from .scheduler import EngineFault, Overloaded, Scheduler
from .server import TMServer

__all__ = [
    "BACKENDS",
    "Batcher",
    "CapacityExceeded",
    "DeadlineExceeded",
    "EngineFault",
    "InterpExecutor",
    "ModelRegistry",
    "NodeDown",
    "Overloaded",
    "PRIORITIES",
    "PlanExecutor",
    "PopcountExecutor",
    "RequestHandle",
    "Scheduler",
    "ServeCapacity",
    "ServeMetrics",
    "ServingNode",
    "ShardedExecutor",
    "SlotEntry",
    "TMServer",
    "make_executor",
]
