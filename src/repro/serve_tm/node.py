"""The ``ServingNode`` boundary — the minimal contract one serving node
exposes to everything that operates ON nodes rather than inside them.

``TMServer`` and the ``repro.accel.Accelerator`` façade both satisfy it;
``repro.fleet`` (pools, the router, canary rollouts) and
``repro.recal.RecalController`` are written against THIS surface only,
so anything that speaks it — a local server, the façade, a proxy for a
remote accelerator — can join a fleet or host a recal loop.

The protocol deliberately stays at the node boundary:

  * traffic:      ``submit`` / ``async_submit`` (priority lanes,
                  deadlines, admission control live behind them),
                  ``infer`` (sync convenience), ``class_sums`` (the
                  direct oracle hook bit-exactness gates use),
                  ``flush`` and the ``start``/``stop`` loop lifecycle;
  * programming:  ``register`` / ``rollback`` — the drain-then-swap
                  discipline and provenance chains are the NODE's job,
                  callers just name the slot;
  * introspection: ``capacity`` (the negotiated ``CapacityPlan`` a
                  router filters on), ``validate_model`` (the exact
                  will-it-fit check this node's engine applies),
                  ``queue_depth`` (the router's load signal),
                  ``metrics_snapshot`` (the per-lane ``summary()``
                  dict — see serve_tm/schema.py), ``slots`` and the
                  per-slot installed-artifact ``installed_checksum`` /
                  ``installed_artifact`` (what rollout gating audits).

Engine objects, registries and schedulers are implementation details a
node keeps to itself; nothing above this boundary may reach for them.
"""

from __future__ import annotations

from typing import List, Optional, Protocol, runtime_checkable

import numpy as np


class NodeDown(RuntimeError):
    """A node stopped responding entirely — every boundary call fails.

    The structured fleet-level failure: raised by a dead node's proxy (or
    the chaos wrapper standing in for one) on ANY boundary operation, and
    by the fleet health layer when it fails the pending handles of a node
    declared dead.  Carries ``node`` (the name, when known) and ``op``
    (the boundary call that hit the corpse) so routers and rollouts can
    quarantine without string parsing."""

    def __init__(self, node: str = "?", op: str = ""):
        self.node = node
        self.op = op
        where = f" (during {op!r})" if op else ""
        super().__init__(
            f"node {node!r} is not responding{where} — it has stopped "
            f"serving; quarantine it and route around"
        )


@runtime_checkable
class ServingNode(Protocol):
    """One deployed accelerator, seen from the outside."""

    # -- traffic -------------------------------------------------------------

    def submit(
        self,
        slot: str,
        x: np.ndarray,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ): ...

    async def async_submit(
        self,
        slot: str,
        x: np.ndarray,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ): ...

    def flush(self) -> None: ...

    def infer(self, slot: str, x: np.ndarray) -> np.ndarray: ...

    def class_sums(self, slot: str, x: np.ndarray) -> np.ndarray: ...

    def start(self) -> None: ...

    def stop(self, drain: bool = True) -> None: ...

    @property
    def scheduler_running(self) -> bool: ...

    # -- programming (drain-then-swap is the node's responsibility) ----------

    def register(self, slot: str, model, provenance: str = "install"): ...

    def rollback(self, slot: str): ...

    # -- introspection (what routers / rollouts / recal loops key on) --------

    @property
    def capacity(self): ...

    def validate_model(self, model) -> None: ...

    def queue_depth(
        self, slot: Optional[str] = None, priority: Optional[str] = None
    ) -> int: ...

    def metrics_snapshot(self) -> dict: ...

    def slots(self) -> List[str]: ...

    def installed_checksum(self, slot: str) -> Optional[int]: ...

    def installed_artifact(self, slot: str): ...

    def compile_cache_size(self) -> int: ...
