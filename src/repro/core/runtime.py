"""Stream protocol + runtime-tunable Accelerator (paper Fig 4 / Fig 8).

The accelerator is "synthesized" once (= jit-compiled once, with fixed
buffer capacities chosen like the eFPGA memory-depth customization of
Fig 6), then reprogrammed arbitrarily many times at runtime via data
streams.  Two packet kinds, distinguished by the header (Fig 4.2/4.3):

  * Instruction stream — carries a new compressed TM model
  * Feature stream     — carries Boolean features for inference

Header layout (64-bit = 4 x uint16 words, the paper's widest option):

  word0: bit15 RESET | bit14 TYPE(1=instr,0=feat) | bits13..0 payload
         TYPE=1: payload = n_classes     TYPE=0: payload = n_features
  word1: TYPE=1: n_clauses per class     TYPE=0: n_datapoints
  word2: count low 16   (TYPE=1: n_instructions, TYPE=0: n_feature_words)
  word3: count high 16

Changing the model, the task (class count), or the input dimensionality is
*pure data movement* — ``Accelerator.infer`` is jitted exactly once per
capacity configuration.  ``tests/test_runtime.py`` asserts the jit cache
does not grow across model swaps (the "no offline resynthesis" property).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .compress import CompressedModel
from .interp import interpret_stream, pack_features

RESET_BIT = 15
TYPE_BIT = 14
PAYLOAD_MASK = 0x3FFF


# ---------------------------------------------------------------------------
# Stream builders (the Fig-8 training node side)
# ---------------------------------------------------------------------------

def build_instruction_stream(model: CompressedModel) -> np.ndarray:
    """CompressedModel -> uint16 stream (header + instruction payload).

    Raises ValueError when a header field does not fit its wire width
    (14-bit class payload, 16-bit clause count, 32-bit instruction count)
    rather than silently wrapping into a corrupt-but-parseable header.
    """
    n = model.n_instructions
    if model.n_classes > PAYLOAD_MASK:
        raise ValueError(
            f"n_classes={model.n_classes} does not fit the 14-bit header "
            f"payload (max {PAYLOAD_MASK})"
        )
    if model.n_clauses > 0xFFFF:
        raise ValueError(
            f"n_clauses={model.n_clauses} does not fit header word1 "
            f"(max {0xFFFF})"
        )
    if n > 0xFFFFFFFF:
        raise ValueError(
            f"n_instructions={n} does not fit the 32-bit count field "
            f"(max {0xFFFFFFFF})"
        )
    header = np.array(
        [
            (1 << RESET_BIT) | (1 << TYPE_BIT) | model.n_classes,
            model.n_clauses,
            n & 0xFFFF,
            (n >> 16) & 0xFFFF,
        ],
        dtype=np.uint16,
    )
    return np.concatenate([header, model.instructions])


def build_feature_stream(x: np.ndarray) -> np.ndarray:
    """Boolean features {0,1}[B, F] -> uint16 stream (header + packed bits).

    Each datapoint's F booleans are packed LSB-first into ceil(F/16) words
    (the paper's "Inference data packets")."""
    x = np.asarray(x, dtype=np.uint16)
    B, F = x.shape
    if F > PAYLOAD_MASK:
        raise ValueError(
            f"n_features={F} does not fit the 14-bit header payload "
            f"(max {PAYLOAD_MASK})"
        )
    if B > 0xFFFF:
        raise ValueError(
            f"n_datapoints={B} does not fit header word1 (max {0xFFFF}); "
            f"stream in chunks"
        )
    wpd = (F + 15) // 16  # words per datapoint
    padded = np.zeros((B, wpd * 16), dtype=np.uint16)
    padded[:, :F] = x
    payload = np.zeros((B, wpd), dtype=np.uint16)
    for w in range(wpd):
        chunk = padded[:, w * 16 : (w + 1) * 16]
        payload[:, w] = (chunk << np.arange(16, dtype=np.uint16)[None, :]).sum(
            axis=1, dtype=np.uint16
        )
    nw = B * wpd
    header = np.array(
        [
            (1 << RESET_BIT) | F,
            B,
            nw & 0xFFFF,
            (nw >> 16) & 0xFFFF,
        ],
        dtype=np.uint16,
    )
    return np.concatenate([header, payload.reshape(-1)])


def parse_header(stream: np.ndarray) -> Tuple[bool, bool, int, int, int]:
    """-> (reset, is_instructions, payload, word1, count)."""
    w0, w1, w2, w3 = (int(stream[i]) for i in range(4))
    reset = bool((w0 >> RESET_BIT) & 1)
    is_instr = bool((w0 >> TYPE_BIT) & 1)
    payload = w0 & PAYLOAD_MASK
    count = w2 | (w3 << 16)
    return reset, is_instr, payload, w1, count


# ---------------------------------------------------------------------------
# The accelerator (Fig 4, base configuration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """"Synthesis-time" memory-depth customization (paper Fig 6)."""

    instruction_capacity: int = 1 << 15  # instruction memory depth
    feature_capacity: int = 1 << 12  # feature memory depth (Boolean features)
    class_capacity: int = 64  # class-sum accumulator bank depth
    batch_words: int = 1  # W: 32 datapoints per word (paper batches 32)

    @property
    def batch_capacity(self) -> int:
        return self.batch_words * 32

    @property
    def bram_bytes(self) -> int:
        """On-chip memory the configuration claims (Fig 6 x-axis analog)."""
        return (
            self.instruction_capacity * 2
            + self.feature_capacity * self.batch_words * 4
            + self.class_capacity * self.batch_capacity * 4
        )


class Accelerator:
    """Runtime-tunable compressed-TM inference engine.

    jit-compiles its interpreter ONCE per AcceleratorConfig; every
    subsequent model/task/dimensionality change is a buffer rewrite.
    """

    def __init__(self, config: AcceleratorConfig = AcceleratorConfig()):
        self.config = config
        c = config
        self._imem = jnp.zeros(c.instruction_capacity, dtype=jnp.uint16)
        self._n_inst = jnp.int32(0)
        self._n_classes = jnp.int32(0)
        self._n_clauses = 0
        self._n_features = 0
        # counts how many times XLA compilation ran for the inference path
        self.programs_loaded = 0

    # -- programming ---------------------------------------------------------

    def feed(self, stream: np.ndarray) -> Optional[np.ndarray]:
        """Consume one stream (header + payload).  Instruction streams
        program the accelerator and return None; feature streams run
        inference and return predictions."""
        reset, is_instr, payload, w1, count = parse_header(stream)
        body = stream[4:]
        if is_instr:
            if count > self.config.instruction_capacity:
                raise ValueError(
                    f"model needs {count} instructions; capacity is "
                    f"{self.config.instruction_capacity} (resynthesize = "
                    f"pick a bigger AcceleratorConfig)"
                )
            if payload > self.config.class_capacity:
                raise ValueError("class count exceeds accumulator bank depth")
            imem = np.zeros(self.config.instruction_capacity, dtype=np.uint16)
            imem[:count] = body[:count]
            self._imem = jnp.asarray(imem)
            self._n_inst = jnp.int32(count)
            self._n_classes = jnp.int32(payload)
            self._n_clauses = w1
            self.programs_loaded += 1
            return None
        # feature stream
        n_features, n_points = payload, w1
        if n_features > self.config.feature_capacity:
            raise ValueError("input dimensionality exceeds feature memory")
        if n_points > self.config.batch_capacity:
            raise ValueError("batch exceeds batch words; stream in chunks")
        x = _unpack_feature_payload(body, n_points, n_features)
        return self.infer(x)

    def load_model(self, model: CompressedModel) -> None:
        self.feed(build_instruction_stream(model))

    # -- inference -----------------------------------------------------------

    def infer(self, x: np.ndarray) -> np.ndarray:
        """{0,1}[B<=batch_capacity, F] -> int32[B] predicted classes."""
        c = self.config
        B = x.shape[0]
        packed = pack_features(
            jnp.asarray(x), c.feature_capacity, c.batch_words
        )
        sums = interpret_stream(
            self._imem, self._n_inst, packed, jnp.int32(B), m_cap=c.class_capacity
        )
        valid = jnp.arange(c.class_capacity) < self._n_classes
        masked = jnp.where(valid[:, None], sums, jnp.iinfo(jnp.int32).min)
        return np.asarray(jnp.argmax(masked, axis=0)[:B], dtype=np.int32)

    def class_sums(self, x: np.ndarray) -> np.ndarray:
        c = self.config
        B = x.shape[0]
        packed = pack_features(jnp.asarray(x), c.feature_capacity, c.batch_words)
        sums = interpret_stream(
            self._imem, self._n_inst, packed, jnp.int32(B), m_cap=c.class_capacity
        )
        return np.asarray(sums)[: int(self._n_classes), :B].T

    def compile_cache_size(self) -> int:
        """# of compiled variants of the interpreter (should stay 1)."""
        return interpret_stream._cache_size()


def _unpack_feature_payload(body: np.ndarray, n_points: int, n_features: int) -> np.ndarray:
    wpd = (n_features + 15) // 16
    words = np.asarray(body[: n_points * wpd], dtype=np.uint16).reshape(
        n_points, wpd
    )
    bits = (words[:, :, None] >> np.arange(16, dtype=np.uint16)[None, None, :]) & 1
    return bits.reshape(n_points, wpd * 16)[:, :n_features].astype(np.uint8)


# ---------------------------------------------------------------------------
# Multi-core configuration (paper Fig 7): class-level parallelism
# ---------------------------------------------------------------------------

class MultiCoreAccelerator:
    """N base cores, each programmed with a disjoint class slice of the same
    model (the AXIS splitter of Fig 7).  Single-process realization; the
    mesh-sharded version of the same split lives in repro/dist (the TM arch
    entry of the multi-pod dry-run)."""

    def __init__(self, n_cores: int, config: AcceleratorConfig = AcceleratorConfig()):
        self.n_cores = n_cores
        self.cores = [Accelerator(config) for _ in range(n_cores)]
        self._class_slices: list[tuple[int, int]] = []

    def load_model(self, model: CompressedModel) -> None:
        from .compress import decode, encode
        from .tm import TMConfig

        acts = decode(model)
        M = model.n_classes
        per = -(-M // self.n_cores)
        self._class_slices = []
        for i, core in enumerate(self.cores):
            lo, hi = i * per, min((i + 1) * per, M)
            self._class_slices.append((lo, hi))
            if lo >= hi:
                continue
            sub_cfg = TMConfig(
                n_classes=hi - lo,
                n_clauses=model.n_clauses,
                n_features=model.n_features,
            )
            core.load_model(encode(sub_cfg, acts[lo:hi]))

    def infer(self, x: np.ndarray) -> np.ndarray:
        if not self._class_slices:
            raise RuntimeError(
                "no model loaded: call MultiCoreAccelerator.load_model() "
                "before infer()"
            )
        all_sums = []
        for core, (lo, hi) in zip(self.cores, self._class_slices):
            if lo >= hi:
                continue
            all_sums.append(core.class_sums(x))  # [B, hi-lo]
        sums = np.concatenate(all_sums, axis=1)
        return np.argmax(sums, axis=1).astype(np.int32)
