"""Vanilla Tsetlin Machine training (Granmo 2018, refs [8,9,21] in the paper).

Faithful *online* semantics: samples update TA state sequentially
(``lax.scan`` over the batch).  For each sample:

  * target class y       -> clauses selected w.p. (T - clamp(v))/2T
       positive clauses get Type I feedback, negative get Type II
  * one random class != y -> clauses selected w.p. (T + clamp(v))/2T
       positive clauses get Type II feedback, negative get Type I

Type I  (combats false negatives / reinforces patterns):
   clause==1: literal==1 -> +1 w.p. (s-1)/s (1.0 if boost_true_positive)
              literal==0 -> -1 w.p. 1/s
   clause==0: all TAs    -> -1 w.p. 1/s
Type II (combats false positives):
   clause==1 & literal==0 & action==Exclude -> +1 (deterministic)

This trainer is the "Model Training Node" of the paper's Fig 8 system: it is
cheap (bitwise + increments), runs on host/CPU-class hardware, and its output
is compressed into the instruction stream that reprograms the accelerator.

Seeding contract (fold-in based, reproducible under ``jax.jit``):

  * ``sample_keys(key, n, offset)`` derives the per-sample keys: the sample
    at GLOBAL position ``offset + i`` always trains under
    ``fold_in(key, offset + i)`` — no sequential split chain, so the same
    (key, position) pair yields the same feedback regardless of batch
    slicing, device count or how many steps ran before.
  * ``train_batch`` / ``train_batch_parallel`` consume samples at positions
    ``0..B-1`` of their call key.
  * ``fit_step(..., step=s)`` uses call key ``fold_in(key, s)`` — any step
    is independently re-derivable, which makes training resumable (the
    RecalWorker's incremental API).
  * ``fit`` derives epoch ``e``, batch ``b`` as step ``e * n_batches + b``
    and the epoch-``e`` shuffle as ``fold_in(fold_in(key, _SHUFFLE), e)``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .tm import TMConfig, clause_polarities, literals

Array = jax.Array

# Domain-separation tag for shuffle keys (outside the step-index range).
_SHUFFLE = 0x5F5F5F5F


def sample_keys(key: Array, n: int, offset: Array | int = 0) -> Array:
    """Per-sample training keys for samples at positions offset..offset+n-1."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        offset + jnp.arange(n)
    )


def validate_batch_capacity(n_rows: int, plan, what: str = "training batch"):
    """Raise the structured ``CapacityExceeded`` when a training batch
    blows through a negotiated ``CapacityPlan``'s batch words.

    The trainers share the accelerator's batch envelope semantics (32
    datapoints per bit-packed word): a training node co-located with the
    serving node trains inside the same synthesis-time staging depth it
    serves from, and callers can react programmatically (``.knob`` /
    ``.required`` / ``.capacity``) instead of parsing an assert message.
    Imported lazily — ``repro.accel`` depends on ``repro.core``, not the
    other way around.
    """
    if plan is None:
        return
    from ..accel.capacity import CapacityExceeded

    n_rows = int(n_rows)
    if n_rows > plan.batch_words * 32:
        raise CapacityExceeded(
            "batch_words", -(-n_rows // 32), plan.batch_words, what
        )


def _type_i_delta(cfg: TMConfig, key: Array, clause_out: Array, lits: Array) -> Array:
    """Type I state delta for ALL clauses of one class.

    clause_out: bool[C]; lits: bool[2F] -> int32[C, 2F]
    """
    C, L = cfg.n_clauses, cfg.n_literals
    s = cfg.specificity
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, (C, L))
    # clause fired:
    p_strengthen = 1.0 if cfg.boost_true_positive else (s - 1.0) / s
    inc = jnp.where(lits[None, :], (u < p_strengthen).astype(jnp.int32), 0)
    dec_lit0 = jnp.where(~lits[None, :], -(u < 1.0 / s).astype(jnp.int32), 0)
    fired = inc + dec_lit0
    # clause did not fire: gentle push towards Exclude
    u2 = jax.random.uniform(k2, (C, L))
    unfired = -(u2 < 1.0 / s).astype(jnp.int32)
    return jnp.where(clause_out[:, None], fired, unfired)


def _type_ii_delta(
    cfg: TMConfig, clause_out: Array, lits: Array, actions: Array
) -> Array:
    """Type II delta: push Excluded TAs of 0-literals towards Include when the
    clause (wrongly) fires. int32[C, 2F]."""
    push = clause_out[:, None] & (~lits[None, :]) & (~actions)
    return push.astype(jnp.int32)


def _feedback_from_clause_outputs(
    cfg: TMConfig,
    key: Array,
    class_state: Array,  # int32[C, 2F]
    actions: Array,  # bool[C, 2F]  (class_state > N)
    sat: Array,  # bool[C]  training-semantics clause outputs (empty -> 1)
    lits: Array,  # bool[2F]
    is_target: Array,  # bool scalar
) -> Array:
    """New state for one class given its precomputed clause outputs.

    The single source of truth for the Type I/II feedback math: every
    trainer — dense (``_class_feedback``), class-sharded
    (``sample_class_delta``) and the packed fused kernel
    (``kernels.tm_train``) — funnels through this function, so the
    stochastic selection and state increments are bit-identical by
    construction, whatever representation computed ``sat``.
    """
    N = cfg.n_states
    T = cfg.threshold
    pol = clause_polarities(cfg)  # +1/-1
    v = jnp.clip(jnp.sum(sat.astype(jnp.int32) * pol), -T, T)

    p_sel = jnp.where(is_target, (T - v) / (2.0 * T), (T + v) / (2.0 * T))
    k_sel, k_t1 = jax.random.split(key)
    selected = jax.random.uniform(k_sel, (cfg.n_clauses,)) < p_sel

    pos = pol > 0
    t1_mask = selected & jnp.where(is_target, pos, ~pos)
    t2_mask = selected & jnp.where(is_target, ~pos, pos)

    d1 = _type_i_delta(cfg, k_t1, sat, lits)
    d2 = _type_ii_delta(cfg, sat, lits, actions)
    delta = t1_mask[:, None] * d1 + t2_mask[:, None] * d2
    return jnp.clip(class_state + delta, 1, 2 * N)


def _class_feedback(
    cfg: TMConfig,
    key: Array,
    class_state: Array,  # int32[C, 2F]
    lits: Array,  # bool[2F]
    is_target: Array,  # bool scalar
) -> Array:
    """New state for one class given one sample."""
    actions = class_state > cfg.n_states
    sat = jnp.all(jnp.where(actions, lits[None, :], True), axis=-1)  # train: empty->1
    return _feedback_from_clause_outputs(
        cfg, key, class_state, actions, sat, lits, is_target
    )


def _sample_update(cfg: TMConfig, state: Array, key: Array, x: Array, y: Array) -> Array:
    """Online update for one sample. state: int32[M, C, 2F]."""
    lits = literals(x)  # bool[2F]
    k_neg, k_tgt, k_not = jax.random.split(key, 3)
    # random negative class != y
    M = cfg.n_classes
    neg = jax.random.randint(k_neg, (), 0, M - 1)
    neg = jnp.where(neg >= y, neg + 1, neg).astype(jnp.int32)

    new_tgt = _class_feedback(cfg, k_tgt, state[y], lits, jnp.bool_(True))
    state = state.at[y].set(new_tgt)
    new_neg = _class_feedback(cfg, k_not, state[neg], lits, jnp.bool_(False))
    return state.at[neg].set(new_neg)


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_batch(
    cfg: TMConfig, state: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """Sequential (online) updates over a batch. xb: {0,1}[B,F], yb: int32[B]."""

    def step(st, inp):
        k, x, y = inp
        return _sample_update(cfg, st, k, x, y), None

    keys = sample_keys(key, xb.shape[0])
    xb = xb.astype(jnp.bool_)
    state, _ = jax.lax.scan(step, state, (keys, xb, yb))
    return state


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_batch_parallel(
    cfg: TMConfig, state: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """Data-parallel (summed-delta) batch update.

    Computes every sample's feedback against the SAME pre-batch state and
    applies the summed, clipped deltas — the standard approximation used by
    parallel TM implementations (CAIR CUDA TM, arXiv:2009.04861).  Trades
    exact online semantics for a vmap that parallelizes over the batch —
    this is what makes the Fig-8 training node fast on SIMD hardware.
    """
    N = cfg.n_states

    def sample_delta(k, x, yv):
        lits = literals(x)
        k_neg, k_tgt, k_not = jax.random.split(k, 3)
        M = cfg.n_classes
        neg = jax.random.randint(k_neg, (), 0, M - 1)
        neg = jnp.where(neg >= yv, neg + 1, neg).astype(jnp.int32)
        d = jnp.zeros((M, cfg.n_clauses, cfg.n_literals), jnp.int32)
        new_t = _class_feedback(cfg, k_tgt, state[yv], lits, jnp.bool_(True))
        d = d.at[yv].add(new_t - state[yv])
        new_n = _class_feedback(cfg, k_not, state[neg], lits, jnp.bool_(False))
        return d.at[neg].add(new_n - state[neg])

    keys = sample_keys(key, xb.shape[0])
    deltas = jax.vmap(sample_delta)(keys, xb.astype(jnp.bool_), yb)
    return jnp.clip(state + jnp.sum(deltas, axis=0), 1, 2 * N)


def sample_class_delta(
    cfg: TMConfig,
    class_state: Array,  # int32[Mc, C, 2F]  a slice of class rows
    m_ids: Array,  # int32[Mc]  global class ids of those rows
    key: Array,  # this sample's key (from ``sample_keys``)
    x: Array,  # {0,1}[F]
    y: Array,  # int32 scalar
) -> Array:
    """One sample's summed-delta feedback restricted to a class-row slice.

    Bit-identical to the corresponding rows of ``train_batch_parallel``'s
    per-sample delta: the target row uses the sample's k_tgt stream, the
    sampled negative row its k_not stream, every other row is zero.  This
    is the class-sharded form ``dist.steps.make_tm_train_step`` maps over
    the ``model`` mesh axis (each device feeds back only the classes it
    owns, at the cost of evaluating both feedback branches per owned row).
    """
    lits = literals(x)
    k_neg, k_tgt, k_not = jax.random.split(key, 3)
    M = cfg.n_classes
    neg = jax.random.randint(k_neg, (), 0, M - 1)
    neg = jnp.where(neg >= y, neg + 1, neg).astype(jnp.int32)

    def one(m, s_m):
        new_t = _class_feedback(cfg, k_tgt, s_m, lits, jnp.bool_(True))
        new_n = _class_feedback(cfg, k_not, s_m, lits, jnp.bool_(False))
        return jnp.where(
            m == y, new_t - s_m, jnp.where(m == neg, new_n - s_m, 0)
        )

    return jax.vmap(one)(m_ids, class_state)


def fit_step(
    cfg: TMConfig,
    state: Array,
    key: Array,
    xb: Array,
    yb: Array,
    *,
    step: int,
    parallel: bool = False,
    plan=None,
) -> Array:
    """One resumable training step (the RecalWorker's incremental API).

    The batch trains under ``fold_in(key, step)``, so the update for a
    given (key, step, batch) triple is identical no matter how many steps
    ran before — a fine-tune loop can stop, checkpoint the (state, key,
    step) triple, and resume bit-exactly.

    ``plan`` (an ``accel.CapacityPlan``) opts into the negotiated batch
    envelope: a batch wider than ``plan.batch_words * 32`` raises the
    structured ``CapacityExceeded`` instead of training outside the
    synthesis-time staging depth.
    """
    validate_batch_capacity(xb.shape[0], plan)
    kb = jax.random.fold_in(key, step)
    f = train_batch_parallel if parallel else train_batch
    return f(cfg, state, kb, xb, yb)


def fit(
    cfg: TMConfig,
    state: Array,
    key: Array,
    x: Array,
    y: Array,
    *,
    epochs: int = 10,
    batch: int = 128,
    shuffle: bool = True,
    parallel: bool = False,
) -> Array:
    """Host-side epoch loop (the paper's Raspberry-Pi-class training node).

    Keys are fold-in derived (see module docstring): epoch ``e`` batch
    ``b`` is ``fit_step(step=e * n_batches + b)`` — no host-side split
    chain, so the loop is reproducible and restartable mid-epoch.
    """
    n = x.shape[0]
    n_batches = max(1, n // batch)
    k_shuffle = jax.random.fold_in(key, _SHUFFLE)
    for e in range(epochs):
        order = (
            jax.random.permutation(jax.random.fold_in(k_shuffle, e), n)
            if shuffle
            else jnp.arange(n)
        )
        for b in range(n_batches):
            idx = order[b * batch : (b + 1) * batch]
            state = fit_step(
                cfg, state, key, x[idx], y[idx],
                step=e * n_batches + b, parallel=parallel,
            )
    return state


def accuracy(cfg: TMConfig, state: Array, x: Array, y: Array) -> float:
    from .tm import predict

    pred = predict(cfg, state, x)
    return float(jnp.mean((pred == y).astype(jnp.float32)))
