"""Vanilla Tsetlin Machine training (Granmo 2018, refs [8,9,21] in the paper).

Faithful *online* semantics: samples update TA state sequentially
(``lax.scan`` over the batch).  For each sample:

  * target class y       -> clauses selected w.p. (T - clamp(v))/2T
       positive clauses get Type I feedback, negative get Type II
  * one random class != y -> clauses selected w.p. (T + clamp(v))/2T
       positive clauses get Type II feedback, negative get Type I

Type I  (combats false negatives / reinforces patterns):
   clause==1: literal==1 -> +1 w.p. (s-1)/s (1.0 if boost_true_positive)
              literal==0 -> -1 w.p. 1/s
   clause==0: all TAs    -> -1 w.p. 1/s
Type II (combats false positives):
   clause==1 & literal==0 & action==Exclude -> +1 (deterministic)

This trainer is the "Model Training Node" of the paper's Fig 8 system: it is
cheap (bitwise + increments), runs on host/CPU-class hardware, and its output
is compressed into the instruction stream that reprograms the accelerator.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .tm import TMConfig, clause_polarities, literals

Array = jax.Array


def _type_i_delta(cfg: TMConfig, key: Array, clause_out: Array, lits: Array) -> Array:
    """Type I state delta for ALL clauses of one class.

    clause_out: bool[C]; lits: bool[2F] -> int32[C, 2F]
    """
    C, L = cfg.n_clauses, cfg.n_literals
    s = cfg.specificity
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, (C, L))
    # clause fired:
    p_strengthen = 1.0 if cfg.boost_true_positive else (s - 1.0) / s
    inc = jnp.where(lits[None, :], (u < p_strengthen).astype(jnp.int32), 0)
    dec_lit0 = jnp.where(~lits[None, :], -(u < 1.0 / s).astype(jnp.int32), 0)
    fired = inc + dec_lit0
    # clause did not fire: gentle push towards Exclude
    u2 = jax.random.uniform(k2, (C, L))
    unfired = -(u2 < 1.0 / s).astype(jnp.int32)
    return jnp.where(clause_out[:, None], fired, unfired)


def _type_ii_delta(
    cfg: TMConfig, clause_out: Array, lits: Array, actions: Array
) -> Array:
    """Type II delta: push Excluded TAs of 0-literals towards Include when the
    clause (wrongly) fires. int32[C, 2F]."""
    push = clause_out[:, None] & (~lits[None, :]) & (~actions)
    return push.astype(jnp.int32)


def _class_feedback(
    cfg: TMConfig,
    key: Array,
    class_state: Array,  # int32[C, 2F]
    lits: Array,  # bool[2F]
    is_target: Array,  # bool scalar
) -> Array:
    """New state for one class given one sample."""
    N = cfg.n_states
    T = cfg.threshold
    actions = class_state > N
    sat = jnp.all(jnp.where(actions, lits[None, :], True), axis=-1)  # train: empty->1
    pol = clause_polarities(cfg)  # +1/-1
    v = jnp.clip(jnp.sum(sat.astype(jnp.int32) * pol), -T, T)

    p_sel = jnp.where(is_target, (T - v) / (2.0 * T), (T + v) / (2.0 * T))
    k_sel, k_t1 = jax.random.split(key)
    selected = jax.random.uniform(k_sel, (cfg.n_clauses,)) < p_sel

    pos = pol > 0
    t1_mask = selected & jnp.where(is_target, pos, ~pos)
    t2_mask = selected & jnp.where(is_target, ~pos, pos)

    d1 = _type_i_delta(cfg, k_t1, sat, lits)
    d2 = _type_ii_delta(cfg, sat, lits, actions)
    delta = t1_mask[:, None] * d1 + t2_mask[:, None] * d2
    return jnp.clip(class_state + delta, 1, 2 * N)


def _sample_update(cfg: TMConfig, state: Array, key: Array, x: Array, y: Array) -> Array:
    """Online update for one sample. state: int32[M, C, 2F]."""
    lits = literals(x)  # bool[2F]
    k_neg, k_tgt, k_not = jax.random.split(key, 3)
    # random negative class != y
    M = cfg.n_classes
    neg = jax.random.randint(k_neg, (), 0, M - 1)
    neg = jnp.where(neg >= y, neg + 1, neg).astype(jnp.int32)

    new_tgt = _class_feedback(cfg, k_tgt, state[y], lits, jnp.bool_(True))
    state = state.at[y].set(new_tgt)
    new_neg = _class_feedback(cfg, k_not, state[neg], lits, jnp.bool_(False))
    return state.at[neg].set(new_neg)


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_batch(
    cfg: TMConfig, state: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """Sequential (online) updates over a batch. xb: {0,1}[B,F], yb: int32[B]."""

    def step(st, inp):
        k, x, y = inp
        return _sample_update(cfg, st, k, x, y), None

    keys = jax.random.split(key, xb.shape[0])
    xb = xb.astype(jnp.bool_)
    state, _ = jax.lax.scan(step, state, (keys, xb, yb))
    return state


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def train_batch_parallel(
    cfg: TMConfig, state: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """Data-parallel (summed-delta) batch update.

    Computes every sample's feedback against the SAME pre-batch state and
    applies the summed, clipped deltas — the standard approximation used by
    parallel TM implementations (CAIR CUDA TM, arXiv:2009.04861).  Trades
    exact online semantics for a vmap that parallelizes over the batch —
    this is what makes the Fig-8 training node fast on SIMD hardware.
    """
    N = cfg.n_states

    def sample_delta(k, x, yv):
        lits = literals(x)
        k_neg, k_tgt, k_not = jax.random.split(k, 3)
        M = cfg.n_classes
        neg = jax.random.randint(k_neg, (), 0, M - 1)
        neg = jnp.where(neg >= yv, neg + 1, neg).astype(jnp.int32)
        d = jnp.zeros((M, cfg.n_clauses, cfg.n_literals), jnp.int32)
        new_t = _class_feedback(cfg, k_tgt, state[yv], lits, jnp.bool_(True))
        d = d.at[yv].add(new_t - state[yv])
        new_n = _class_feedback(cfg, k_not, state[neg], lits, jnp.bool_(False))
        return d.at[neg].add(new_n - state[neg])

    keys = jax.random.split(key, xb.shape[0])
    deltas = jax.vmap(sample_delta)(keys, xb.astype(jnp.bool_), yb)
    return jnp.clip(state + jnp.sum(deltas, axis=0), 1, 2 * N)


def fit(
    cfg: TMConfig,
    state: Array,
    key: Array,
    x: Array,
    y: Array,
    *,
    epochs: int = 10,
    batch: int = 128,
    shuffle: bool = True,
    parallel: bool = False,
) -> Array:
    """Host-side epoch loop (the paper's Raspberry-Pi-class training node)."""
    n = x.shape[0]
    n_batches = max(1, n // batch)
    for e in range(epochs):
        key, kshuf = jax.random.split(key)
        order = (
            jax.random.permutation(kshuf, n) if shuffle else jnp.arange(n)
        )
        for b in range(n_batches):
            idx = order[b * batch : (b + 1) * batch]
            key, kb = jax.random.split(key)
            step = train_batch_parallel if parallel else train_batch
            state = step(cfg, state, kb, x[idx], y[idx])
    return state


def accuracy(cfg: TMConfig, state: Array, x: Array, y: Array) -> float:
    from .tm import predict

    pred = predict(cfg, state, x)
    return float(jnp.mean((pred == y).astype(jnp.float32)))
