"""Include-only instruction compression (paper §2, Fig 3.4).

A trained TM is ~99% Excludes; only Include TAs matter for inference.  The
model is compressed to a stream of 16-bit *Include Instructions*:

      bit 15 : E   — toggles when the class changes
      bit 14 : CC  — toggles when the clause changes
      bit 13 : P   — polarity of the clause this include belongs to (1 = +)
      bit 12 : L   — literal is the complement (f̄) iff 1
      bits 11..0 : O — offset (literal slots to advance), 0..4094

Traversal order (Fig 3.3): class-major, then clause, then interleaved literal
slot k (= 2*feature + is_complement), so offsets within a clause are strictly
positive after the first include.  The offset counts *within-clause* slots;
the literal pointer resets to 0 at each clause boundary (the Literal Select
step of Fig 4.5 indexes Feature Memory with the accumulated pointer).

Escape: O == 0xFFF is EXTEND — advance the literal pointer by 4095 slots
without consuming a literal.  An EXTEND may also carry the CC/E boundary
toggles; a clause whose stream consists only of EXTENDs has no content and
contributes nothing (inference semantics: empty clause -> 0).  Encoding a
class with zero includes therefore emits a single boundary EXTEND so the
E-toggle class counter stays aligned (the paper's E bit, generalized).

Interpreter contract (shared by interp.py, runtime.py and the Pallas kernel):
  * boundary  := (CC != prev_CC) or (E != prev_E)
  * on boundary: finalize previous clause (add pol * acc to class sums iff
    any include executed in it), advance class iff E toggled, reset the
    literal pointer and the clause accumulator
  * EXTEND: ptr += 4095, no other effect
  * include: ptr += O; literal = (L ? NOT feature[ptr>>1] : feature[ptr>>1]);
    acc &= literal   (ptr's LSB must equal L — interleaved order)
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .tm import TMConfig

E_BIT = 15
CC_BIT = 14
P_BIT = 13
L_BIT = 12
OFF_MASK = 0x0FFF
EXTEND = 0x0FFF  # offset escape: advance 4095 slots, consume nothing
MAX_OFF = OFF_MASK - 1  # 4094


@dataclasses.dataclass(frozen=True)
class CompressedModel:
    """The programmable artifact: what the Fig-8 training node ships."""

    instructions: np.ndarray  # uint16[I]
    n_classes: int
    n_clauses: int  # clauses per class (accumulator bound, Fig 4.6)
    n_features: int  # Boolean features (feature-memory depth)

    @property
    def n_instructions(self) -> int:
        return int(self.instructions.shape[0])

    @property
    def n_bytes(self) -> int:
        return self.n_instructions * 2

    def compression_ratio(self, cfg: TMConfig) -> float:
        """Fraction of the dense 1-bit-per-TA model eliminated (paper: ~99%)."""
        dense_bits = cfg.n_tas
        return 1.0 - (self.n_instructions * 16) / dense_bits


def _emit(e: int, cc: int, p: int, lbit: int, off: int) -> int:
    return (e << E_BIT) | (cc << CC_BIT) | (p << P_BIT) | (lbit << L_BIT) | off


def encode(cfg: TMConfig, actions: np.ndarray) -> CompressedModel:
    """Dense include actions bool[M, C, 2F] -> compressed instruction stream."""
    actions = np.asarray(actions, dtype=bool)
    M, C, L2 = actions.shape
    assert (M, C, L2) == (cfg.n_classes, cfg.n_clauses, cfg.n_literals)

    out: List[int] = []
    e_tog, cc_tog = 0, 0  # current toggle levels
    for m in range(M):
        new_class = True
        if not actions[m].any():
            # class with zero includes: lone boundary EXTEND advances E
            e_tog ^= 1
            cc_tog ^= 1
            out.append(_emit(e_tog, cc_tog, 0, 0, EXTEND))
            continue
        for j in range(C):
            ks = np.flatnonzero(actions[m, j])
            if ks.size == 0:
                continue  # empty clause: contributes 0 at inference; skip
            pol = 1 if j % 2 == 0 else 0
            cc_tog ^= 1
            if new_class:
                e_tog ^= 1
                new_class = False
            ptr = 0
            for k in ks.tolist():
                delta = int(k) - ptr
                while delta > MAX_OFF:
                    out.append(_emit(e_tog, cc_tog, pol, 0, EXTEND))
                    delta -= EXTEND
                out.append(_emit(e_tog, cc_tog, pol, int(k) & 1, delta))
                ptr = int(k)
    return CompressedModel(
        instructions=np.asarray(out, dtype=np.uint16),
        n_classes=M,
        n_clauses=C,
        n_features=cfg.n_features,
    )


def validate_roundtrip(
    cfg: TMConfig, actions: np.ndarray, model: CompressedModel, X: np.ndarray
) -> None:
    """Publication gate for the Fig-8 loop: the compressed stream must
    reproduce dense inference BIT-EXACTLY on the probe inputs before it may
    be shipped to a live accelerator.  Decodes ``model`` back to an action
    mask and compares ``batch_class_sums`` against the original ``actions``
    (ordinal equality is too strict — empty clauses are legitimately
    dropped at encode time).  Raises ``ValueError`` on any mismatch.
    """
    import jax.numpy as jnp

    from .tm import batch_class_sums, state_from_actions

    decoded = decode(model)
    s_dense = batch_class_sums(
        cfg, state_from_actions(cfg, actions), jnp.asarray(X)
    )
    s_stream = batch_class_sums(
        cfg, state_from_actions(cfg, decoded), jnp.asarray(X)
    )
    if not bool(jnp.array_equal(s_dense, s_stream)):
        bad = int(jnp.sum(jnp.any(s_dense != s_stream, axis=1)))
        raise ValueError(
            f"compressed stream is not bit-exact against the dense oracle: "
            f"{bad}/{X.shape[0]} probe datapoints disagree — refusing to "
            f"publish the model"
        )


def decode(model: CompressedModel) -> np.ndarray:
    """Instruction stream -> dense include actions bool[M, C, 2F].

    Clause ordinals are re-assigned densely per class (empty clauses were
    skipped at encode time): + clauses to even slots, - clauses to odd slots,
    restoring polarity semantics exactly (verified by property tests).
    """
    M, C, F = model.n_classes, model.n_clauses, model.n_features
    acts = np.zeros((M, C, 2 * F), dtype=bool)
    next_even = np.zeros(M, dtype=np.int64)
    next_odd = np.ones(M, dtype=np.int64)

    cls = -1
    slot = -1
    content = False
    ptr = 0
    prev_e, prev_cc = 0, 0
    for ins in model.instructions.tolist():
        e = (ins >> E_BIT) & 1
        cc = (ins >> CC_BIT) & 1
        p = (ins >> P_BIT) & 1
        off = ins & OFF_MASK
        if cc != prev_cc or e != prev_e:  # boundary
            if e != prev_e:
                cls += 1
            prev_e, prev_cc = e, cc
            ptr = 0
            content = False
            slot = -1
        if off == EXTEND:
            ptr += EXTEND
            continue
        if not content:
            if p == 1:
                slot = int(next_even[cls])
                next_even[cls] += 2
            else:
                slot = int(next_odd[cls])
                next_odd[cls] += 2
            content = True
        ptr = ptr + off
        acts[cls, slot, ptr] = True
    return acts


# ---------------------------------------------------------------------------
# Decoded execution plan (beyond-paper optimized path; see interp.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodedPlan:
    """Offset chains prefix-summed into absolute indices (done ONCE at
    program time).  Inference then becomes gather + segmented reduction —
    fully parallel, unlike the paper's 4-cycle/instruction pipeline."""

    lit_idx: np.ndarray  # int32[I']  absolute literal slot in [0, 2F)
    clause_id: np.ndarray  # int32[I'] global clause id (dense numbering)
    clause_class: np.ndarray  # int32[Ncl] class of each global clause
    clause_pol: np.ndarray  # int32[Ncl] +1 / -1
    n_classes: int
    n_features: int

    @property
    def n_includes(self) -> int:
        return int(self.lit_idx.shape[0])

    @property
    def n_clauses_total(self) -> int:
        return int(self.clause_pol.shape[0])

    def clauses_per_class(self, n_classes: int | None = None) -> np.ndarray:
        """int64[M] non-empty clauses per class — the clause-table depth a
        deployment must provision (capacity negotiation reads its max)."""
        m = self.n_classes if n_classes is None else n_classes
        return np.bincount(self.clause_class, minlength=m)

    def includes_per_clause(self) -> np.ndarray:
        """int64[Ncl] includes per (non-empty) clause — the include-slot
        width a clause-major layout must provision."""
        if self.n_clauses_total == 0:
            return np.zeros(0, dtype=np.int64)
        return np.bincount(self.clause_id, minlength=self.n_clauses_total)


def decode_to_plan(model: CompressedModel) -> DecodedPlan:
    """Walk the stream once on the host, materializing absolute indices."""
    lit_idx: List[int] = []
    clause_id: List[int] = []
    clause_class: List[int] = []
    clause_pol: List[int] = []

    cls = -1
    cur_clause = -1
    content = False
    ptr = 0
    prev_e, prev_cc = 0, 0
    for ins in model.instructions.tolist():
        e = (ins >> E_BIT) & 1
        cc = (ins >> CC_BIT) & 1
        p = (ins >> P_BIT) & 1
        off = ins & OFF_MASK
        if cc != prev_cc or e != prev_e:  # boundary
            if e != prev_e:
                cls += 1
            prev_e, prev_cc = e, cc
            ptr = 0
            content = False
        if off == EXTEND:
            ptr += EXTEND
            continue
        if not content:
            cur_clause += 1
            clause_class.append(cls)
            clause_pol.append(1 if p == 1 else -1)
            content = True
        ptr = ptr + off
        lit_idx.append(ptr)
        clause_id.append(cur_clause)
    return DecodedPlan(
        lit_idx=np.asarray(lit_idx, dtype=np.int32),
        clause_id=np.asarray(clause_id, dtype=np.int32),
        clause_class=np.asarray(clause_class, dtype=np.int32),
        clause_pol=np.asarray(clause_pol, dtype=np.int32),
        n_classes=model.n_classes,
        n_features=model.n_features,
    )
