"""Include-only instruction compression (paper §2, Fig 3.4).

A trained TM is ~99% Excludes; only Include TAs matter for inference.  The
model is compressed to a stream of 16-bit *Include Instructions*:

      bit 15 : E   — toggles when the class changes
      bit 14 : CC  — toggles when the clause changes
      bit 13 : P   — polarity of the clause this include belongs to (1 = +)
      bit 12 : L   — literal is the complement (f̄) iff 1
      bits 11..0 : O — offset (literal slots to advance), 0..4094

Traversal order (Fig 3.3): class-major, then clause, then interleaved literal
slot k (= 2*feature + is_complement), so offsets within a clause are strictly
positive after the first include.  The offset counts *within-clause* slots;
the literal pointer resets to 0 at each clause boundary (the Literal Select
step of Fig 4.5 indexes Feature Memory with the accumulated pointer).

Escape: O == 0xFFF is EXTEND — advance the literal pointer by 4095 slots
without consuming a literal.  An EXTEND may also carry the CC/E boundary
toggles; a clause whose stream consists only of EXTENDs has no content and
contributes nothing (inference semantics: empty clause -> 0).  Encoding a
class with zero includes therefore emits a single boundary EXTEND so the
E-toggle class counter stays aligned (the paper's E bit, generalized).

Interpreter contract (shared by interp.py, runtime.py and the Pallas kernel):
  * boundary  := (CC != prev_CC) or (E != prev_E)
  * on boundary: finalize previous clause (add pol * acc to class sums iff
    any include executed in it), advance class iff E toggled, reset the
    literal pointer and the clause accumulator
  * EXTEND: ptr += 4095, no other effect
  * include: ptr += O; literal = (L ? NOT feature[ptr>>1] : feature[ptr>>1]);
    acc &= literal   (ptr's LSB must equal L — interleaved order)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .tm import TMConfig

E_BIT = 15
CC_BIT = 14
P_BIT = 13
L_BIT = 12
OFF_MASK = 0x0FFF
EXTEND = 0x0FFF  # offset escape: advance 4095 slots, consume nothing
MAX_OFF = OFF_MASK - 1  # 4094


@dataclasses.dataclass(frozen=True)
class CompressedModel:
    """The programmable artifact: what the Fig-8 training node ships.

    ``clause_weights`` (repro.prune, ETHEREAL-style weighted clauses) is an
    optional int vector with ONE entry per non-empty clause in stream
    emission order: the clause's vote is ``weight * pol`` instead of
    ``pol``.  ``None`` is the classic weightless model — every pre-prune
    artifact and every v1 wire blob stays exactly what it was."""

    instructions: np.ndarray  # uint16[I]
    n_classes: int
    n_clauses: int  # clauses per class (accumulator bound, Fig 4.6)
    n_features: int  # Boolean features (feature-memory depth)
    clause_weights: Optional[np.ndarray] = None  # uint16[Ncl'] emission order

    def __post_init__(self):
        if self.clause_weights is not None:
            w = np.asarray(self.clause_weights)
            if w.ndim != 1:
                raise ValueError(
                    f"clause_weights must be a 1-D per-clause vector, got "
                    f"shape {w.shape}"
                )
            if w.size and (w.min() < 1 or w.max() > 0xFFFF):
                raise ValueError(
                    "clause_weights must be integers in [1, 65535] (a zero "
                    "weight is a pruned clause — drop it from the stream "
                    "instead)"
                )
            object.__setattr__(
                self, "clause_weights", w.astype(np.uint16)
            )

    @property
    def n_instructions(self) -> int:
        return int(self.instructions.shape[0])

    @property
    def weighted(self) -> bool:
        return self.clause_weights is not None

    @property
    def n_weights(self) -> int:
        return 0 if self.clause_weights is None else int(
            self.clause_weights.shape[0]
        )

    @property
    def weight_planes(self) -> int:
        """Bitplanes the popcount engine needs for this model's weights
        (``max_weight.bit_length()``); 1 for weightless models — weight 1
        is the implicit plane-0-only case, so the weightless and
        all-weights-1 programs cost the same."""
        if self.clause_weights is None or self.clause_weights.size == 0:
            return 1
        return int(self.clause_weights.max()).bit_length()

    @property
    def n_bytes(self) -> int:
        return (self.n_instructions + self.n_weights) * 2

    def compression_ratio(self, cfg: TMConfig) -> float:
        """Fraction of the dense 1-bit-per-TA model eliminated (paper: ~99%)."""
        dense_bits = cfg.n_tas
        return 1.0 - (self.n_bytes * 8) / dense_bits


def _emit(e: int, cc: int, p: int, lbit: int, off: int) -> int:
    return (e << E_BIT) | (cc << CC_BIT) | (p << P_BIT) | (lbit << L_BIT) | off


def encode(
    cfg: TMConfig,
    actions: np.ndarray,
    clause_weights: Optional[np.ndarray] = None,
) -> CompressedModel:
    """Dense include actions bool[M, C, 2F] -> compressed instruction stream.

    ``clause_weights`` (optional int[M, C], the repro.prune weighted-clause
    output) rides along per NON-EMPTY clause in emission order.  An
    all-ones weight matrix normalizes back to a weightless model, so the
    prune pipeline never inflates an artifact that gained nothing from
    weighting (and the v1 wire format keeps covering it)."""
    actions = np.asarray(actions, dtype=bool)
    M, C, L2 = actions.shape
    assert (M, C, L2) == (cfg.n_classes, cfg.n_clauses, cfg.n_literals)
    if clause_weights is not None:
        clause_weights = np.asarray(clause_weights)
        if clause_weights.shape != (M, C):
            raise ValueError(
                f"clause_weights must be int[{M}, {C}] (one weight per "
                f"clause slot), got shape {clause_weights.shape}"
            )

    out: List[int] = []
    weights: List[int] = []
    e_tog, cc_tog = 0, 0  # current toggle levels
    for m in range(M):
        new_class = True
        if not actions[m].any():
            # class with zero includes: lone boundary EXTEND advances E
            e_tog ^= 1
            cc_tog ^= 1
            out.append(_emit(e_tog, cc_tog, 0, 0, EXTEND))
            continue
        for j in range(C):
            ks = np.flatnonzero(actions[m, j])
            if ks.size == 0:
                continue  # empty clause: contributes 0 at inference; skip
            pol = 1 if j % 2 == 0 else 0
            cc_tog ^= 1
            if new_class:
                e_tog ^= 1
                new_class = False
            if clause_weights is not None:
                weights.append(int(clause_weights[m, j]))
            ptr = 0
            for k in ks.tolist():
                delta = int(k) - ptr
                while delta > MAX_OFF:
                    out.append(_emit(e_tog, cc_tog, pol, 0, EXTEND))
                    delta -= EXTEND
                out.append(_emit(e_tog, cc_tog, pol, int(k) & 1, delta))
                ptr = int(k)
    wvec = None
    if clause_weights is not None and any(w != 1 for w in weights):
        wvec = np.asarray(weights, dtype=np.uint16)
    return CompressedModel(
        instructions=np.asarray(out, dtype=np.uint16),
        n_classes=M,
        n_clauses=C,
        n_features=cfg.n_features,
        clause_weights=wvec,
    )


def validate_roundtrip(
    cfg: TMConfig,
    actions: np.ndarray,
    model: CompressedModel,
    X: np.ndarray,
    clause_weights: Optional[np.ndarray] = None,
) -> None:
    """Publication gate for the Fig-8 loop: the compressed stream must
    reproduce dense inference BIT-EXACTLY on the probe inputs before it may
    be shipped to a live accelerator.  Decodes ``model`` back to an action
    mask (plus per-slot weights for weighted streams) and compares
    ``batch_class_sums`` against the original ``actions`` (ordinal equality
    is too strict — empty clauses are legitimately dropped at encode time).
    ``clause_weights`` (int[M, C]) is the weight matrix the reference side
    votes with; ``None`` means unit weights.  Raises ``ValueError`` on any
    mismatch.

    Degenerate streams fail CLEANLY: a stream that is structurally
    inconsistent with the model dims (e.g. a prune pass dropped every
    clause of a class without leaving the boundary EXTEND, so class
    alignment slipped past ``n_classes``) is a structured publication
    refusal, not an ``IndexError`` from deep inside the decoder.  A
    well-formed stream whose class has zero clauses (the lone boundary
    EXTEND) is a legitimate model and PASSES.
    """
    import jax.numpy as jnp

    from .tm import batch_class_sums_weighted, state_from_actions

    try:
        decoded, dec_w = decode_weights(model)
    except ValueError as err:
        raise ValueError(
            f"compressed stream failed to decode against its own dims "
            f"(n_classes={model.n_classes}, n_clauses={model.n_clauses}, "
            f"n_features={model.n_features}): {err} — refusing to publish "
            f"the model"
        ) from err
    ref_w = None
    if clause_weights is not None:
        ref_w = jnp.asarray(np.asarray(clause_weights), jnp.int32)
    s_dense = batch_class_sums_weighted(
        cfg, state_from_actions(cfg, actions), jnp.asarray(X), weights=ref_w
    )
    s_stream = batch_class_sums_weighted(
        cfg, state_from_actions(cfg, decoded), jnp.asarray(X),
        weights=jnp.asarray(dec_w, jnp.int32),
    )
    if not bool(jnp.array_equal(s_dense, s_stream)):
        bad = int(jnp.sum(jnp.any(s_dense != s_stream, axis=1)))
        raise ValueError(
            f"compressed stream is not bit-exact against the dense oracle: "
            f"{bad}/{X.shape[0]} probe datapoints disagree — refusing to "
            f"publish the model"
        )


def _decode_walk(model: CompressedModel) -> Tuple[np.ndarray, np.ndarray]:
    """Shared stream walk -> (actions bool[M, C, 2F], weights int32[M, C]).

    Validates the stream against the model dims as it walks — every
    structural inconsistency is a ``ValueError`` naming the offending
    instruction (the satellite fix: a degenerate stream must be a clean
    publication refusal, never an ``IndexError``):

      * more class boundaries (E toggles) than ``n_classes``
      * an include before the first class boundary
      * a class accumulating more +/- clauses than ``n_clauses`` slots
      * a literal pointer outside the ``2 * n_features`` slots
      * a weight vector whose length disagrees with the non-empty clause
        count
    """
    M, C, F = model.n_classes, model.n_clauses, model.n_features
    acts = np.zeros((M, C, 2 * F), dtype=bool)
    weights = np.ones((M, C), dtype=np.int32)
    wvec = model.clause_weights
    next_even = np.zeros(M, dtype=np.int64)
    next_odd = np.ones(M, dtype=np.int64)

    cls = -1
    slot = -1
    content = False
    n_emitted = 0
    ptr = 0
    prev_e, prev_cc = 0, 0
    for t, ins in enumerate(model.instructions.tolist()):
        e = (ins >> E_BIT) & 1
        cc = (ins >> CC_BIT) & 1
        p = (ins >> P_BIT) & 1
        off = ins & OFF_MASK
        if cc != prev_cc or e != prev_e:  # boundary
            if e != prev_e:
                cls += 1
                if cls >= M:
                    raise ValueError(
                        f"instruction {t}: stream advances to class {cls} "
                        f"but the model declares n_classes={M} (class "
                        f"alignment slipped — a pruned-away class must "
                        f"still emit its boundary EXTEND)"
                    )
            prev_e, prev_cc = e, cc
            ptr = 0
            content = False
            slot = -1
        if off == EXTEND:
            ptr += EXTEND
            continue
        if cls < 0:
            raise ValueError(
                f"instruction {t}: include before the first class boundary "
                f"(the stream must open with an E/CC toggle)"
            )
        if not content:
            if p == 1:
                slot = int(next_even[cls])
                next_even[cls] += 2
            else:
                slot = int(next_odd[cls])
                next_odd[cls] += 2
            if slot >= C:
                pol_name = "positive" if p == 1 else "negative"
                raise ValueError(
                    f"instruction {t}: class {cls} holds more {pol_name} "
                    f"clauses than the declared n_clauses={C} provides "
                    f"slots for"
                )
            if wvec is not None:
                if n_emitted >= wvec.shape[0]:
                    raise ValueError(
                        f"instruction {t}: stream emits more non-empty "
                        f"clauses than the {wvec.shape[0]}-entry weight "
                        f"vector covers"
                    )
                weights[cls, slot] = int(wvec[n_emitted])
            n_emitted += 1
            content = True
        ptr = ptr + off
        if ptr >= 2 * F:
            raise ValueError(
                f"instruction {t}: literal slot {ptr} out of range for "
                f"n_features={F} ({2 * F} interleaved slots)"
            )
        acts[cls, slot, ptr] = True
    if wvec is not None and n_emitted != wvec.shape[0]:
        raise ValueError(
            f"weight vector carries {wvec.shape[0]} entries but the stream "
            f"emits {n_emitted} non-empty clauses"
        )
    return acts, weights


def decode(model: CompressedModel) -> np.ndarray:
    """Instruction stream -> dense include actions bool[M, C, 2F].

    Clause ordinals are re-assigned densely per class (empty clauses were
    skipped at encode time): + clauses to even slots, - clauses to odd slots,
    restoring polarity semantics exactly (verified by property tests).
    """
    acts, _ = _decode_walk(model)
    return acts


def decode_weights(model: CompressedModel) -> Tuple[np.ndarray, np.ndarray]:
    """Stream -> (actions bool[M, C, 2F], clause weights int32[M, C]).

    The weights land in the same re-assigned clause slots as ``decode``
    places the includes in; weightless models (and empty slots) get 1."""
    return _decode_walk(model)


# ---------------------------------------------------------------------------
# Decoded execution plan (beyond-paper optimized path; see interp.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodedPlan:
    """Offset chains prefix-summed into absolute indices (done ONCE at
    program time).  Inference then becomes gather + segmented reduction —
    fully parallel, unlike the paper's 4-cycle/instruction pipeline."""

    lit_idx: np.ndarray  # int32[I']  absolute literal slot in [0, 2F)
    clause_id: np.ndarray  # int32[I'] global clause id (dense numbering)
    clause_class: np.ndarray  # int32[Ncl] class of each global clause
    clause_pol: np.ndarray  # int32[Ncl] +1 / -1
    n_classes: int
    n_features: int
    clause_weight: Optional[np.ndarray] = None  # int32[Ncl]; None = all 1

    @property
    def n_includes(self) -> int:
        return int(self.lit_idx.shape[0])

    @property
    def n_clauses_total(self) -> int:
        return int(self.clause_pol.shape[0])

    @property
    def weights(self) -> np.ndarray:
        """int32[Ncl] per-clause vote weights (ones when weightless)."""
        if self.clause_weight is not None:
            return self.clause_weight
        return np.ones(self.n_clauses_total, dtype=np.int32)

    @property
    def weighted_pol(self) -> np.ndarray:
        """int32[Ncl] ``weight * pol`` — what the multiply-capable engines
        (plan / sharded) fold straight into their polarity operand, so
        weighted execution is the SAME kernel at weight 1."""
        return (self.clause_pol * self.weights).astype(np.int32)

    @property
    def weight_planes(self) -> int:
        """Bitplanes the popcount reduction needs (1 when weightless)."""
        if self.clause_weight is None or self.clause_weight.size == 0:
            return 1
        return int(self.clause_weight.max()).bit_length()

    def clauses_per_class(self, n_classes: int | None = None) -> np.ndarray:
        """int64[M] non-empty clauses per class — the clause-table depth a
        deployment must provision (capacity negotiation reads its max)."""
        m = self.n_classes if n_classes is None else n_classes
        return np.bincount(self.clause_class, minlength=m)

    def includes_per_clause(self) -> np.ndarray:
        """int64[Ncl] includes per (non-empty) clause — the include-slot
        width a clause-major layout must provision."""
        if self.n_clauses_total == 0:
            return np.zeros(0, dtype=np.int64)
        return np.bincount(self.clause_id, minlength=self.n_clauses_total)


def decode_to_plan(model: CompressedModel) -> DecodedPlan:
    """Walk the stream once on the host, materializing absolute indices."""
    lit_idx: List[int] = []
    clause_id: List[int] = []
    clause_class: List[int] = []
    clause_pol: List[int] = []
    wvec = model.clause_weights

    cls = -1
    cur_clause = -1
    content = False
    ptr = 0
    prev_e, prev_cc = 0, 0
    for ins in model.instructions.tolist():
        e = (ins >> E_BIT) & 1
        cc = (ins >> CC_BIT) & 1
        p = (ins >> P_BIT) & 1
        off = ins & OFF_MASK
        if cc != prev_cc or e != prev_e:  # boundary
            if e != prev_e:
                cls += 1
            prev_e, prev_cc = e, cc
            ptr = 0
            content = False
        if off == EXTEND:
            ptr += EXTEND
            continue
        if not content:
            cur_clause += 1
            clause_class.append(cls)
            clause_pol.append(1 if p == 1 else -1)
            content = True
        ptr = ptr + off
        lit_idx.append(ptr)
        clause_id.append(cur_clause)
    n_emitted = len(clause_pol)
    if wvec is not None and n_emitted != wvec.shape[0]:
        raise ValueError(
            f"weight vector carries {wvec.shape[0]} entries but the stream "
            f"emits {n_emitted} non-empty clauses"
        )
    return DecodedPlan(
        lit_idx=np.asarray(lit_idx, dtype=np.int32),
        clause_id=np.asarray(clause_id, dtype=np.int32),
        clause_class=np.asarray(clause_class, dtype=np.int32),
        clause_pol=np.asarray(clause_pol, dtype=np.int32),
        n_classes=model.n_classes,
        n_features=model.n_features,
        clause_weight=(
            None if wvec is None else wvec.astype(np.int32)
        ),
    )
