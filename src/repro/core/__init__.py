"""The paper's primary contribution: runtime-tunable compressed TM inference.

Layers (bottom-up):
  tm.py          dense Tsetlin Machine model + bitpacked batch inference
  booleanize.py  raw features -> Boolean features
  train.py       Type I/II feedback training (the Fig-8 "training node")
  compress.py    Include-only 16-bit instruction encoding (Fig 3.4)
  interp.py      compressed inference: faithful scan interpreter +
                 decoded-plan parallel executor (beyond-paper)
  runtime.py     stream protocol (headers, Fig 4.1-4.3) + fixed-capacity
                 Accelerator with zero-recompile model swap + class-sharded
                 multi-core execution
"""

from .tm import (
    TMConfig,
    init_state,
    include_actions,
    state_from_actions,
    literals,
    clause_outputs,
    clause_polarities,
    class_sums,
    predict,
    predict_weighted,
    batch_class_sums,
    batch_class_sums_weighted,
    pack_literals,
    unpack_bits,
    packed_class_sums,
    dense_model_bytes,
)
from .train import (
    accuracy,
    fit,
    fit_step,
    sample_class_delta,
    sample_keys,
    train_batch,
    train_batch_parallel,
)
from .booleanize import Booleanizer, booleanize_images

__all__ = [
    "TMConfig",
    "init_state",
    "include_actions",
    "state_from_actions",
    "literals",
    "clause_outputs",
    "clause_polarities",
    "class_sums",
    "predict",
    "predict_weighted",
    "batch_class_sums",
    "batch_class_sums_weighted",
    "pack_literals",
    "unpack_bits",
    "packed_class_sums",
    "dense_model_bytes",
    "train_batch",
    "train_batch_parallel",
    "fit",
    "fit_step",
    "sample_keys",
    "sample_class_delta",
    "accuracy",
    "Booleanizer",
    "booleanize_images",
]
