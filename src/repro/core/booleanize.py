"""Booleanization: raw features -> Boolean features (paper Fig 2, top).

Two standard schemes used across the TM literature:
  * threshold: per-feature mean/quantile thresholding -> 1 bit/feature
  * thermometer: per-feature quantile bins, unary ("thermometer") code ->
    ``bits`` bits/feature — the scheme REDRESS [15] and MATADOR [18] use for
    the UCI edge datasets.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Booleanizer:
    """Fitted thermometer/threshold booleanizer (host-side, NumPy)."""

    thresholds: np.ndarray  # [F_raw, bits]
    bits: int

    @property
    def n_boolean_features(self) -> int:
        return self.thresholds.shape[0] * self.bits

    @staticmethod
    def fit(x: np.ndarray, bits: int = 1) -> "Booleanizer":
        """x: float[N, F_raw]; quantile thermometer with ``bits`` levels."""
        qs = np.linspace(0.0, 1.0, bits + 2)[1:-1]  # interior quantiles
        th = np.quantile(x, qs, axis=0).T  # [F_raw, bits]
        return Booleanizer(thresholds=np.ascontiguousarray(th), bits=bits)

    def transform(self, x: np.ndarray) -> np.ndarray:
        """float[N, F_raw] -> uint8[N, F_raw*bits] in {0,1}."""
        b = (x[:, :, None] > self.thresholds[None, :, :]).astype(np.uint8)
        return b.reshape(x.shape[0], -1)


def booleanize_images(x: np.ndarray, threshold: float = 0.3) -> np.ndarray:
    """MNIST-style fixed-threshold booleanization (paper's MNIST example)."""
    return (x > threshold).astype(np.uint8)


def to_device_bool(x: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.bool_)
