"""Dense (vanilla) Tsetlin Machine model in JAX.

The TM model for M classes, C clauses/class, F Boolean features:
  * TA state tensor  S  : int32[M, C, 2F]   in [1, 2N]   (N = ``n_states``)
  * include action   A  : bool [M, C, 2F]   A = S > N
  * literal order is **interleaved**: slot k corresponds to feature k>>1,
    complemented iff k&1 == 1.  This keeps within-clause include offsets
    strictly positive for the compressed encoding (see compress.py).

Clause semantics:
  train:     empty clause (no includes) outputs 1
  inference: empty clause outputs 0
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TMConfig:
    n_classes: int
    n_clauses: int          # clauses per class; polarity alternates +,-,+,-,...
    n_features: int         # Boolean features (literals = 2 * n_features)
    n_states: int = 128     # per-action state count N; S in [1, 2N]
    threshold: int = 15     # T
    specificity: float = 3.9  # s
    boost_true_positive: bool = True

    @property
    def n_literals(self) -> int:
        return 2 * self.n_features

    @property
    def n_tas(self) -> int:
        return self.n_classes * self.n_clauses * self.n_literals


def init_state(cfg: TMConfig, key: Array) -> Array:
    """TA states start on the Exclude side of the decision boundary (= N)."""
    del key  # deterministic init; kept for interface symmetry
    return jnp.full(
        (cfg.n_classes, cfg.n_clauses, cfg.n_literals), cfg.n_states, dtype=jnp.int32
    )


def include_actions(cfg: TMConfig, state: Array) -> Array:
    """bool[M, C, 2F] — True where the TA action is Include."""
    return state > cfg.n_states


def state_from_actions(cfg: TMConfig, actions) -> Array:
    """Minimal TA state tensor realizing the given include mask — the
    inverse of ``include_actions`` (tests/benches build models straight
    from action masks with it)."""
    a = jnp.asarray(actions, dtype=jnp.bool_)
    return jnp.where(a, cfg.n_states + 1, cfg.n_states).astype(jnp.int32)


def literals(x: Array) -> Array:
    """Boolean features -> interleaved literals.

    x: bool/int {0,1}[..., F]  ->  {0,1}[..., 2F] with slot 2k = x_k,
    slot 2k+1 = NOT x_k.
    """
    x = x.astype(jnp.bool_)
    inter = jnp.stack([x, ~x], axis=-1)  # [..., F, 2]
    return inter.reshape(*x.shape[:-1], x.shape[-1] * 2)


def clause_outputs(
    cfg: TMConfig, actions: Array, lits: Array, *, training: bool
) -> Array:
    """Clause outputs for one datapoint.

    actions: bool[M, C, 2F]; lits: bool[2F]  ->  bool[M, C]
    """
    # A clause fires iff every included literal is 1.
    sat = jnp.all(jnp.where(actions, lits, True), axis=-1)  # [M, C]
    nonempty = jnp.any(actions, axis=-1)  # [M, C]
    if training:
        return sat
    return sat & nonempty


def clause_polarities(cfg: TMConfig) -> Array:
    """int32[C]: +1 for even clause index, -1 for odd."""
    idx = jnp.arange(cfg.n_clauses)
    return jnp.where(idx % 2 == 0, 1, -1).astype(jnp.int32)


def class_sums(cfg: TMConfig, actions: Array, lits: Array, *, training: bool) -> Array:
    """int32[M] class sums for one datapoint."""
    c = clause_outputs(cfg, actions, lits, training=training).astype(jnp.int32)
    pol = clause_polarities(cfg)
    return jnp.sum(c * pol[None, :], axis=-1)


@partial(jax.jit, static_argnums=0)
def predict(cfg: TMConfig, state: Array, x: Array) -> Array:
    """Batched dense prediction. x: {0,1}[B, F] -> int32[B] class ids."""
    actions = include_actions(cfg, state)
    lits = literals(x)  # [B, 2F]
    sums = jax.vmap(
        lambda row: class_sums(cfg, actions, row, training=False)
    )(lits)  # [B, M]
    return jnp.argmax(sums, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnums=0)
def batch_class_sums(cfg: TMConfig, state: Array, x: Array) -> Array:
    """int32[B, M] inference-semantics class sums (oracle for all fast paths)."""
    actions = include_actions(cfg, state)
    lits = literals(x)
    return jax.vmap(
        lambda row: class_sums(cfg, actions, row, training=False)
    )(lits)


@partial(jax.jit, static_argnums=0)
def batch_class_sums_weighted(
    cfg: TMConfig, state: Array, x: Array, weights: "Array | None" = None
) -> Array:
    """int32[B, M] class sums with per-clause vote weights (repro.prune).

    ``weights`` is int[M, C]; each clause votes ``weight * pol`` instead of
    ``pol``.  ``None`` (or all-ones) is exactly ``batch_class_sums`` — this
    is THE oracle the weighted engines are property-tested against."""
    actions = include_actions(cfg, state)
    lits = literals(x)
    pol = clause_polarities(cfg)[None, :]  # [1, C]
    vote = pol if weights is None else weights.astype(jnp.int32) * pol

    def one(row):
        c = clause_outputs(cfg, actions, row, training=False).astype(jnp.int32)
        return jnp.sum(c * vote, axis=-1)

    return jax.vmap(one)(lits)


def predict_weighted(
    cfg: TMConfig, state: Array, x: Array, weights: "Array | None" = None
) -> Array:
    """Batched weighted prediction: argmax of the weighted class sums."""
    sums = batch_class_sums_weighted(cfg, state, x, weights)
    return jnp.argmax(sums, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Bitpacked inference (paper §3: 32 datapoints per machine word)
# ---------------------------------------------------------------------------

def pack_literals(x: Array) -> Array:
    """Pack the batch dim of literals into uint32 words.

    x: {0,1}[B, F] with B % 32 == 0  ->  uint32[2F, B//32]
    word bit b holds datapoint (w*32 + b).
    """
    lits = literals(x).astype(jnp.uint32)  # [B, 2F]
    B = lits.shape[0]
    assert B % 32 == 0, "batch must be a multiple of 32 for bit packing"
    lits = lits.T.reshape(lits.shape[1], B // 32, 32)  # [2F, W, 32]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lits << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_bits(words: Array) -> Array:
    """uint32[..., W] -> int32[..., W*32] of {0,1}."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * 32).astype(jnp.int32)


@partial(jax.jit, static_argnums=0)
def packed_class_sums(cfg: TMConfig, state: Array, packed_lits: Array) -> Array:
    """Bitpacked dense inference.

    packed_lits: uint32[2F, W]  ->  int32[W*32, M] class sums
    (matches ``batch_class_sums`` exactly for the packing in pack_literals).
    """
    actions = include_actions(cfg, state)  # [M, C, 2F]
    ones = jnp.uint32(0xFFFFFFFF)

    # acc[m, c, w] = AND over included k of packed_lits[k, w]
    def clause_word(a_row):  # a_row: bool[2F]
        masked = jnp.where(a_row[:, None], packed_lits, ones)  # [2F, W]
        # AND-reduce over literals via bitwise_and reduction
        return jax.lax.reduce(
            masked, ones, jnp.bitwise_and, dimensions=(0,)
        )  # [W]

    acc = jax.vmap(jax.vmap(clause_word))(actions)  # [M, C, W]
    nonempty = jnp.any(actions, axis=-1)  # [M, C]
    acc = jnp.where(nonempty[..., None], acc, jnp.uint32(0))
    bits = unpack_bits(acc)  # [M, C, B]
    pol = clause_polarities(cfg)
    sums = jnp.sum(bits * pol[None, :, None], axis=1)  # [M, B]
    return sums.T  # [B, M]


def dense_model_bytes(cfg: TMConfig) -> int:
    """Uncompressed model footprint: 1 bit per TA action."""
    return (cfg.n_tas + 7) // 8
