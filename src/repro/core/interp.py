"""Compressed-domain TM inference engines.

Two execution strategies over the SAME instruction stream (compress.py):

1. ``interpret_stream`` — the paper-faithful interpreter.  A ``lax.scan``
   walks the stream exactly like the eFPGA's fetch/decode/select/accumulate
   pipeline (Fig 4.4-4.6, Fig 5): one instruction per step, a literal
   pointer register, a clause-output accumulator of ``W`` bit-packed words
   (32 datapoints per word, the paper's batching), class-sum accumulators,
   and toggle-bit boundary detection.  Buffers are FIXED CAPACITY with
   dynamic counts, so the jitted program never recompiles when the model,
   task, or input dimensionality changes — the JAX analog of "no offline
   resynthesis".

2. ``plan_class_sums`` — the beyond-paper *decoded-plan* executor.  The
   offset chains are prefix-summed once at program time (compress.decode_to_plan);
   inference is then a literal gather + segmented AND (min) + segmented
   polarity sum, which is embarrassingly parallel across instructions AND
   datapoints — the TPU-native reformulation of the sequential pipeline.

Both match dense inference (tm.batch_class_sums) bit-exactly; property tests
enforce it.

jit policy (the serving-executor contract): every hot helper here is jitted
at module level with STATIC capacity arguments only — capacities are
synthesis-time constants, so each deployment compiles exactly once.  Buffer
donation is deliberately NOT annotated on these shared engines: callers
(benchmarks, tests, notebooks) legitimately reuse operand buffers across
calls, which donation would invalidate.  The serving executors wrap
``.__wrapped__`` in their own private jit and donate their per-call staging
buffers there instead (serve_tm/executors.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .compress import CC_BIT, E_BIT, EXTEND, L_BIT, OFF_MASK, P_BIT
from .tm import unpack_bits

Array = jax.Array


@partial(jax.jit, static_argnames=("n_feature_cap", "n_word_cap"))
def pack_features(x: Array, n_feature_cap: int, n_word_cap: int) -> Array:
    """{0,1}[B, F] -> uint32[F_cap, W_cap] feature memory (bit b of word w =
    datapoint w*32+b).  B must be <= 32*W_cap; F <= F_cap.

    jitted with static capacities (the executor contract: capacities are
    synthesis-time constants, so this compiles once per deployment).  The
    shape checks below are trace-time and therefore free per call."""
    x = x.astype(jnp.uint32)
    B, F = x.shape
    if F > n_feature_cap:
        raise ValueError(
            f"input dimensionality F={F} exceeds feature capacity "
            f"{n_feature_cap}; resynthesize with a larger feature_capacity"
        )
    if B > 32 * n_word_cap:
        raise ValueError(
            f"batch B={B} exceeds the {32 * n_word_cap} datapoints of "
            f"batch_words={n_word_cap}; stream in chunks or resynthesize "
            f"with more batch_words"
        )
    W = (B + 31) // 32
    pad_b = W * 32 - B
    xp = jnp.pad(x, ((0, pad_b), (0, n_feature_cap - F)))  # [W*32, F_cap]
    xp = xp.T.reshape(n_feature_cap, W, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words = jnp.sum(xp << shifts[None, None, :], axis=-1, dtype=jnp.uint32)
    return jnp.pad(words, ((0, 0), (0, n_word_cap - W)))


# ---------------------------------------------------------------------------
# 1. Paper-faithful stream interpreter
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("m_cap",))
def interpret_stream(
    instructions: Array,  # uint16[I_cap]  instruction memory
    n_instructions: Array,  # int32 scalar   (Instruction Header field)
    packed_features: Array,  # uint32[F_cap, W] feature memory
    n_datapoints: Array,  # int32 scalar   (Feature Header field)
    clause_weights: "Array | None" = None,  # int32[>=Ncl'] emission order
    *,
    m_cap: int,  # class-sum accumulator depth ("synthesis-time" choice)
) -> Array:
    """Execute the compressed model -> int32[m_cap, W*32] class sums.

    Rows >= the stream's class count stay 0; datapoint columns >=
    n_datapoints are garbage (caller slices).  Mirrors the hardware: the
    accumulator bank is physically m_cap deep regardless of the model.

    ``clause_weights`` (optional, repro.prune weighted clauses) holds one
    int32 vote weight per NON-EMPTY clause in stream emission order — the
    same order the interpreter finalizes clauses in, so a carry-held
    ordinal counter indexes it directly.  Lone boundary EXTENDs (empty
    classes) never finalize a non-empty clause and so never consume an
    ordinal.  ``None`` votes ``pol`` exactly as before.
    """
    i_cap = instructions.shape[0]
    f_cap, w = packed_features.shape
    B = w * 32
    ones = jnp.uint32(0xFFFFFFFF)

    def weight_at(wi):
        if clause_weights is None:
            return jnp.int32(1)
        return clause_weights[jnp.clip(wi, 0, clause_weights.shape[0] - 1)]

    def finalize(sums, cls, pol, acc, gate, wi):
        """Scatter-add the finished clause iff ``gate``.

        The contribution is zeroed by the gate rather than selecting
        between two whole sum banks (the old ``jnp.where(boundary,
        sums.at[...], sums)`` materialized and re-derived the full
        [m_cap, B] bank every step — dead work on non-boundary steps)."""
        vote = pol * weight_at(wi)
        contrib = jnp.where(gate, vote, 0) * unpack_bits(acc)  # [B]
        return sums.at[cls].add(contrib)

    def step(carry, i):
        (ptr, cls, pol, acc, nonempty, prev_e, prev_cc, wi, sums) = carry
        ins = instructions[i].astype(jnp.uint32)
        active = i < n_instructions

        e = (ins >> E_BIT) & 1
        cc = (ins >> CC_BIT) & 1
        p = (ins >> P_BIT) & 1
        lbit = (ins >> L_BIT) & 1
        off = (ins & OFF_MASK).astype(jnp.int32)

        boundary = active & ((e != prev_e) | (cc != prev_cc))
        finalized = boundary & nonempty
        # finalize previous clause on boundary (single gated scatter-add)
        sums = finalize(sums, cls, pol, acc, finalized, wi)
        wi = wi + finalized.astype(jnp.int32)
        cls = jnp.where(boundary & (e != prev_e), cls + 1, cls)
        ptr = jnp.where(boundary, 0, ptr)
        acc = jnp.where(boundary, ones, acc)
        nonempty = jnp.where(boundary, False, nonempty)
        pol = jnp.where(boundary, jnp.where(p == 1, 1, -1).astype(jnp.int32), pol)
        prev_e = jnp.where(active, e, prev_e)
        prev_cc = jnp.where(active, cc, prev_cc)

        is_ext = off == EXTEND
        do_inc = active & ~is_ext
        ptr = ptr + jnp.where(active, jnp.where(is_ext, EXTEND, off), 0)
        feat = jnp.clip(ptr >> 1, 0, f_cap - 1)
        word = packed_features[feat]  # [W] uint32 — Literal Select (Fig 4.5)
        lit = jnp.where(lbit == 1, ~word, word)
        acc = jnp.where(do_inc, acc & lit, acc)
        nonempty = nonempty | do_inc
        return (ptr, cls, pol, acc, nonempty, prev_e, prev_cc, wi, sums), None

    sums0 = jnp.zeros((m_cap, B), dtype=jnp.int32)
    carry0 = (
        jnp.int32(0),  # ptr
        jnp.int32(-1),  # cls (first boundary brings it to 0)
        jnp.int32(1),  # pol
        jnp.full((w,), ones, dtype=jnp.uint32),  # acc
        jnp.bool_(False),  # nonempty
        jnp.uint32(0),  # prev_e
        jnp.uint32(0),  # prev_cc
        jnp.int32(0),  # wi: finalized non-empty clause ordinal
        sums0,
    )
    carry, _ = jax.lax.scan(step, carry0, jnp.arange(i_cap, dtype=jnp.int32))
    ptr, cls, pol, acc, nonempty, _, _, wi, sums = carry
    # end-of-stream: finalize the last clause
    cls = jnp.clip(cls, 0, m_cap - 1)
    sums = finalize(sums, cls, pol, acc, nonempty, wi)
    del n_datapoints  # columns beyond the count are sliced by the caller
    return sums


def interpret_predict(
    instructions: Array,
    n_instructions: Array,
    packed_features: Array,
    n_datapoints: Array,
    n_classes: Array,
    *,
    m_cap: int,
) -> Array:
    """argmax over valid class rows -> int32[B] predictions."""
    sums = interpret_stream(
        instructions, n_instructions, packed_features, n_datapoints, m_cap=m_cap
    )
    valid = jnp.arange(m_cap) < n_classes
    masked = jnp.where(valid[:, None], sums, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(masked, axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# 2. Decoded-plan executor (beyond-paper, parallel)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clause_cap", "m_cap"))
def plan_class_sums(
    lit_idx: Array,  # int32[I_cap] absolute literal slot (padded)
    clause_id: Array,  # int32[I_cap] global clause id; padded slots -> n_clause_cap
    clause_class: Array,  # int32[Ncl_cap] (padded -> m_cap sink row handled below)
    clause_pol: Array,  # int32[Ncl_cap] +1/-1 (padded -> 0)
    lits: Array,  # bool[B, 2F] literal matrix
    *,
    n_clause_cap: int,
    m_cap: int,
) -> Array:
    """Gather + segmented reduction form -> int32[B, m_cap] class sums."""
    sel = jnp.take(lits, lit_idx, axis=1).astype(jnp.int32)  # [B, I]
    # segmented AND == segmented min over {0,1}; padded instructions land in
    # an extra sink segment (id == n_clause_cap) and are dropped.
    clause_out = jax.ops.segment_min(
        sel.T, clause_id, num_segments=n_clause_cap + 1, indices_are_sorted=True
    )[:n_clause_cap]  # [Ncl_cap, B]; empty segments -> int32 max
    has_content = jax.ops.segment_sum(
        jnp.ones_like(clause_id), clause_id, num_segments=n_clause_cap + 1,
        indices_are_sorted=True,
    )[:n_clause_cap] > 0
    clause_out = jnp.where(has_content[:, None], clause_out, 0)
    contrib = clause_out * clause_pol[:, None]  # [Ncl_cap, B]
    sums = jax.ops.segment_sum(
        contrib, jnp.clip(clause_class, 0, m_cap - 1), num_segments=m_cap,
    )  # [m_cap, B]
    return sums.T


def pad_plan(plan, i_cap: int, n_clause_cap: int):
    """Host-side: pad a DecodedPlan to fixed capacities for the jitted path.

    Clause weights (repro.prune) fold straight into the polarity operand
    (``cp = weight * pol``): the segmented reduction is already a
    multiply-accumulate against ``cp``, so weighted execution is the SAME
    compiled program — and bit-identical to the old path at weight 1."""
    import numpy as np

    li = np.zeros(i_cap, dtype=np.int32)
    ci = np.full(i_cap, n_clause_cap, dtype=np.int32)  # sink segment
    li[: plan.n_includes] = plan.lit_idx
    ci[: plan.n_includes] = plan.clause_id
    cc = np.zeros(n_clause_cap, dtype=np.int32)
    cp = np.zeros(n_clause_cap, dtype=np.int32)
    cc[: plan.n_clauses_total] = plan.clause_class
    cp[: plan.n_clauses_total] = plan.weighted_pol
    return li, ci, cc, cp
