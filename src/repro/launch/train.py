"""Distributed training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b-smoke \
        --steps 50 --batch 8 --seq 128 --mesh 1x1 --ckpt /tmp/run1

Integrates: config registry, sharded data pipeline, AdamW, checkpoint/
restart (atomic; exact-resume data state), straggler monitor, optional
gradient compression.  On this CPU container it runs reduced configs; the
same driver lowers the full configs on the production mesh (dry-run).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ShapeSpec
from ..configs.registry import get
from ..data.pipeline import TokenStream, TokenStreamConfig
from ..dist import sharding as shd
from ..dist.steps import make_train_step, opt_config_for
from ..models.api import family_for
from ..optim import adamw
from ..runtime_ft.supervisor import StragglerMonitor


def build(cfg, mesh, *, seq: int, batch: int):
    shd.set_activation_mesh(mesh)
    fam = family_for(cfg)
    shape = ShapeSpec("train_cli", seq, batch, "train")
    p_specs = fam.param_specs(cfg)
    p_sh = shd.param_shardings(cfg, mesh, p_specs)
    opt_cfg = opt_config_for(cfg)
    o_specs = adamw.init_specs(opt_cfg, p_specs)
    o_sh = shd.opt_shardings(cfg, mesh, o_specs, p_sh)
    in_specs = fam.input_specs(cfg, shape)
    in_sh = shd.input_shardings(cfg, mesh, shape, in_specs)
    rep = shd.replicated(mesh)
    step = make_train_step(cfg, opt_cfg, microbatches=cfg.train_microbatches)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, in_sh),
        out_shardings=(p_sh, o_sh, {"loss": rep, "grad_norm": rep}),
        donate_argnums=(0, 1),
    )
    return jitted, p_sh, o_sh, in_sh, opt_cfg, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", type=str, default="1x1", help="DATAxMODEL")
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    jitted, p_sh, o_sh, in_sh, opt_cfg, shape = build(
        cfg, mesh, seq=args.seq, batch=args.batch
    )
    fam = family_for(cfg)

    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    stream = TokenStream(TokenStreamConfig(cfg.vocab, args.seq, args.batch))
    monitor = StragglerMonitor()

    start = 0
    params = jax.device_put(fam.init_params(cfg, jax.random.key(0)), p_sh)
    opt_state = jax.device_put(adamw.init(opt_cfg, params), o_sh)
    if ckpt and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        state = ckpt.restore(
            s, like={"params": params, "opt": opt_state, "data": 0}
        )
        params, opt_state = (
            jax.device_put(state["params"], p_sh),
            jax.device_put(state["opt"], o_sh),
        )
        stream.restore(state["data"])
        start = s
        print(f"[restore] step {s}")

    for step_i in range(start, args.steps):
        t0 = time.time()
        batch = stream.next_batch()
        if "tokens" in batch and cfg.family == "vlm":
            # vlm training consumes patches + shortened token seq
            B = batch["tokens"].shape[0]
            batch = {
                "patches": np.zeros(
                    (B, cfg.n_patches, cfg.d_model), np.float32
                ).astype(np.dtype("bfloat16") if hasattr(np, "bfloat16") else np.float32),
                "tokens": batch["tokens"][:, : args.seq - cfg.n_patches],
            }
        batch = jax.tree.map(
            lambda x, sh: jax.device_put(x, sh), batch, in_sh
        )
        params, opt_state, metrics = jitted(params, opt_state, batch)
        dt = time.time() - t0
        verdict = monitor.observe("host0", dt)
        if verdict != "ok":
            print(f"[straggler] host0 {verdict} ({dt:.2f}s)")
        if (step_i + 1) % args.log_every == 0:
            print(
                f"step {step_i+1}: loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.2f}s)",
                flush=True,
            )
        if ckpt and (step_i + 1) % args.save_every == 0:
            ckpt.save(
                step_i + 1,
                {"params": params, "opt": opt_state, "data": stream.state()},
            )
    print("done")


if __name__ == "__main__":
    main()
