import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
derive roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Outputs one JSON per cell under experiments/dryrun/.
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from ..analysis.roofline import build_roofline, model_flops
from ..configs.base import ShapeSpec, shape_by_name, shapes_for
from ..configs.registry import all_arch_names, get
from ..dist import sharding as shd
from ..dist.steps import make_decode_step, make_prefill_step, make_train_step, opt_config_for
from ..models.api import active_params, family_for
from ..optim import adamw
from .mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _memory_dict(mem) -> dict:
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        try:
            out[k] = float(getattr(mem, k))
        except Exception:
            pass
    return out


class _CompiledCell:
    """Thin adapter over jax's Compiled: ``cost_analysis`` returns one flat
    dict on every jax version (0.4.x returns a one-element list)."""

    def __init__(self, compiled):
        self._compiled = compiled

    def cost_analysis(self):
        from ..analysis.roofline import cost_analysis_dict

        return cost_analysis_dict(self._compiled.cost_analysis())

    def __call__(self, *args, **kwargs):
        return self._compiled(*args, **kwargs)

    def __getattr__(self, name):
        return getattr(self._compiled, name)


class _LoweredCell:
    def __init__(self, lowered):
        self._lowered = lowered

    def compile(self):
        return _CompiledCell(self._lowered.compile())

    def __getattr__(self, name):
        return getattr(self._lowered, name)


def lower_cell(cfg, shape: ShapeSpec, mesh):
    """Build the jitted step for this cell and lower it (abstract only).

    The activation mesh is installed only for the duration of the trace
    (restored on exit) so repeated dry-run cells — or anything jitted later
    in the same process — never see a stale mesh."""
    prev_mesh = shd._ACTIVATION_MESH
    try:
        return _lower_cell(cfg, shape, mesh)
    finally:
        shd.set_activation_mesh(prev_mesh)


def _lower_cell(cfg, shape: ShapeSpec, mesh):
    shd.set_activation_mesh(mesh)
    fam = family_for(cfg)
    p_specs = fam.param_specs(cfg)
    p_sh = shd.param_shardings(cfg, mesh, p_specs)
    in_specs = fam.input_specs(cfg, shape)
    in_sh = shd.input_shardings(cfg, mesh, shape, in_specs)
    rep = shd.replicated(mesh)

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        o_specs = adamw.init_specs(opt_cfg, p_specs)
        o_sh = shd.opt_shardings(cfg, mesh, o_specs, p_sh)
        step = make_train_step(cfg, opt_cfg, microbatches=cfg.train_microbatches)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, in_sh),
            out_shardings=(p_sh, o_sh, {"loss": rep, "grad_norm": rep}),
            donate_argnums=(0, 1),
        )
        with mesh:
            return _LoweredCell(jitted.lower(p_specs, o_specs, in_specs))
    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_sh, in_sh))
        with mesh:
            return _LoweredCell(jitted.lower(p_specs, in_specs))
    # decode
    c_specs = fam.cache_specs(cfg, shape)
    c_sh = shd.cache_shardings(cfg, mesh, shape, c_specs)
    step = make_decode_step(cfg)
    bx = shd.batch_axes(mesh, shape.global_batch)
    from jax.sharding import NamedSharding, PartitionSpec as P

    tok_sh = NamedSharding(mesh, P(bx))
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, in_sh),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(1,),
    )
    with mesh:
        return _LoweredCell(jitted.lower(p_specs, c_specs, in_specs))


def _unit_count(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm_xlstm":
        return cfg.n_layers // 2
    return cfg.n_layers


def _unit_variant(cfg, u: int):
    """Depth-u analysis variant with Python-unrolled layer loops so XLA's
    cost analysis counts every layer (while-loop bodies are counted once
    regardless of trip count — verified empirically)."""
    import dataclasses

    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, n_layers=u * cfg.attn_every, analysis_unroll=True
        )
    if cfg.family == "ssm_xlstm":
        return dataclasses.replace(cfg, n_layers=2 * u, analysis_unroll=True)
    if cfg.family == "encdec":
        return dataclasses.replace(
            cfg, n_layers=u, n_encoder_layers=u, analysis_unroll=True
        )
    return dataclasses.replace(cfg, n_layers=u, analysis_unroll=True)


def _cell_metrics(cfg, shape, mesh) -> dict:
    compiled = lower_cell(cfg, shape, mesh).compile()
    cost = compiled.cost_analysis()
    from ..analysis.roofline import collective_bytes

    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    """Compile the full cell (deliverable) + u=1/u=2 variants whose linear
    extrapolation recovers while-loop trip counts in the cost metrics (see
    analysis/corrections.py for the methodology)."""
    cfg = get(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size

    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()

    # layer-count extrapolation (u=1, u=2)
    units = _unit_count(cfg)
    m1 = _cell_metrics(_unit_variant(cfg, 1), shape, mesh)
    m2 = _cell_metrics(_unit_variant(cfg, 2), shape, mesh)

    def extrap(a, b):
        return a + (units - 1) * (b - a)

    from ..analysis.corrections import scan_correction_flops

    corr = scan_correction_flops(cfg, shape) / chips
    flops_x = extrap(m1["flops"], m2["flops"]) + corr
    bytes_x = extrap(m1["bytes"], m2["bytes"])
    coll_kinds = {
        k: extrap(m1["coll"].get(k, 0.0), m2["coll"].get(k, 0.0))
        for k in set(m1["coll"]) | set(m2["coll"])
    }
    cost_corrected = {"flops": flops_x, "bytes accessed": bytes_x}

    mf = model_flops(cfg, shape, active_params(cfg))
    rl = build_roofline(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cost=cost_corrected,
        hlo_text="",  # collectives supplied pre-extrapolated below
        model_flops_global=mf,
        memory_analysis=_memory_dict(mem),
    )
    # patch in extrapolated collectives
    coll_total = float(sum(coll_kinds.values()))
    rl.collective_bytes_per_device = coll_total
    rl.collective_by_kind = {k: int(v) for k, v in coll_kinds.items() if v}
    rl.t_collective = coll_total / 50e9
    terms = {
        "compute": rl.t_compute,
        "memory": rl.t_memory,
        "collective": rl.t_collective,
    }
    rl.bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())
    rl.peak_fraction = mf / (chips * 197e12 * t_bound) if t_bound > 0 else 0.0
    rl.useful_flops_ratio = (
        mf / (flops_x * chips) if flops_x > 0 else 0.0
    )

    rec = json.loads(rl.to_json())
    rec["raw_full_cost"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }
    rec["scan_correction_flops_per_device"] = corr
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out = OUT_DIR / f"{arch}_{shape_name}_{mesh_name}.json"
    out.write_text(json.dumps(rec, indent=1))
    if verbose:
        ma = rec["memory_analysis"]
        print(
            f"[OK] {arch} x {shape_name} x {mesh_name}: "
            f"compile {rec['compile_s']}s  "
            f"args/device {ma.get('argument_size_in_bytes', 0)/1e9:.2f} GB  "
            f"temp/device {ma.get('temp_size_in_bytes', 0)/1e9:.2f} GB  "
            f"t_comp {rl.t_compute*1e3:.2f}ms t_mem {rl.t_memory*1e3:.2f}ms "
            f"t_coll {rl.t_collective*1e3:.2f}ms -> {rl.bottleneck}",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-tm", action="store_true",
                    help="also dry-run the TM (paper) sharded configs")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for name in all_arch_names():
            cfg = get(name)
            for s in shapes_for(cfg):
                cells.append((name, s.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    for arch, sname in cells:
        if args.skip_existing and (OUT_DIR / f"{arch}_{sname}_{mesh_name}.json").exists():
            print(f"[SKIP] {arch} x {sname} (exists)", flush=True)
            continue
        try:
            run_cell(arch, sname, args.multi_pod)
        except Exception as e:
            failures.append((arch, sname, repr(e)))
            print(f"[FAIL] {arch} x {sname}: {e!r}", flush=True)
            traceback.print_exc()

    if args.include_tm:
        from ..dist.tm_sharded import dryrun_tm

        for tm_name in ("tm-paper", "tm-xl"):
            try:
                rec = dryrun_tm(tm_name, multi_pod=args.multi_pod, out_dir=OUT_DIR)
                print(f"[OK] {tm_name}: {rec['bottleneck']}", flush=True)
            except Exception as e:
                failures.append((tm_name, "-", repr(e)))
                print(f"[FAIL] {tm_name}: {e!r}", flush=True)

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        sys.exit(1)
    print(f"\nall {len(cells)} cells OK")


if __name__ == "__main__":
    main()
