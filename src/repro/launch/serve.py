"""Batched serving driver (LM prefill+decode) with the paper's
runtime-tunability discipline: fixed-capacity compiled programs, model
swap = weight rewrite (no re-jit).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b-smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get
from ..dist import sharding as shd
from ..dist.steps import make_decode_step, make_prefill_step
from ..models.api import family_for


class Server:
    """Fixed-shape serving engine: compiled once per (batch, prompt_cap,
    gen_cap).  The decode-cache capacity is ``prompt_cap + gen_cap``,
    fixed at construction, so every ``generate`` call reuses the same
    compiled prefill/decode programs regardless of the requested token
    count."""

    def __init__(self, cfg, mesh, *, batch: int, prompt_cap: int,
                 gen_cap: int = 16):
        self.cfg = cfg
        self.mesh = mesh
        shd.set_activation_mesh(mesh)
        self.fam = family_for(cfg)
        self.batch = batch
        self.prompt_cap = prompt_cap
        self.gen_cap = gen_cap
        self.cache_cap = prompt_cap + gen_cap
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        self.params = None

    def load_weights(self, params):
        """Model swap: pure data movement (the Fig-8 reprogram step)."""
        self.params = params

    def generate(self, prompts: np.ndarray, n_tokens: int) -> np.ndarray:
        """prompts: int32[B, prompt_len] -> int32[B, n_tokens].

        The prompt is right-padded to ``cache_cap = prompt_cap + gen_cap``
        so the compiled prefill allocates decode-capacity KV buffers
        (fixed-shape discipline); decode steps then fill slots
        sequentially, and the per-step kv_len mask hides not-yet-written
        slots."""
        B, plen = prompts.shape
        if plen > self.prompt_cap:
            raise ValueError(
                f"prompt length {plen} exceeds prompt_cap {self.prompt_cap}"
            )
        if n_tokens > self.gen_cap:
            raise ValueError(
                f"n_tokens {n_tokens} exceeds gen_cap {self.gen_cap}"
            )
        padded = np.zeros((B, self.cache_cap), np.int32)
        padded[:, :plen] = prompts
        logits, cache = self.prefill(self.params, {"tokens": jnp.asarray(padded)})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for i in range(n_tokens - 1):
            tok, cache = self.decode(
                self.params, cache, {"token": tok, "pos": jnp.int32(plen + i)}
            )
            tok = tok[:, None] if tok.ndim == 1 else tok
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # decode cache capacity (prompt + generation) is fixed at construction
    server = Server(cfg, mesh, batch=args.batch, prompt_cap=args.prompt_len,
                    gen_cap=args.gen)
    server.load_weights(family_for(cfg).init_params(cfg, jax.random.key(0)))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    t0 = time.time()
    tokens = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(tokens[:, :8])


if __name__ == "__main__":
    main()
