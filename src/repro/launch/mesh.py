"""Production mesh construction.

NOTE: this module never touches jax device state at import time; meshes are
built inside functions so the dry-run's XLA_FLAGS (512 host devices) or the
test environment (1 device) decide what exists.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: pod = cross-pod data parallelism (DCN), data = in-pod DP/FSDP,
    model = TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-scale sharding tests (requires host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
