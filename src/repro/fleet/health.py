"""Per-node circuit breakers + the retry/backoff policy the router runs.

The fleet's failure model (docs/fleet.md "failure model") in two parts:

``RetryPolicy`` — how hard ONE request tries: bounded attempts across
the candidate set, exponential backoff between sweeps (injectable
``sleep``/``clock`` so tests never touch wall-clock), and the retry
budget rule: a retry never sleeps past the request's remaining
``timeout_ms`` deadline budget — better to surface the structured error
while the caller can still act on it than to return late.

``FleetHealth`` — what the fleet believes about EACH node, as a
circuit breaker:

    healthy ──failure──► degraded ──thresholds──► quarantined
       ▲                                        │
       │                              probe_after_s cooldown
       │                                        ▼
       └────probe succeeds──── half_open ◄──next request probes
                                  │
                                  └──probe fails──► quarantined (restamped)

Transitions are driven by the outcomes the router records
(``record_success`` / ``record_failure`` / ``record_overload``) against
two thresholds: ``consecutive_failures`` and a windowed error rate.
``Overloaded`` is deliberately NOT a health failure — a full lane is
backpressure, not sickness; it only counts toward the ``overloads``
telemetry.

Liveness reuses ``runtime_ft.supervisor`` instead of duplicating it:
every success beats a ``HeartbeatTracker`` (same injectable-clock
pattern), and ``sweep()`` quarantines its ``dead_hosts()``; service
latencies feed a ``StragglerMonitor`` whose ``evict`` verdict also
quarantines — a node that is technically answering but 3x slower than
the fleet median is routed around just like a dead one.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional

from ..runtime_ft.supervisor import HeartbeatTracker, StragglerMonitor
from ..serve_tm.schema import HEALTH_NODE_KEYS, HEALTH_STATES

logger = logging.getLogger(__name__)

HEALTHY, DEGRADED, QUARANTINED, HALF_OPEN = HEALTH_STATES


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-attempt exponential backoff with a hard deadline budget.

    ``max_attempts`` bounds TOTAL per-node tries for one request (across
    failover sweeps, not per node).  Between sweeps the router sleeps
    ``backoff_s(sweep)`` = min(base * multiplier**sweep, max).  Both
    ``sleep`` and ``clock`` are injectable so property tests drive the
    policy through simulated time."""

    max_attempts: int = 4
    backoff_base_s: float = 0.005
    backoff_multiplier: float = 2.0
    backoff_max_s: float = 0.25
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}"
            )
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff durations must be >= 0")
        if self.backoff_multiplier < 1.0:
            raise ValueError(
                f"backoff_multiplier must be >= 1 (got "
                f"{self.backoff_multiplier}); a shrinking backoff hammers "
                f"a struggling node harder each sweep"
            )

    def backoff_s(self, sweep: int) -> float:
        """Backoff before re-sweeping the candidates (0-indexed sweep)."""
        return min(
            self.backoff_base_s * self.backoff_multiplier ** sweep,
            self.backoff_max_s,
        )

    def deadline_for(self, timeout_ms: Optional[float]) -> Optional[float]:
        """Absolute clock() stamp the whole retry loop must finish by."""
        if timeout_ms is None:
            return None
        return self.clock() + timeout_ms / 1e3

    def remaining_ms(self, deadline: Optional[float]) -> Optional[float]:
        """Budget left (ms); None when the request carried no timeout."""
        if deadline is None:
            return None
        return (deadline - self.clock()) * 1e3

    def budget_allows(
        self, deadline: Optional[float], sleep_s: float
    ) -> bool:
        """The retry-budget rule: never sleep past the remaining
        deadline budget — surface the last error instead."""
        if deadline is None:
            return True
        return self.clock() + sleep_s < deadline


class _NodeStats:
    __slots__ = (
        "state", "successes", "failures", "consecutive_failures",
        "retries", "failovers", "overloads", "quarantines", "probes",
        "window", "quarantined_at",
    )

    def __init__(self):
        self.state = HEALTHY
        self.successes = 0
        self.failures = 0
        self.consecutive_failures = 0
        self.retries = 0
        self.failovers = 0
        self.overloads = 0
        self.quarantines = 0
        self.probes = 0
        self.window: List[bool] = []  # True = success, most recent last
        self.quarantined_at: Optional[float] = None


class FleetHealth:
    """Circuit-breaker state for every node in a pool.

    Purely reactive: the router (and rollout manager) push outcomes in;
    ``state()``/``probe_due()`` answer routing questions; ``sweep()``
    applies the heartbeat timeout.  ``pool`` is optional and only used
    to best-effort mirror quarantine/probe events into the affected
    node's own ``ServeMetrics`` (unreachable nodes are skipped)."""

    def __init__(
        self,
        *,
        pool=None,
        consecutive_failures: int = 3,
        error_rate_threshold: float = 0.5,
        window: int = 16,
        min_window: int = 4,
        probe_after_s: float = 1.0,
        heartbeat_timeout_s: float = 30.0,
        clock: Callable[[], float] = time.monotonic,
        straggler: Optional[StragglerMonitor] = None,
    ):
        if consecutive_failures < 1:
            raise ValueError("consecutive_failures must be >= 1")
        if not 0.0 < error_rate_threshold <= 1.0:
            raise ValueError("error_rate_threshold must be in (0, 1]")
        self.pool = pool
        self.consecutive_failures = consecutive_failures
        self.error_rate_threshold = error_rate_threshold
        self.window = window
        self.min_window = min_window
        self.probe_after_s = probe_after_s
        self.clock = clock
        # one injectable clock drives the breaker, the heartbeat timeout
        # and (via the caller-measured latencies) the straggler monitor
        self.heartbeats = HeartbeatTracker(
            timeout_s=heartbeat_timeout_s, clock=clock
        )
        self.straggler = (
            straggler if straggler is not None else StragglerMonitor()
        )
        self._stats: Dict[str, _NodeStats] = {}

    def _ensure(self, name: str) -> _NodeStats:
        return self._stats.setdefault(name, _NodeStats())

    # -- routing questions ---------------------------------------------------

    def state(self, name: str) -> str:
        s = self._stats.get(name)
        return HEALTHY if s is None else s.state

    def error_rate(self, name: str) -> float:
        s = self._stats.get(name)
        if s is None or not s.window:
            return 0.0
        return s.window.count(False) / len(s.window)

    def probe_due(self, name: str) -> bool:
        """Quarantine cooldown elapsed: the next request may probe."""
        s = self._stats.get(name)
        return (
            s is not None
            and s.state == QUARANTINED
            and s.quarantined_at is not None
            and self.clock() - s.quarantined_at >= self.probe_after_s
        )

    # -- outcome recording (the router's side) -------------------------------

    def record_success(
        self, name: str, latency_s: Optional[float] = None
    ) -> None:
        s = self._ensure(name)
        s.successes += 1
        s.consecutive_failures = 0
        self._push(s, True)
        self.heartbeats.beat(name)
        if s.state != HEALTHY:
            # degraded recovers, and a half-open probe success CLOSES
            # the breaker (quarantined-with-success likewise: a rollout
            # gate may exercise a node the router never probed)
            s.state = HEALTHY
            s.quarantined_at = None
        if latency_s is not None:
            verdict = self.straggler.observe(name, latency_s)
            if verdict == "evict":
                self.quarantine(name, reason="straggler evicted")
            elif verdict == "suspect" and s.state == HEALTHY:
                s.state = DEGRADED

    def record_failure(self, name: str, exc: Optional[BaseException] = None):
        s = self._ensure(name)
        s.failures += 1
        s.consecutive_failures += 1
        self._push(s, False)
        if s.state == HALF_OPEN:
            # the probe failed: back to quarantine, cooldown restarts
            self.quarantine(name, reason=f"half-open probe failed: {exc!r}")
        elif s.state == QUARANTINED:
            s.quarantined_at = self.clock()  # still down; restamp cooldown
        elif (
            s.consecutive_failures >= self.consecutive_failures
            or (
                len(s.window) >= self.min_window
                and self.error_rate(name) >= self.error_rate_threshold
            )
        ):
            self.quarantine(name, reason=f"thresholds tripped: {exc!r}")
        else:
            s.state = DEGRADED

    def record_overload(self, name: str) -> None:
        """``Overloaded`` is backpressure, not sickness — telemetry only."""
        self._ensure(name).overloads += 1

    def record_retry(self, name: str) -> None:
        self._ensure(name).retries += 1

    def record_failover(self, name: str) -> None:
        self._ensure(name).failovers += 1

    # -- breaker transitions -------------------------------------------------

    def quarantine(self, name: str, reason: str = "") -> None:
        s = self._ensure(name)
        s.state = QUARANTINED
        s.quarantined_at = self.clock()
        s.quarantines += 1
        logger.warning("node %r quarantined: %s", name, reason or "(manual)")
        self._mirror(name, "record_quarantine")

    def begin_probe(self, name: str) -> None:
        """The router is about to send ONE request to a quarantined node
        whose cooldown elapsed; until its outcome lands the node is
        half-open and receives no other traffic."""
        s = self._ensure(name)
        s.state = HALF_OPEN
        s.probes += 1
        self._mirror(name, "record_probe")

    def sweep(self) -> List[str]:
        """Quarantine every node whose heartbeat timed out; returns the
        names newly quarantined."""
        newly = []
        for host in self.heartbeats.dead_hosts():
            if self.state(host) not in (QUARANTINED, HALF_OPEN):
                self.quarantine(host, reason="missed heartbeats")
                newly.append(host)
        return newly

    # -- rendering -----------------------------------------------------------

    def summary(self) -> Dict[str, Dict]:
        """Per-node dicts, keys pinned by ``schema.HEALTH_NODE_KEYS``."""
        out: Dict[str, Dict] = {}
        for name, s in sorted(self._stats.items()):
            d = {
                "state": s.state,
                "successes": s.successes,
                "failures": s.failures,
                "consecutive_failures": s.consecutive_failures,
                "error_rate": self.error_rate(name),
                "retries": s.retries,
                "failovers": s.failovers,
                "overloads": s.overloads,
                "quarantines": s.quarantines,
                "probes": s.probes,
            }
            assert tuple(d.keys()) == HEALTH_NODE_KEYS
            out[name] = d
        return out

    # -- internals -----------------------------------------------------------

    def _push(self, s: _NodeStats, ok: bool) -> None:
        s.window.append(ok)
        del s.window[: -self.window]

    def _mirror(self, name: str, method: str) -> None:
        """Best-effort: count the event on the node's own ServeMetrics
        too, so pool metric rollups show it (dead nodes are skipped)."""
        if self.pool is None:
            return
        try:
            metrics = getattr(self.pool.node(name), "metrics", None)
            if metrics is not None:
                getattr(metrics, method)()
        except Exception:
            pass
