"""Fleet routing: capacity-fit filtering + least-queue-depth dispatch
over a ``FleetPool``, speaking the same priority/deadline semantics as a
single node — now health-gated and retrying.

A request names a SLOT, not a node.  The router's job:

  * eligibility — only nodes hosting the slot are candidates (placement
    itself is capacity-fit filtered: ``replicate``/``FleetPool.install``
    run each target node's own ``validate_model`` before programming),
    and the ``FleetHealth`` circuit breaker prunes them further:
    quarantined nodes are skipped until their probe cooldown elapses,
    at which point exactly ONE request is let through half-open;
  * load balancing — among candidates, the node with the fewest pending
    rows wins (ties break by pool join order, so routing is
    deterministic for a given load picture);
  * retry/failover — ``submit``, ``async_submit`` and ``infer`` all run
    the same ``RetryPolicy`` loop: candidates are swept least-loaded
    first, a node that raises (``Overloaded``, an engine exception,
    ``NodeDown``) is failed over within the sweep, and between sweeps
    the router backs off exponentially — but NEVER past the request's
    remaining ``timeout_ms`` deadline budget; when the budget (or the
    attempt bound) is exhausted the LAST structured error propagates.
    Every outcome is recorded into the health tracker: successes beat
    the heartbeat, failures drive the breaker, ``Overloaded`` counts as
    backpressure only;
  * the PR-6 semantics ride through untouched — ``priority=`` picks the
    lane and ``timeout_ms=`` stamps the deadline ON THE CHOSEN NODE
    (the *remaining* budget, not the original, after any backoff),
    whose scheduler applies EDF/shedding/admission exactly as if the
    caller had spoken to it directly;
  * hot-slot replication — ``replicate`` re-ships the slot's installed
    ``TMProgram`` artifact to more nodes (least-loaded, capacity-fit
    first), widening the candidate set under load.

Every handle the router returns is tagged ``handle.routed_to`` with the
chosen node's name, and the serving node's own ``ServeMetrics`` gains
``retries``/``failovers`` counts, so callers (and the fleet bench) can
audit placement and the retry path without reaching past the boundary.
"""

from __future__ import annotations

import asyncio
import time
from typing import List, Optional, Tuple

from ..accel.capacity import CapacityExceeded
from ..serve_tm.node import ServingNode
from ..serve_tm.scheduler import Overloaded
from .health import FleetHealth, HALF_OPEN, QUARANTINED, RetryPolicy
from .pool import FleetPool


class NoEligibleNode(RuntimeError):
    """No pool member can serve the request.

    Structured fields (``slot``, ``reason``, ``candidates``) so callers
    can distinguish "slot deployed nowhere" from "no node fits" from
    "every host quarantined"."""

    def __init__(self, slot: str, reason: str, candidates: List[str]):
        self.slot = slot
        self.reason = reason
        self.candidates = candidates
        super().__init__(
            f"no eligible node for slot {slot!r}: {reason} "
            f"(pool members: {candidates or 'none'})"
        )


class Router:
    def __init__(
        self,
        pool: FleetPool,
        *,
        health: Optional[FleetHealth] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.pool = pool
        self.health = health if health is not None else FleetHealth(pool=pool)
        self.retry = retry if retry is not None else RetryPolicy()

    # -- candidate selection -------------------------------------------------

    def candidates(self, slot: str) -> List[Tuple[str, ServingNode]]:
        """Healthy nodes hosting ``slot``, least-loaded first (pending
        rows across all slots — the engine is shared per node, so the
        whole backlog delays a new request, not just the slot's share).
        Ties break by pool join order.  Quarantined nodes are skipped
        unless their probe cooldown elapsed, in which case the node is
        offered FIRST so the next request probes it half-open; a node
        whose introspection raises (dead mid-listing) is recorded as a
        failure and skipped."""
        order = {name: i for i, name in enumerate(self.pool.names())}
        hosting: List[Tuple[int, int, str, ServingNode]] = []
        probes: List[Tuple[str, ServingNode]] = []
        skipped = 0
        for name, node in self.pool.items():
            state = self.health.state(name)
            if state == HALF_OPEN:
                skipped += 1  # a probe is already in flight
                continue
            if state == QUARANTINED and not self.health.probe_due(name):
                skipped += 1
                continue
            try:
                if slot not in node.slots():
                    continue
                depth = node.queue_depth()
            except Exception as e:
                self.health.record_failure(name, e)
                skipped += 1
                continue
            if state == QUARANTINED:
                probes.append((name, node))
            else:
                hosting.append((depth, order[name], name, node))
        hosting.sort()
        result = probes + [(name, node) for _, _, name, node in hosting]
        if not result:
            if skipped:
                raise NoEligibleNode(
                    slot, f"{skipped} node(s) quarantined or unreachable "
                    f"and no healthy node hosts this slot",
                    self.pool.names(),
                )
            raise NoEligibleNode(
                slot, "no node hosts this slot — deploy or replicate it "
                "first", self.pool.names(),
            )
        return result

    def route(self, slot: str) -> Tuple[str, ServingNode]:
        """The node the next request for ``slot`` should land on."""
        return self.candidates(slot)[0]

    # -- the shared retry/failover loop --------------------------------------

    def _record_ok(self, name, node, latency_s, retried, failed_over):
        self.health.record_success(name, latency_s)
        if retried:
            self.health.record_retry(name)
            self._bump(node, "record_retry")
        if failed_over:
            self.health.record_failover(name)
            self._bump(node, "record_failover")

    @staticmethod
    def _bump(node, method: str) -> None:
        """Best-effort mirror into the serving node's own ServeMetrics."""
        try:
            metrics = getattr(node, "metrics", None)
            if metrics is not None:
                getattr(metrics, method)()
        except Exception:
            pass

    # -- traffic -------------------------------------------------------------

    def submit(
        self,
        slot: str,
        x,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ):
        """Queue the request on the least-loaded healthy hosting node;
        fails over on engine exceptions / ``NodeDown`` / ``Overloaded``
        and retries with backoff inside the deadline budget.  Returns
        the serving node's ``RequestHandle`` tagged ``.routed_to``."""
        retry = self.retry
        deadline = retry.deadline_for(timeout_ms)
        attempts = sweeps = 0
        retried = failed_over = False
        last: Optional[BaseException] = None
        while attempts < retry.max_attempts:
            try:
                cands = self.candidates(slot)
            except NoEligibleNode as e:
                if last is not None:
                    raise last
                raise e
            for name, node in cands:
                if attempts >= retry.max_attempts:
                    break
                remaining = retry.remaining_ms(deadline)
                if remaining is not None and remaining <= 0:
                    raise last if last is not None else TimeoutError(
                        f"slot {slot!r}: deadline budget exhausted "
                        f"before any node accepted the request"
                    )
                attempts += 1
                if self.health.state(name) == QUARANTINED:
                    self.health.begin_probe(name)
                t0 = retry.clock()
                try:
                    handle = node.submit(
                        slot, x, priority=priority, timeout_ms=remaining
                    )
                except Overloaded as e:
                    self.health.record_overload(name)
                    last = e
                    failed_over = True
                    continue
                except Exception as e:
                    self.health.record_failure(name, e)
                    last = e
                    failed_over = True
                    continue
                self._record_ok(
                    name, node, retry.clock() - t0, retried,
                    failed_over and attempts > 1,
                )
                handle.routed_to = name
                return handle
            if attempts >= retry.max_attempts:
                break
            delay = retry.backoff_s(sweeps)
            sweeps += 1
            if not retry.budget_allows(deadline, delay):
                break  # never sleep past the remaining deadline budget
            retry.sleep(delay)
            retried = True
        assert last is not None
        raise last

    async def async_submit(
        self,
        slot: str,
        x,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ):
        """``submit`` with the node's admission-controlled async front
        door; the same retry/failover/deadline-budget loop, with async
        backoff sleeps (unless an injected policy ``sleep`` overrides)."""
        retry = self.retry
        deadline = retry.deadline_for(timeout_ms)
        attempts = sweeps = 0
        retried = failed_over = False
        last: Optional[BaseException] = None
        while attempts < retry.max_attempts:
            try:
                cands = self.candidates(slot)
            except NoEligibleNode as e:
                if last is not None:
                    raise last
                raise e
            for name, node in cands:
                if attempts >= retry.max_attempts:
                    break
                remaining = retry.remaining_ms(deadline)
                if remaining is not None and remaining <= 0:
                    raise last if last is not None else TimeoutError(
                        f"slot {slot!r}: deadline budget exhausted "
                        f"before any node accepted the request"
                    )
                attempts += 1
                if self.health.state(name) == QUARANTINED:
                    self.health.begin_probe(name)
                t0 = retry.clock()
                try:
                    handle = await node.async_submit(
                        slot, x, priority=priority, timeout_ms=remaining
                    )
                except Overloaded as e:
                    self.health.record_overload(name)
                    last = e
                    failed_over = True
                    continue
                except Exception as e:
                    self.health.record_failure(name, e)
                    last = e
                    failed_over = True
                    continue
                self._record_ok(
                    name, node, retry.clock() - t0, retried,
                    failed_over and attempts > 1,
                )
                handle.routed_to = name
                return handle
            if attempts >= retry.max_attempts:
                break
            delay = retry.backoff_s(sweeps)
            sweeps += 1
            if not retry.budget_allows(deadline, delay):
                break  # never sleep past the remaining deadline budget
            if retry.sleep is time.sleep:
                await asyncio.sleep(delay)
            else:
                retry.sleep(delay)  # injected (tests drive fake time)
            retried = True
        assert last is not None
        raise last

    def infer(self, slot: str, x):
        """Synchronous convenience: route + the node's submit/drain,
        with the same failover/backoff loop (no deadline — ``infer``
        carries no timeout)."""
        retry = self.retry
        attempts = sweeps = 0
        retried = failed_over = False
        last: Optional[BaseException] = None
        while attempts < retry.max_attempts:
            try:
                cands = self.candidates(slot)
            except NoEligibleNode as e:
                if last is not None:
                    raise last
                raise e
            for name, node in cands:
                if attempts >= retry.max_attempts:
                    break
                attempts += 1
                if self.health.state(name) == QUARANTINED:
                    self.health.begin_probe(name)
                t0 = retry.clock()
                try:
                    preds = node.infer(slot, x)
                except Overloaded as e:
                    self.health.record_overload(name)
                    last = e
                    failed_over = True
                    continue
                except Exception as e:
                    self.health.record_failure(name, e)
                    last = e
                    failed_over = True
                    continue
                self._record_ok(
                    name, node, retry.clock() - t0, retried,
                    failed_over and attempts > 1,
                )
                return preds
            if attempts >= retry.max_attempts:
                break
            delay = retry.backoff_s(sweeps)
            sweeps += 1
            retry.sleep(delay)
            retried = True
        assert last is not None
        raise last

    # -- hot-slot replication ------------------------------------------------

    def replicate(
        self,
        slot: str,
        n: int = 1,
        *,
        artifact=None,
        provenance: Optional[str] = None,
    ) -> List[str]:
        """Install ``slot`` on up to ``n`` more nodes (hot-slot scaling).

        The artifact re-shipped is the one a hosting node records for the
        slot (``installed_checksum``'s subject), unless ``artifact``
        overrides it.  Targets are the non-hosting nodes whose OWN
        capacity check accepts the model — capacity-fit filtering, the
        per-node half of routing — least-loaded first; nodes that raise
        mid-check (dead) are recorded as failures and skipped.  Returns
        the node names that received the slot (may be shorter than ``n``
        when the fleet runs out of fitting nodes)."""
        hosting = self.pool.nodes_with_slot(slot)
        if artifact is None:
            if not hosting:
                raise NoEligibleNode(
                    slot, "no node hosts this slot and no artifact was "
                    "given to replicate from", self.pool.names(),
                )
            src_name, src = hosting[0]
            artifact = src.installed_artifact(slot)
            if artifact is None:
                raise ValueError(
                    f"slot {slot!r} on node {src_name!r} was programmed "
                    f"from a bare model, not a TMProgram artifact — "
                    f"pass artifact= to replicate it"
                )
            if provenance is None:
                provenance = f"replicate:{src_name}"
        if provenance is None:
            provenance = "replicate"
        hosting_names = {name for name, _ in hosting}
        order = {name: i for i, name in enumerate(self.pool.names())}
        targets = []
        for name, node in self.pool.items():
            if name in hosting_names:
                continue
            if self.health.state(name) in (QUARANTINED, HALF_OPEN):
                continue  # don't widen onto a node the breaker distrusts
            try:
                node.validate_model(artifact.model)
                depth = node.queue_depth()
            except CapacityExceeded:
                continue  # capacity-fit filtering: this node can't host it
            except Exception as e:
                self.health.record_failure(name, e)
                continue
            targets.append((depth, order[name], name, node))
        targets.sort()
        installed = []
        for _, _, name, node in targets[: max(0, n)]:
            node.register(slot, artifact, provenance=provenance)
            installed.append(name)
        return installed
