"""Fleet routing: capacity-fit filtering + least-queue-depth dispatch
over a ``FleetPool``, speaking the same priority/deadline semantics as a
single node.

A request names a SLOT, not a node.  The router's job:

  * eligibility — only nodes hosting the slot are candidates (placement
    itself is capacity-fit filtered: ``replicate``/``FleetPool.install``
    run each target node's own ``validate_model`` before programming);
  * load balancing — among candidates, the node with the fewest pending
    rows wins (ties break by pool join order, so routing is
    deterministic for a given load picture);
  * the PR-6 semantics ride through untouched — ``priority=`` picks the
    lane and ``timeout_ms=`` stamps the deadline ON THE CHOSEN NODE,
    whose scheduler applies EDF/shedding/admission exactly as if the
    caller had spoken to it directly.  ``async_submit`` additionally
    FAILS OVER on ``Overloaded``: if the least-loaded candidate's lane
    budget is exhausted the router tries the next-least-loaded one, and
    only when EVERY candidate rejects does the structured ``Overloaded``
    propagate — a fleet is only overloaded when all of it is;
  * hot-slot replication — ``replicate`` re-ships the slot's installed
    ``TMProgram`` artifact to more nodes (least-loaded, capacity-fit
    first), widening the candidate set under load.

Every handle the router returns is tagged ``handle.routed_to`` with the
chosen node's name, so callers (and the fleet bench) can audit placement
without reaching through the boundary.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from ..accel.capacity import CapacityExceeded
from ..serve_tm.node import ServingNode
from ..serve_tm.scheduler import Overloaded
from .pool import FleetPool


class NoEligibleNode(RuntimeError):
    """No pool member can serve the request.

    Structured fields (``slot``, ``reason``, ``candidates``) so callers
    can distinguish "slot deployed nowhere" from "no node fits"."""

    def __init__(self, slot: str, reason: str, candidates: List[str]):
        self.slot = slot
        self.reason = reason
        self.candidates = candidates
        super().__init__(
            f"no eligible node for slot {slot!r}: {reason} "
            f"(pool members: {candidates or 'none'})"
        )


class Router:
    def __init__(self, pool: FleetPool):
        self.pool = pool

    # -- candidate selection -------------------------------------------------

    def candidates(self, slot: str) -> List[Tuple[str, ServingNode]]:
        """Nodes hosting ``slot``, least-loaded first (pending rows
        across all slots — the engine is shared per node, so the whole
        backlog delays a new request, not just the slot's share).  Ties
        break by pool join order."""
        hosting = self.pool.nodes_with_slot(slot)
        if not hosting:
            raise NoEligibleNode(
                slot, "no node hosts this slot — deploy or replicate it "
                "first", self.pool.names(),
            )
        order = {name: i for i, name in enumerate(self.pool.names())}
        return sorted(
            hosting, key=lambda nn: (nn[1].queue_depth(), order[nn[0]])
        )

    def route(self, slot: str) -> Tuple[str, ServingNode]:
        """The node the next request for ``slot`` should land on."""
        return self.candidates(slot)[0]

    # -- traffic -------------------------------------------------------------

    def submit(
        self,
        slot: str,
        x,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ):
        """Queue the request on the least-loaded hosting node; returns
        that node's ``RequestHandle`` tagged with ``.routed_to``."""
        name, node = self.route(slot)
        handle = node.submit(
            slot, x, priority=priority, timeout_ms=timeout_ms
        )
        handle.routed_to = name
        return handle

    async def async_submit(
        self,
        slot: str,
        x,
        *,
        priority: str = "normal",
        timeout_ms: Optional[float] = None,
    ):
        """Admission-controlled submit with fleet failover: candidates
        are tried least-loaded first and a node's ``Overloaded`` moves on
        to the next; the last rejection propagates only when every
        candidate's lane budget is exhausted."""
        last: Optional[Overloaded] = None
        for name, node in self.candidates(slot):
            try:
                handle = await node.async_submit(
                    slot, x, priority=priority, timeout_ms=timeout_ms
                )
            except Overloaded as e:
                last = e
                continue
            handle.routed_to = name
            return handle
        raise last

    def infer(self, slot: str, x):
        """Synchronous convenience: route + the node's submit/drain."""
        _, node = self.route(slot)
        return node.infer(slot, x)

    # -- hot-slot replication ------------------------------------------------

    def replicate(
        self,
        slot: str,
        n: int = 1,
        *,
        artifact=None,
        provenance: Optional[str] = None,
    ) -> List[str]:
        """Install ``slot`` on up to ``n`` more nodes (hot-slot scaling).

        The artifact re-shipped is the one a hosting node records for the
        slot (``installed_checksum``'s subject), unless ``artifact``
        overrides it.  Targets are the non-hosting nodes whose OWN
        capacity check accepts the model — capacity-fit filtering, the
        per-node half of routing — least-loaded first.  Returns the node
        names that received the slot (may be shorter than ``n`` when the
        fleet runs out of fitting nodes)."""
        hosting = self.pool.nodes_with_slot(slot)
        if artifact is None:
            if not hosting:
                raise NoEligibleNode(
                    slot, "no node hosts this slot and no artifact was "
                    "given to replicate from", self.pool.names(),
                )
            src_name, src = hosting[0]
            artifact = src.installed_artifact(slot)
            if artifact is None:
                raise ValueError(
                    f"slot {slot!r} on node {src_name!r} was programmed "
                    f"from a bare model, not a TMProgram artifact — "
                    f"pass artifact= to replicate it"
                )
            if provenance is None:
                provenance = f"replicate:{src_name}"
        if provenance is None:
            provenance = "replicate"
        hosting_names = {name for name, _ in hosting}
        order = {name: i for i, name in enumerate(self.pool.names())}
        targets = []
        for name, node in self.pool.items():
            if name in hosting_names:
                continue
            try:
                node.validate_model(artifact.model)
            except CapacityExceeded:
                continue  # capacity-fit filtering: this node can't host it
            targets.append((name, node))
        targets.sort(key=lambda nn: (nn[1].queue_depth(), order[nn[0]]))
        installed = []
        for name, node in targets[: max(0, n)]:
            node.register(slot, artifact, provenance=provenance)
            installed.append(name)
        return installed
