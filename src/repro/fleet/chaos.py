"""``ChaosNode`` — deterministic fault injection at the ServingNode
boundary.

Wraps any ``ServingNode`` and injects the fleet's whole fault taxonomy
from a SEEDED schedule: same seed + same call sequence → the identical
fault sequence (``fault_log``), replayable in tests and benchmarks.  No
wall-clock anywhere — latency faults go through an injectable ``sleep``
and the schedule is driven by operation COUNT, not time.

Faults, per boundary operation (one RNG draw per op, always, so the
schedule stays aligned even when every rate is zero):

  * ``error``    — the op raises (models an engine/transport exception);
  * ``latency``  — the op is served, ``latency_s`` late;
  * ``overload`` — submit raises the structured ``Overloaded`` (storms);
  * ``hang``     — submit returns a handle that will NEVER complete
                   (the pathology retry/timeout budgets exist for);
  * ``down``     — the node dies: THIS op and every later one raise
                   ``NodeDown`` and all pending handles it issued are
                   failed (``down_after_ops`` schedules the same thing
                   deterministically; ``kill()``/``revive()`` script it);
  * ``corrupt``  — install-path only: the shipped ``TMProgram`` bytes
                   get one bit flipped before reaching the inner node,
                   whose CRC-32 integrity check MUST reject them.

Because ``ChaosNode`` satisfies ``ServingNode`` itself, pools, routers
and rollouts exercise their failure handling against the exact surface
a real flaky transport proxy would present.
"""

from __future__ import annotations

import itertools
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..accel.program import TMProgram
from ..serve_tm.batching import RequestHandle
from ..serve_tm.node import NodeDown
from ..serve_tm.scheduler import Overloaded

# traffic ops draw from these; "corrupt" only applies to register()
TRAFFIC_FAULTS = ("error", "latency", "overload", "hang", "down")

_hung_ids = itertools.count(-1, -1)  # negative rids: never clash with real


class ChaosNode:
    """A ``ServingNode`` that misbehaves on a deterministic schedule."""

    def __init__(
        self,
        inner,
        *,
        name: str = "chaos",
        seed: int = 0,
        error_rate: float = 0.0,
        latency_rate: float = 0.0,
        latency_s: float = 0.002,
        overload_rate: float = 0.0,
        hang_rate: float = 0.0,
        down_rate: float = 0.0,
        corrupt_rate: float = 0.0,
        down_after_ops: Optional[int] = None,
        sleep: Callable[[float], None] = time.sleep,
    ):
        rates = {
            "error": error_rate, "latency": latency_rate,
            "overload": overload_rate, "hang": hang_rate,
            "down": down_rate, "corrupt": corrupt_rate,
        }
        for kind, rate in rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{kind}_rate must be in [0, 1], got {rate}")
        self.inner = inner
        self.name = name
        self.seed = seed
        self.rates = rates
        self.latency_s = latency_s
        self.down_after_ops = down_after_ops
        self.sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._ops = 0
        self._down = False
        # (op index, op name, fault-or-"ok") — the replayable schedule
        self.fault_log: List[Tuple[int, str, str]] = []
        self._issued: List[RequestHandle] = []

    # -- the schedule --------------------------------------------------------

    def _draw(self, op: str, kinds: Tuple[str, ...]) -> Optional[str]:
        """One op: check liveness, advance the schedule, pick the fault.

        Exactly one RNG draw happens per op regardless of rates or the
        kinds eligible for this op — determinism must not depend on
        which faults a particular call site can express."""
        if self._down:
            raise NodeDown(self.name, op)
        self._ops += 1
        if (
            self.down_after_ops is not None
            and self._ops > self.down_after_ops
        ):
            self.fault_log.append((self._ops, op, "down"))
            self.kill()
            raise NodeDown(self.name, op)
        u = float(self._rng.random())
        fault = None
        edge = 0.0
        for kind in kinds:
            edge += self.rates[kind]
            if u < edge:
                fault = kind
                break
        self.fault_log.append((self._ops, op, fault or "ok"))
        if fault == "down":
            self.kill()
            raise NodeDown(self.name, op)
        if fault == "error":
            raise RuntimeError(
                f"chaos[{self.name}]: injected fault during {op}"
            )
        if fault == "latency":
            self.sleep(self.latency_s)
        return fault

    def _alive(self, op: str) -> None:
        if self._down:
            raise NodeDown(self.name, op)

    def _track(self, handle: RequestHandle) -> RequestHandle:
        self._issued = [
            h for h in self._issued
            if not (h.done or h.expired or h.failed)
        ]
        self._issued.append(handle)
        return handle

    def _hung_handle(
        self, slot: str, x: np.ndarray, priority: str
    ) -> RequestHandle:
        # a handle nobody will ever fill or shed: deliberately carries NO
        # deadline (the node "accepted" the request, then went silent) —
        # only the caller's own wait timeout or a kill() resolves it
        return self._track(RequestHandle(
            next(_hung_ids), slot, int(np.asarray(x).shape[0]), priority
        ))

    # -- scripted lifecycle --------------------------------------------------

    def kill(self, fail_pending: bool = True) -> None:
        """Stop responding entirely.  Pending handles this node issued
        are failed with ``NodeDown`` (a monitor noticing the corpse would
        do the same) so no caller blocks past its own timeout."""
        self._down = True
        if fail_pending:
            exc = NodeDown(self.name, "kill")
            for h in self._issued:
                if not (h.done or h.expired or h.failed):
                    h._fail(exc)
        self._issued.clear()

    def revive(self) -> None:
        """Bring the node back (its inner loop never stopped)."""
        self._down = False
        self.down_after_ops = None  # a revived node stays up until rekilled

    @property
    def down(self) -> bool:
        return self._down

    # -- traffic -------------------------------------------------------------

    def submit(self, slot, x, *, priority="normal", timeout_ms=None):
        fault = self._draw(
            "submit", ("error", "latency", "overload", "hang", "down")
        )
        if fault == "overload":
            raise Overloaded(slot, priority, 0, 0)
        if fault == "hang":
            return self._hung_handle(slot, x, priority)
        return self._track(self.inner.submit(
            slot, x, priority=priority, timeout_ms=timeout_ms
        ))

    async def async_submit(self, slot, x, *, priority="normal",
                           timeout_ms=None):
        fault = self._draw(
            "async_submit", ("error", "latency", "overload", "hang", "down")
        )
        if fault == "overload":
            raise Overloaded(slot, priority, 0, 0)
        if fault == "hang":
            return self._hung_handle(slot, x, priority)
        return self._track(await self.inner.async_submit(
            slot, x, priority=priority, timeout_ms=timeout_ms
        ))

    def flush(self) -> None:
        self._draw("flush", ("error", "latency", "down"))
        self.inner.flush()

    def infer(self, slot, x):
        self._draw("infer", ("error", "latency", "down"))
        return self.inner.infer(slot, x)

    def class_sums(self, slot, x):
        self._alive("class_sums")  # the oracle hook is not chaos-injected
        return self.inner.class_sums(slot, x)

    def start(self) -> None:
        self._alive("start")
        self.inner.start()

    def stop(self, drain: bool = True) -> None:
        self._alive("stop")
        self.inner.stop(drain=drain)

    @property
    def scheduler_running(self) -> bool:
        return (not self._down) and self.inner.scheduler_running

    # -- programming ---------------------------------------------------------

    def register(self, slot, model, provenance="install"):
        fault = self._draw("register", ("corrupt", "down"))
        if fault == "corrupt" and isinstance(model, TMProgram):
            blob = bytearray(model.to_bytes())
            blob[-1] ^= 0x01  # one bit, in the payload: CRC must catch it
            # hand the corrupted wire bytes to the inner node — its
            # TMProgram.from_bytes integrity check raises ValueError
            return self.inner.register(
                slot, bytes(blob), provenance=provenance
            )
        return self.inner.register(slot, model, provenance=provenance)

    def rollback(self, slot):
        self._alive("rollback")
        return self.inner.rollback(slot)

    # -- introspection -------------------------------------------------------

    @property
    def capacity(self):
        return self.inner.capacity

    def validate_model(self, model) -> None:
        self._alive("validate_model")
        self.inner.validate_model(model)

    def queue_depth(self, slot=None, priority=None) -> int:
        self._alive("queue_depth")
        return self.inner.queue_depth(slot, priority)

    def metrics_snapshot(self) -> dict:
        self._alive("metrics_snapshot")
        return self.inner.metrics_snapshot()

    def slots(self):
        self._alive("slots")
        return self.inner.slots()

    def installed_checksum(self, slot):
        self._alive("installed_checksum")
        return self.inner.installed_checksum(slot)

    def installed_artifact(self, slot):
        self._alive("installed_artifact")
        return self.inner.installed_artifact(slot)

    def compile_cache_size(self) -> int:
        self._alive("compile_cache_size")
        return self.inner.compile_cache_size()

    # -- passthroughs the fleet uses best-effort -----------------------------

    @property
    def metrics(self):
        # local observability convenience, NOT a boundary member; kept
        # reachable even when down so post-mortem rollups still work
        return self.inner.metrics

    @property
    def registry(self):
        if self._down:
            # AttributeError (not NodeDown) so hasattr() degrades cleanly
            raise AttributeError("registry unreachable: node is down")
        return self.inner.registry
