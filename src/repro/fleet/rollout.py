"""Canary → wave → fleet-wide artifact rollouts over a ``FleetPool``.

The fleet analog of the paper's Fig-8 reprogram step: a recalibrated
``TMProgram`` ships to ONE node first (the canary), is gated on real
served traffic, then widens to a wave (~half the remaining nodes) and
finally the whole fleet — each stage re-gated before the next may start.

Per-node, per-stage gates:

  * **integrity** — the node's ``installed_checksum(slot)`` must equal
    the shipped artifact's CRC-32 (the wire artifact the node actually
    programmed is the one the operator audited);
  * **bit-exactness** — the holdout block is served through the node's
    REAL batched path (submit → scheduler/flush → demux) and every
    node's class sums must match the canary's exactly.  Heterogeneous
    engines are interchangeable only because of this invariant, so the
    rollout re-proves it on every node it touches;
  * **accuracy** — with labels, holdout accuracy must stay within
    ``regression_margin`` of the pre-rollout baseline (or clear an
    absolute ``min_accuracy``) — the fleet edition of the recal
    controller's post-swap validation.

A failed gate triggers the FLEET-WIDE rollback: every node that received
this rollout's artifact is rolled back through its registry's
drain-then-swap path, so the provenance chain on each node records both
the attempt and the retreat (``rollback:v3->v2(rollout:canary:…)``), and
the structured ``RolloutAborted`` carries the full ``RolloutReport``.
In-flight traffic is never dropped: installs and rollbacks hold each
node's scheduler lock across drain + install, exactly like a single-node
hot-swap.

Failure-aware: a node that dies mid-wave — raising out of its install,
its gate, or the retreat's rollback — is treated as a GATE FAILURE, not
a crash of the rollout itself.  The dead node is quarantined (when the
manager shares the router's ``FleetHealth``), the rollback still
completes on every reachable node, and ``RolloutReport.unreachable``
records who kept the attempted artifact so an operator can reconcile
when the node returns.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..accel.program import TMProgram
from .health import FleetHealth
from .pool import FleetPool, _validate_for_node
from .router import NoEligibleNode

# how long a gate waits for the node to serve the holdout block (a live
# scheduler loop completes it; without one the rollout drives flush())
_DEFAULT_GATE_TIMEOUT_S = 120.0

STAGES = ("canary", "wave", "fleet")

_GATE_TIMEOUT_WARNED = False


def __getattr__(name: str):
    # deprecated module constant: the timeout is a RolloutManager knob
    # now (gate_timeout_s=), per the once-per-process warning pattern
    if name == "GATE_TIMEOUT_S":
        global _GATE_TIMEOUT_WARNED
        if not _GATE_TIMEOUT_WARNED:
            _GATE_TIMEOUT_WARNED = True
            warnings.warn(
                "fleet.rollout.GATE_TIMEOUT_S is deprecated: pass "
                "RolloutManager(..., gate_timeout_s=...) instead — the "
                "module constant is no longer consulted at run time",
                DeprecationWarning,
                stacklevel=2,
            )
        return _DEFAULT_GATE_TIMEOUT_S
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class StageReport:
    """One rollout stage: which nodes, what the gates measured."""

    stage: str
    nodes: Tuple[str, ...]
    versions: Dict[str, int]          # slot version installed per node
    checksum_ok: bool
    bit_exact: bool
    accuracy: Optional[float]         # worst node accuracy (labels given)
    passed: bool
    install_s: float
    verify_s: float


@dataclasses.dataclass
class RolloutReport:
    """The full trip (or the aborted prefix) of one artifact rollout."""

    slot: str
    checksum: int
    stages: List[StageReport]
    completed: bool
    failed_stage: Optional[str] = None
    failure_reason: Optional[str] = None
    rolled_back: Tuple[str, ...] = ()
    # nodes the retreat could NOT reach (dead mid-rollout): they keep the
    # attempted artifact until they come back; the health layer
    # quarantines them so no traffic routes there meanwhile
    unreachable: Tuple[str, ...] = ()
    baseline_accuracy: Optional[float] = None
    provenance: Dict[str, str] = dataclasses.field(default_factory=dict)


class RolloutAborted(RuntimeError):
    """A stage gate failed; every installed node was rolled back.

    Structured fields: ``slot``, ``stage``, ``reason`` and the full
    ``report`` (whose ``rolled_back``/``provenance`` record the fleet's
    retreat)."""

    def __init__(self, report: RolloutReport):
        self.report = report
        self.slot = report.slot
        self.stage = report.failed_stage
        self.reason = report.failure_reason
        super().__init__(
            f"rollout of slot {report.slot!r} aborted at stage "
            f"{report.failed_stage!r}: {report.failure_reason} — rolled "
            f"back {list(report.rolled_back) or 'nothing'}"
        )


def plan_stages(names: List[str]) -> List[Tuple[str, List[str]]]:
    """canary = first node, wave = ~half the remainder, fleet = the
    rest; empty stages are dropped (a 1-node pool is canary-only)."""
    stages = []
    if names:
        stages.append(("canary", names[:1]))
        rest = names[1:]
        n_wave = math.ceil(len(rest) / 2)
        if n_wave:
            stages.append(("wave", rest[:n_wave]))
        if rest[n_wave:]:
            stages.append(("fleet", rest[n_wave:]))
    return stages


class RolloutManager:
    def __init__(
        self,
        pool: FleetPool,
        *,
        health: Optional[FleetHealth] = None,
        gate_timeout_s: float = _DEFAULT_GATE_TIMEOUT_S,
    ):
        self.pool = pool
        # share the ROUTER's FleetHealth so a node this rollout finds
        # dead is quarantined for traffic too, not just for rollouts
        self.health = health
        self.gate_timeout_s = gate_timeout_s

    def _quarantine(self, name: str, exc: BaseException) -> None:
        if self.health is not None:
            self.health.record_failure(name, exc)
            self.health.quarantine(
                name, reason=f"died mid-rollout: {type(exc).__name__}: {exc}"
            )

    def rollout(
        self,
        slot: str,
        artifact: TMProgram,
        *,
        holdout_x: np.ndarray,
        holdout_y: Optional[np.ndarray] = None,
        min_accuracy: Optional[float] = None,
        regression_margin: float = 0.02,
        nodes: Optional[List[str]] = None,
    ) -> RolloutReport:
        """Ship ``artifact`` into ``slot`` across the pool in gated
        stages.  Targets are the nodes hosting the slot (``nodes=``
        overrides; a slot hosted nowhere targets the whole pool — a
        staged initial deploy).  Returns the completed ``RolloutReport``
        or raises ``RolloutAborted`` after the fleet-wide rollback."""
        if not isinstance(artifact, TMProgram):
            raise TypeError(
                f"rollout ships TMProgram artifacts (the checksummed wire "
                f"unit), got {type(artifact).__name__}"
            )
        holdout_x = np.asarray(holdout_x, np.uint8)
        if holdout_y is not None:
            holdout_y = np.asarray(holdout_y, np.int32)
        if nodes is not None:
            targets = [(n, self.pool.node(n)) for n in nodes]
        else:
            targets = self.pool.nodes_with_slot(slot)
            if not targets:
                targets = self.pool.items()  # staged initial deploy
        if not targets:
            raise NoEligibleNode(slot, "the pool is empty", [])

        # every target must fit the artifact BEFORE any node is touched:
        # a misfit mid-wave would strand the fleet split-brained
        for name, node in targets:
            _validate_for_node(node, artifact.model, name,
                               f"rollout of slot {slot!r}")

        report = RolloutReport(
            slot=slot, checksum=artifact.checksum, stages=[],
            completed=False,
        )
        # accuracy baseline: the CURRENT program's holdout score (first
        # hosting node's direct oracle hook — no queue traffic involved)
        floor = min_accuracy
        if holdout_y is not None and floor is None:
            hosting = self.pool.nodes_with_slot(slot)
            if hosting:
                sums = np.asarray(hosting[0][1].class_sums(slot, holdout_x))
                report.baseline_accuracy = float(
                    (sums.argmax(1) == holdout_y).mean()
                )
                floor = report.baseline_accuracy - regression_margin

        installed: List[str] = []
        reference: Optional[np.ndarray] = None
        names = [name for name, _ in targets]
        by_name = dict(targets)
        for stage, stage_names in plan_stages(names):
            t0 = time.perf_counter()
            versions = {}
            reason = None
            for name in stage_names:
                try:
                    entry = by_name[name].register(
                        slot, artifact,
                        provenance=(
                            f"rollout:{stage}:{artifact.checksum:08x}"
                        ),
                    )
                except Exception as e:
                    # a node dying (or rejecting corrupted wire bytes)
                    # mid-install is a GATE FAILURE, not an exception out
                    # of the loop: quarantine it, abort, roll back the
                    # reachable nodes
                    reason = (
                        f"node {name!r} ({stage}) failed install: "
                        f"{type(e).__name__}: {e}"
                    )
                    self._quarantine(name, e)
                    break
                installed.append(name)
                versions[name] = entry.version
            install_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            checksum_ok = bit_exact = True
            accuracy: Optional[float] = None
            if reason is None:
                for name in stage_names:
                    node = by_name[name]
                    try:
                        if node.installed_checksum(slot) != artifact.checksum:
                            checksum_ok = False
                            reason = (
                                f"node {name!r} reports checksum "
                                f"{node.installed_checksum(slot)!r}, shipped "
                                f"{artifact.checksum:#x}"
                            )
                            break
                        # gate on the REAL served path, not the oracle
                        # hook: a live loop completes the handle,
                        # otherwise flush drives
                        handle = node.submit(slot, holdout_x)
                        if node.scheduler_running:
                            preds = handle.wait(timeout=self.gate_timeout_s)
                        else:
                            node.flush()
                            preds = handle.result()
                        sums = handle.class_sums
                    except Exception as e:
                        # node died mid-gate (NodeDown, a failed handle,
                        # a gate timeout): same treatment as any failed
                        # gate, plus quarantine
                        reason = (
                            f"node {name!r} ({stage}) died during the "
                            f"gate: {type(e).__name__}: {e}"
                        )
                        self._quarantine(name, e)
                        break
                    if reference is None:
                        reference = np.asarray(sums)
                    elif not np.array_equal(np.asarray(sums), reference):
                        bit_exact = False
                        reason = (
                            f"node {name!r} ({stage}) diverged from the "
                            f"canary's class sums — engines are no longer "
                            f"bit-exact"
                        )
                        break
                    if holdout_y is not None:
                        acc = float((preds == holdout_y).mean())
                        accuracy = acc if accuracy is None else min(accuracy,
                                                                    acc)
                        if floor is not None and acc < floor:
                            reason = (
                                f"node {name!r} ({stage}) holdout accuracy "
                                f"{acc:.3f} under the gate floor {floor:.3f}"
                            )
                            break
            verify_s = time.perf_counter() - t0
            passed = reason is None
            report.stages.append(StageReport(
                stage=stage, nodes=tuple(stage_names), versions=versions,
                checksum_ok=checksum_ok, bit_exact=bit_exact,
                accuracy=accuracy, passed=passed,
                install_s=install_s, verify_s=verify_s,
            ))
            if not passed:
                self._abort(report, stage, reason, installed, by_name,
                            slot)
        report.completed = True
        report.provenance = self._provenance(installed, by_name, slot)
        return report

    def _abort(self, report, stage, reason, installed, by_name, slot):
        """The fleet-wide retreat: roll back every node this rollout
        touched (drain-then-swap, provenance chains nest the attempt),
        then raise the structured ``RolloutAborted``.  A node the
        retreat cannot reach (died after install) is recorded in
        ``report.unreachable`` and quarantined — the rollback COMPLETES
        on every reachable node instead of raising out half-rolled-back."""
        rolled = []
        unreachable = []
        for name in installed:
            try:
                by_name[name].rollback(slot)
                rolled.append(name)
            except Exception as e:
                unreachable.append(name)
                self._quarantine(name, e)
        report.failed_stage = stage
        report.failure_reason = reason
        report.rolled_back = tuple(rolled)
        report.unreachable = tuple(unreachable)
        report.provenance = self._provenance(rolled, by_name, slot)
        raise RolloutAborted(report)

    @staticmethod
    def _provenance(names, by_name, slot) -> Dict[str, str]:
        """Per-node provenance audit strings, skipping nodes that cannot
        answer (the registry is an optional, best-effort window)."""
        out: Dict[str, str] = {}
        for name in names:
            try:
                node = by_name[name]
                out[name] = (
                    node.registry.get(slot).provenance
                    if hasattr(node, "registry") else ""
                )
            except Exception:
                out[name] = ""
        return out
