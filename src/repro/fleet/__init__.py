"""repro.fleet — routed replica pools with canary artifact rollouts.

The fleet layer stacks on the ``ServingNode`` boundary (serve_tm/node.py):
anything that satisfies the protocol — a ``TMServer``, the
``repro.accel.Accelerator`` façade, a remote proxy — can join a pool,
and the fleet machinery never reaches past the boundary into a node's
registry, engine or scheduler.

  pool.py      FleetPool — named membership, whole-fleet lifecycle
               (dead-node tolerant teardown), capacity-validated slot
               deploys, aggregate metrics rollup
  router.py    Router — capacity-fit + least-queue-depth routing with
               PR-6 priority/deadline semantics, health-gated candidates,
               retry/backoff failover on Overloaded / engine exceptions /
               NodeDown, hot-slot replication; structured NoEligibleNode
  health.py    FleetHealth — per-node circuit breaker (healthy →
               degraded → quarantined → half-open probe → healthy) over
               runtime_ft.supervisor's heartbeat/straggler trackers;
               RetryPolicy — bounded attempts, exponential backoff,
               hard deadline budget
  chaos.py     ChaosNode — deterministic seeded fault injection at the
               ServingNode boundary (errors, latency, Overloaded storms,
               hung handles, NodeDown, corrupted artifacts)
  rollout.py   RolloutManager — canary → wave → fleet-wide TMProgram
               shipping, gated per stage on installed checksum, served
               bit-exactness and holdout accuracy, with fleet-wide
               rollback (structured RolloutAborted carrying the
               RolloutReport); mid-wave node death is a gate failure,
               rollback completes on the reachable nodes

The structured exceptions ``NodeDown`` and ``EngineFault`` are stable
exports here and on ``repro.serve_tm`` (same objects, per the PR-7
convention).
"""

from ..serve_tm.node import NodeDown, ServingNode
from ..serve_tm.scheduler import EngineFault
from .chaos import ChaosNode
from .health import FleetHealth, RetryPolicy
from .pool import FleetPool
from .rollout import (
    RolloutAborted,
    RolloutManager,
    RolloutReport,
    StageReport,
    plan_stages,
)
from .router import NoEligibleNode, Router

__all__ = [
    "ChaosNode",
    "EngineFault",
    "FleetHealth",
    "FleetPool",
    "NoEligibleNode",
    "NodeDown",
    "RetryPolicy",
    "RolloutAborted",
    "RolloutManager",
    "RolloutReport",
    "Router",
    "ServingNode",
    "StageReport",
    "plan_stages",
]
