"""repro.fleet — routed replica pools with canary artifact rollouts.

The fleet layer stacks on the ``ServingNode`` boundary (serve_tm/node.py):
anything that satisfies the protocol — a ``TMServer``, the
``repro.accel.Accelerator`` façade, a remote proxy — can join a pool,
and the fleet machinery never reaches past the boundary into a node's
registry, engine or scheduler.

  pool.py      FleetPool — named membership, whole-fleet lifecycle,
               capacity-validated slot deploys, aggregate metrics rollup
  router.py    Router — capacity-fit + least-queue-depth routing with
               PR-6 priority/deadline semantics, Overloaded failover and
               hot-slot replication; structured NoEligibleNode
  rollout.py   RolloutManager — canary → wave → fleet-wide TMProgram
               shipping, gated per stage on installed checksum, served
               bit-exactness and holdout accuracy, with fleet-wide
               rollback (structured RolloutAborted carrying the
               RolloutReport)
"""

from ..serve_tm.node import ServingNode
from .pool import FleetPool
from .rollout import (
    RolloutAborted,
    RolloutManager,
    RolloutReport,
    StageReport,
    plan_stages,
)
from .router import NoEligibleNode, Router

__all__ = [
    "FleetPool",
    "NoEligibleNode",
    "RolloutAborted",
    "RolloutManager",
    "RolloutReport",
    "Router",
    "ServingNode",
    "StageReport",
    "plan_stages",
]
