"""A pool of heterogeneous ``ServingNode``s — the fleet's membership and
lifecycle layer.

Nodes are named, joined and left at runtime, and may be ANY object that
satisfies the ``ServingNode`` boundary (a ``TMServer``, the
``repro.accel.Accelerator`` façade, or a proxy for a remote box); each
brings its own negotiated ``CapacityPlan`` and engine, so a pool can mix
interp/plan/popcount/sharded nodes freely — the bit-exactness contract
makes them interchangeable for routing.

The pool answers the fleet-level questions the router and rollout
manager ask: which nodes exist, which host a slot, how deep is each
node's queue, and what does the fleet's aggregate traffic look like
(``metrics_summary`` collects each node's per-lane snapshot and rolls
them up via ``ServeMetrics.aggregate``).  It also owns whole-fleet
lifecycle (``start_all``/``stop_all``) and the initial slot deploy
(``install`` validates the artifact against every target node's OWN
capacity check first, so a heterogeneous fleet fails fast on the
misfitting node instead of half-deploying).
"""

from __future__ import annotations

import logging
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from ..accel.capacity import CapacityExceeded
from ..serve_tm.metrics import ServeMetrics
from ..serve_tm.node import ServingNode

logger = logging.getLogger(__name__)


def _validate_for_node(node, model, name: str, action: str) -> None:
    """Run ``node``'s own capacity check, re-raising with the node named
    (structured ``CapacityExceeded`` fields preserved)."""
    try:
        node.validate_model(model)
    except CapacityExceeded as e:
        raise CapacityExceeded(
            e.knob, e.required, e.capacity,
            what=f"{e.what} [node {name!r}, refusing {action}]",
        ) from e
    except ValueError as e:
        raise ValueError(
            f"{action} refused: node {name!r} cannot fit the model ({e})"
        ) from e


class FleetPool:
    """name -> ``ServingNode``, plus fleet-level lifecycle and rollups."""

    def __init__(
        self,
        nodes: Optional[Dict[str, ServingNode]] = None,
        *,
        max_warnings: int = 256,
    ):
        if max_warnings < 1:
            raise ValueError(
                f"max_warnings must be >= 1, got {max_warnings}"
            )
        self._nodes: Dict[str, ServingNode] = {}
        # drain/stop failures on dead nodes downgrade to entries here —
        # teardown always completes, operators read what it swallowed.
        # Ring-buffered: a long-lived pool with a flapping node keeps the
        # newest ``max_warnings`` entries instead of growing unboundedly.
        self.warnings: Deque[str] = deque(maxlen=max_warnings)
        for name, node in (nodes or {}).items():
            self.add(name, node)

    def clear_warnings(self) -> List[str]:
        """Drain the warning ring: returns what was recorded (oldest
        first) and empties the buffer — the operator's ack."""
        drained = list(self.warnings)
        self.warnings.clear()
        return drained

    # -- membership ----------------------------------------------------------

    def add(self, name: str, node: ServingNode) -> ServingNode:
        """Join ``node`` under ``name``.  The node must satisfy the
        ``ServingNode`` boundary — checked structurally up front so a
        misshapen node fails at join time, not mid-rollout."""
        if name in self._nodes:
            raise ValueError(f"node {name!r} already in the pool")
        if not isinstance(node, ServingNode):
            raise TypeError(
                f"node {name!r} ({type(node).__name__}) does not satisfy "
                f"the ServingNode protocol"
            )
        self._nodes[name] = node
        return node

    def remove(self, name: str, *, drain: bool = True) -> ServingNode:
        """Leave the pool; by default the node's loop is stopped and its
        queued traffic drained first so nothing admitted is stranded.
        A DEAD node (stop raises) is still removed: the failure becomes
        a recorded warning, never a stuck membership entry."""
        node = self.node(name)
        if drain:
            try:
                node.stop(drain=True)
            except Exception as e:
                self._warn(
                    f"removing node {name!r}: drain/stop failed "
                    f"({type(e).__name__}: {e}); detaching it anyway"
                )
        del self._nodes[name]
        return node

    def _warn(self, message: str) -> None:
        self.warnings.append(message)
        logger.warning("%s", message)

    def node(self, name: str) -> ServingNode:
        if name not in self._nodes:
            raise KeyError(
                f"no node {name!r} in the pool "
                f"(members: {self.names() or 'none'})"
            )
        return self._nodes[name]

    def names(self) -> List[str]:
        """Member names in join order (the rollout's stage order)."""
        return list(self._nodes)

    def items(self) -> List[Tuple[str, ServingNode]]:
        return list(self._nodes.items())

    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    def __iter__(self) -> Iterator[str]:
        return iter(self._nodes)

    # -- fleet lifecycle -----------------------------------------------------

    def start_all(self) -> None:
        """Start every node's continuous-batching loop (idempotent)."""
        for node in self._nodes.values():
            node.start()

    def stop_all(self, drain: bool = True) -> None:
        """Stop every node; dead nodes downgrade to recorded warnings so
        fleet teardown always completes."""
        for name, node in self._nodes.items():
            try:
                node.stop(drain=drain)
            except Exception as e:
                self._warn(
                    f"stop_all: node {name!r} failed to stop "
                    f"({type(e).__name__}: {e}); continuing teardown"
                )

    # -- slot placement ------------------------------------------------------

    def nodes_with_slot(self, slot: str) -> List[Tuple[str, ServingNode]]:
        """Members currently hosting ``slot`` (the router's candidates),
        in join order; nodes that cannot answer (dead) are skipped."""
        hosting = []
        for name, node in self._nodes.items():
            try:
                if slot in node.slots():
                    hosting.append((name, node))
            except Exception:
                continue  # unreachable — it can't serve the slot anyway
        return hosting

    def install(
        self,
        slot: str,
        artifact,
        nodes: Optional[List[str]] = None,
        provenance: str = "fleet:install",
    ) -> Dict[str, object]:
        """Deploy ``artifact`` into ``slot`` on ``nodes`` (default: every
        member).  All targets are capacity-validated FIRST — a
        heterogeneous fleet raises the misfitting node's
        ``CapacityExceeded`` before any node is touched, so a failed
        deploy never leaves the fleet half-programmed."""
        from ..accel.program import TMProgram

        targets = [(n, self.node(n)) for n in (nodes or self.names())]
        model = (
            artifact.model if isinstance(artifact, TMProgram)
            else TMProgram.from_bytes(artifact).model
            if isinstance(artifact, (bytes, bytearray, memoryview))
            else artifact
        )
        for name, node in targets:
            _validate_for_node(node, model, name,
                               f"fleet install of slot {slot!r}")
        return {
            name: node.register(slot, artifact, provenance=provenance)
            for name, node in targets
        }

    # -- fleet introspection -------------------------------------------------

    def queue_depths(self, slot: Optional[str] = None) -> Dict[str, int]:
        """Per-node pending rows (the router's load signal); nodes that
        cannot answer (dead) are omitted."""
        depths = {}
        for name, node in self._nodes.items():
            try:
                depths[name] = node.queue_depth(slot)
            except Exception:
                continue
        return depths

    def metrics_summary(self) -> Dict:
        """``{"aggregate": <fleet rollup>, "nodes": {name: snapshot},
        "unreachable": [names]}`` — per-node ``metrics_snapshot()`` dicts
        plus the ``ServeMetrics.aggregate`` rollup (schema:
        serve_tm/schema.py); the rollup covers the nodes that answered."""
        snaps: Dict[str, Dict] = {}
        unreachable: List[str] = []
        for name, node in self._nodes.items():
            try:
                snaps[name] = node.metrics_snapshot()
            except Exception:
                unreachable.append(name)
        return {
            "aggregate": ServeMetrics.aggregate(list(snaps.values())),
            "nodes": snaps,
            "unreachable": unreachable,
        }

    def __repr__(self) -> str:
        return f"FleetPool({self.names()})"
