"""Static block-size autotuner for the compressed-TM Pallas kernels.

The kernels in this package tile their grids with two knobs:

  * ``block_instructions`` — instruction-memory rows per grid step (the
    sequential "K-loop" depth; must be a multiple of 32 for the popcount
    bitplane reduction, whose class masks are packed 32 instructions/word);
  * ``block_words``        — 32-datapoint feature words per grid step (the
    parallel batch tile).

The right choice depends only on the *capacity* point (instruction depth x
batch words) — a synthesis-time property, never on runtime model contents —
so a small measured table is enough: no search at trace time, no cache
misses at serve time.  ``DEFAULT_TABLE`` was measured with
``measure_blocks`` over the tm_popcount kernel (interpret mode on the CPU
container; re-measure on real TPU hardware with ``python -m
repro.kernels.tuning``).  Rows are matched first-fit, so keep them sorted
from smallest to largest capacity.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple

BlockChoice = Tuple[int, int]  # (block_instructions, block_words)

# (max_instructions, max_words) -> (block_instructions, block_words);
# ``None`` bounds match anything (the final row is the fallback).
DEFAULT_TABLE: Tuple[Tuple[Optional[int], Optional[int], int, int], ...] = (
    # measured 2026-07 (CPU interpret, python -m repro.kernels.tuning):
    # deep word tiles amortize the per-block bitplane transpose; small
    # instruction blocks win only at shallow instruction depths
    (256, 1, 128, 1),
    (256, None, 256, 4),
    (1024, 2, 256, 2),
    (1024, None, 512, 8),
    (4096, 4, 256, 4),
    (None, None, 512, 8),
)


def _ceil32(n: int) -> int:
    return max(32, -(-n // 32) * 32)


def choose_blocks(
    n_instructions: int,
    n_words: int,
    table: Sequence[Tuple[Optional[int], Optional[int], int, int]] = DEFAULT_TABLE,
) -> BlockChoice:
    """Pick ``(block_instructions, block_words)`` for a capacity point.

    First-fit over ``table``; the returned block_instructions is clipped to
    the (32-aligned) instruction depth and block_words to the word count,
    so the caller can pass the choice straight to the kernel.
    """
    if n_instructions <= 0 or n_words <= 0:
        raise ValueError(
            f"capacity must be positive, got {n_instructions} instructions "
            f"x {n_words} words"
        )
    for max_i, max_w, bi, bw in table:
        if (max_i is None or n_instructions <= max_i) and (
            max_w is None or n_words <= max_w
        ):
            return min(bi, _ceil32(n_instructions)), min(bw, n_words)
    # defensive: a table without a (None, None) fallback row
    return min(512, _ceil32(n_instructions)), min(4, n_words)


def measure_blocks(
    n_instructions: int,
    n_words: int,
    *,
    candidates: Iterable[BlockChoice] = (
        (128, 1), (128, 2), (256, 1), (256, 2), (256, 4),
        (512, 1), (512, 2), (512, 4), (512, 8),
    ),
    m_cap: int = 16,
    l2: int = 256,
    repeats: int = 10,
    interpret: bool = True,
    seed: int = 0,
) -> Tuple[BlockChoice, dict]:
    """Time the tm_popcount kernel per candidate block shape at one
    capacity point -> (best choice, {choice: median_seconds}).

    Used offline to (re)generate ``DEFAULT_TABLE``; not called on any hot
    path.  ``interpret=True`` measures the CPU emulation — only relative
    ordering is meaningful there; on a TPU pass ``interpret=False``.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from .tm_popcount.kernel import tm_popcount

    rng = np.random.default_rng(seed)
    i_cap = _ceil32(n_instructions)
    lit_idx = rng.integers(0, l2, i_cap).astype(np.int32)
    last = (rng.random(i_cap) < 0.25).astype(np.int32)
    n_chunks = i_cap // 32
    mask_pos = rng.integers(0, 2**32, (m_cap, n_chunks), dtype=np.uint32)
    mask_neg = (~mask_pos).astype(np.uint32)
    lits = rng.integers(0, 2**32, (l2, n_words), dtype=np.uint32)
    args = tuple(
        jnp.asarray(a) for a in (lit_idx, last, mask_pos, mask_neg, lits)
    )

    timings: dict = {}
    for bi, bw in candidates:
        if bi > i_cap or bw > n_words:
            continue
        fn = lambda: tm_popcount(  # noqa: E731
            *args, block_instructions=bi, block_words=bw, interpret=interpret
        )
        jax.block_until_ready(fn())  # compile outside the window
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        timings[(bi, bw)] = float(np.median(ts))
    if not timings:
        raise ValueError(
            f"no candidate block shape fits {n_instructions} instructions "
            f"x {n_words} words"
        )
    best = min(timings, key=timings.get)
    return best, timings


def _main() -> None:  # pragma: no cover - offline table regeneration
    points = [(256, 1), (256, 4), (1024, 2), (1024, 8), (4096, 4)]
    print("capacity (instructions x words) -> best (bi, bw)  [median us]")
    for i_cap, w in points:
        best, timings = measure_blocks(i_cap, w)
        print(
            f"  ({i_cap:5d}, {w}) -> {best}  "
            f"[{', '.join(f'{k}={v * 1e6:.0f}' for k, v in sorted(timings.items()))}]"
        )


if __name__ == "__main__":  # pragma: no cover
    _main()
