"""Naive oracle for the fused packed trainer: round-trip through the
canonical representation and the reference summed-delta trainer.

Deliberately does everything the fused kernel avoids — full unpack to
``int32[M, C, 2F]``, dense clause evaluation, an ``[B, M, C, 2F]`` delta
tensor — so a test that compares ``fused_train_batch`` against this is
comparing two independently-structured computations that must agree
bit-for-bit under the seeding contract.
"""

from __future__ import annotations

import jax

from ...core.tm import TMConfig
from ...core.train import train_batch_parallel
from .ops import pack_ta_state, unpack_ta_state

Array = jax.Array


def fused_train_batch_ref(
    cfg: TMConfig, packed: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """unpack -> ``train_batch_parallel`` -> repack (the slow truth)."""
    state = unpack_ta_state(cfg, packed)
    new = train_batch_parallel(cfg, state, key, xb, yb)
    return pack_ta_state(cfg, new)
