"""tm_train: fused packed-TA training (clause-eval + Type I/II feedback +
TA update in one pass over packed uint32 literal bitplanes, int8 state).

See ``kernel.py`` for the algorithm and the bit-reproducibility contract,
``ops.py`` for the packed int8 ``(clauses, literals, 2)`` layout, and
``repro.recal.train_engine`` for the serving-side plugin ('packed')."""

from .kernel import fused_fit_step, fused_train_batch, packed_clause_words
from .ops import (
    MAX_PACKED_STATES,
    check_packable,
    pack_ta_state,
    packed_include_actions,
    supports_packed_states,
    unpack_ta_state,
)
from .ref import fused_train_batch_ref

__all__ = [
    "MAX_PACKED_STATES",
    "check_packable",
    "fused_fit_step",
    "fused_train_batch",
    "fused_train_batch_ref",
    "pack_ta_state",
    "packed_clause_words",
    "packed_include_actions",
    "supports_packed_states",
    "unpack_ta_state",
]
