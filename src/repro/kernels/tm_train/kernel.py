"""Fused packed-TA training step: clause-eval + feedback + TA update in one
compiled pass over packed uint32 literal bitplanes.

What the reference trainer (``core.train.train_batch_parallel``) does per
sample — dense clause evaluation over ``bool[C, 2F]``, a full
``int32[M, C, 2F]`` delta tensor materialized per sample — this kernel
restructures around the serving representation:

  1. **Clause eval is the PR-4 popcount machinery.**  The batch is
     bit-packed 32 datapoints per ``uint32`` word (``core.tm.
     pack_literals``), clause outputs are computed ONCE for all classes
     and all samples as packed words (AND over included literal rows —
     the same formulation as ``packed_class_sums`` / ``tm_popcount``,
     with training semantics: an all-excluded clause outputs 1), and the
     per-sample clause-output rows are extracted through the 32x32
     bitplane transpose (``tm_popcount.kernel.bit_transpose32``).
  2. **TA states stay int8** in the flat ``(clauses, literals, 2)``
     layout (``ops.pack_ta_state``); only the two class rows a sample
     actually touches (target + sampled negative) are widened to int32
     for the feedback arithmetic.
  3. **Deltas are two rows, not M.**  Each sample contributes
     ``int32[2, C, 2F]`` keyed by (target, negative) class ids,
     scatter-added into the update — integer addition commutes, so the
     result is bit-identical to the reference's summed ``[B, M, C, 2F]``
     tensor at an M/2 memory-traffic discount.

**Bit-reproducibility.**  All stochastic feedback comes from the same
counter-based threefry streams as the reference path: the fold-in
seeding contract keys sample ``i`` of step ``s`` as
``fold_in(fold_in(key, s), i)``, and the per-(clause, literal) uniforms
are drawn by the SHARED ``core.train._feedback_from_clause_outputs`` —
the kernel only substitutes how clause outputs are computed (packed
words vs dense bools, both exact).  Acceptance is bit-identical final TA
state vs ``core.train.fit_step`` on the same (key, step), which
``tests/test_train_engine.py`` property-tests.

**Why XLA and not a Pallas lowering.**  The TPU Pallas PRNG
(``pltpu.prng_random_bits``) is a hardware generator that cannot
reproduce the threefry bit-streams the seeding contract promises, so a
Pallas kernel could be fast but never bit-identical — the same reasoning
that makes ``tm_popcount_xla`` the serving path off-TPU makes the fused
XLA formulation the training path everywhere.  The packed layout is
Pallas-shaped (uint32 panels, int8 state tiles) if the contract is ever
relaxed to per-backend streams.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...core.tm import TMConfig, literals, pack_literals, unpack_bits
from ...core.train import (
    _feedback_from_clause_outputs,
    sample_keys,
    validate_batch_capacity,
)
from ..tm_popcount.kernel import bit_transpose32
from .ops import packed_include_actions

Array = jax.Array

ONES = 0xFFFFFFFF


def packed_clause_words(actions: Array, packed_lits: Array) -> Array:
    """Training-semantics clause outputs, packed 32 datapoints per word.

    actions: bool[M, C, 2F]; packed_lits: uint32[2F, W] -> uint32[M, C, W]
    (bit b of word w = clause output for datapoint ``32w + b``; an
    all-excluded clause ANDs nothing and stays all-ones — the training
    convention, unlike inference's empty->0).
    """
    ones = jnp.uint32(ONES)

    def clause_word(a_row):  # a_row: bool[2F]
        masked = jnp.where(a_row[:, None], packed_lits, ones)
        return jax.lax.reduce(
            masked, ones, jnp.bitwise_and, dimensions=(0,)
        )  # [W]

    return jax.vmap(jax.vmap(clause_word))(actions)


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def fused_train_batch(
    cfg: TMConfig, packed: Array, key: Array, xb: Array, yb: Array
) -> Array:
    """One summed-delta batch update on the packed int8 state.

    packed: int8[M, C, F, 2]; xb: {0,1}[B, F]; yb: int32[B] ->
    int8[M, C, F, 2].  Bit-identical (after unpacking) to
    ``core.train.train_batch_parallel`` under the same call key.
    """
    M, C, L, N = cfg.n_classes, cfg.n_clauses, cfg.n_literals, cfg.n_states
    B = xb.shape[0]
    xb = xb.astype(jnp.bool_)
    lits_all = literals(xb)  # [B, 2F] dense (the feedback operand)

    # -- packed clause evaluation (once, all classes x all samples) ----------
    b_pad = -(-B // 32) * 32  # whole 32-datapoint words; pad rows unused
    plits = pack_literals(jnp.pad(xb, ((0, b_pad - B), (0, 0))))  # [2F, W]
    flat = packed.reshape(M, C, L)
    actions = packed_include_actions(flat)  # [M, C, 2F]
    cw = packed_clause_words(actions, plits)  # [M, C, W]
    c_chunks = -(-C // 32)
    cw = jnp.pad(cw, ((0, 0), (0, c_chunks * 32 - C), (0, 0)))
    # planes[m, cc, b, w] bit j = output of clause 32cc+j for datapoint
    # 32w+b — the PR-4 bitplane transpose, reused for per-sample extraction
    planes = bit_transpose32(
        cw.reshape(M, c_chunks, 32, cw.shape[-1]), axis=2
    )

    # -- per-sample feedback on the two touched class rows -------------------
    def sample_rows(k, i, lits_i, y):
        k_neg, k_tgt, k_not = jax.random.split(k, 3)
        neg = jax.random.randint(k_neg, (), 0, M - 1)
        neg = jnp.where(neg >= y, neg + 1, neg).astype(jnp.int32)
        word, bit = i // 32, i % 32

        def row_delta(kk, m, is_target):
            sat_words = planes[m, :, bit, word]  # uint32[c_chunks]
            sat = unpack_bits(sat_words)[:C].astype(jnp.bool_)
            row = flat[m].astype(jnp.int32) + (N + 1)  # widen ONLY this row
            new = _feedback_from_clause_outputs(
                cfg, kk, row, actions[m], sat, lits_i, is_target
            )
            return new - row

        d_t = row_delta(k_tgt, y, jnp.bool_(True))
        d_n = row_delta(k_not, neg, jnp.bool_(False))
        return jnp.stack([y, neg]), jnp.stack([d_t, d_n])

    keys = sample_keys(key, B)
    ids, deltas = jax.vmap(sample_rows)(
        keys, jnp.arange(B), lits_all, yb
    )  # int32[B, 2], int32[B, 2, C, 2F]

    # -- scatter-add the 2B touched rows, clip in the centered int8 domain --
    summed = (
        jnp.zeros((M, C, L), jnp.int32)
        .at[ids.reshape(-1)]
        .add(deltas.reshape(-1, C, L))
    )
    # clip(state + d, 1, 2N) - (N+1)  ==  clip(packed + d, -N, N-1)
    new_flat = jnp.clip(flat.astype(jnp.int32) + summed, -N, N - 1)
    return new_flat.astype(jnp.int8).reshape(M, C, cfg.n_features, 2)


def fused_fit_step(
    cfg: TMConfig,
    packed: Array,
    key: Array,
    xb: Array,
    yb: Array,
    *,
    step: int,
    plan=None,
) -> Array:
    """Resumable fused step under the fold-in seeding contract.

    Same contract as ``core.train.fit_step``: the batch trains under
    ``fold_in(key, step)`` and sample ``i`` under ``fold_in(call_key,
    i)``, so (key, step, state) checkpoints round-trip bit-exactly
    between this kernel and the reference/sharded paths.  ``plan`` opts
    into the negotiated batch envelope (structured ``CapacityExceeded``).
    """
    validate_batch_capacity(xb.shape[0], plan)
    kb = jax.random.fold_in(key, step)
    return fused_train_batch(cfg, packed, kb, xb, yb)
