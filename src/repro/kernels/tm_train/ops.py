"""Packed int8 TA-state representation for the fused training path.

The FPGA online-learning architecture (Prescott et al.) keeps TA states
on-device as narrow counters; the C reference implementations
(green_tsetlin et al.) use the flat ``(clauses, literals, 2)`` int8
layout.  This module is the host-side adapter between the repo's
canonical TA tensor — ``int32[M, C, 2F]`` with states in ``[1, 2N]`` and
the interleaved literal order of ``core.tm`` (slot ``2k`` = feature k,
``2k+1`` = NOT k) — and the packed form the fused kernel trains in:

    ``int8[M, C, F, 2]``   with   packed = state - (N + 1)  in  [-N, N-1]

The last axis is (literal, negated literal) — exactly the canonical
interleaved ``2F`` axis reshaped to ``(F, 2)``, so packing is a
subtract + cast + reshape, never a permutation.  The Include action
becomes a sign test: ``state > N  <=>  packed >= 0``.

int8 holds the full state range iff ``2N <= 256`` (``n_states <= 128``,
the repo default); ``supports_packed_states`` / ``check_packable`` gate
that — a config outside the int8 envelope must use the reference or
sharded engines instead of silently wrapping.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.tm import TMConfig

Array = jax.Array

# packed = state - (n_states + 1); int8 range [-128, 127] holds
# [1 - (N+1), 2N - (N+1)] = [-N, N-1] exactly when N <= 128
MAX_PACKED_STATES = 128


def supports_packed_states(cfg: TMConfig) -> bool:
    """True when the config's TA range fits the int8 packed layout."""
    return cfg.n_states <= MAX_PACKED_STATES


def check_packable(cfg: TMConfig) -> None:
    if not supports_packed_states(cfg):
        raise ValueError(
            f"packed int8 TA states hold at most 2*{MAX_PACKED_STATES} "
            f"levels, but n_states={cfg.n_states} needs {2 * cfg.n_states}; "
            f"use the 'reference' or 'sharded' train engines for this config"
        )


def pack_ta_state(cfg: TMConfig, state: Array) -> Array:
    """Canonical ``int32[M, C, 2F]`` -> packed ``int8[M, C, F, 2]``."""
    check_packable(cfg)
    state = jnp.asarray(state)
    packed = (state.astype(jnp.int32) - (cfg.n_states + 1)).astype(jnp.int8)
    return packed.reshape(
        cfg.n_classes, cfg.n_clauses, cfg.n_features, 2
    )


def unpack_ta_state(cfg: TMConfig, packed: Array) -> Array:
    """Packed ``int8[M, C, F, 2]`` -> canonical ``int32[M, C, 2F]``."""
    packed = jnp.asarray(packed)
    flat = packed.reshape(cfg.n_classes, cfg.n_clauses, cfg.n_literals)
    return flat.astype(jnp.int32) + (cfg.n_states + 1)


def packed_include_actions(packed: Array) -> Array:
    """bool include mask straight off the packed representation
    (``state > N`` is a sign test in the centered int8 domain)."""
    return packed >= 0
