# Pallas kernel families for TM inference — see README.md in this
# directory for the family map (clause_eval / clause_matmul / tm_interp /
# tm_popcount), the Fig 4/5 memory-layout mapping, and when the tuning.py
# block-size table applies.
