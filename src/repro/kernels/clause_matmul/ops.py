"""jit'd wrapper: full dense TM class sums via the MXU clause kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import clause_matmul


@partial(jax.jit, static_argnames=("n_classes", "interpret"))
def tm_matmul_class_sums(
    actions: jax.Array,  # {0,1}[M, C, 2F]
    lits: jax.Array,  # {0,1}[2F, B]
    *,
    n_classes: int,
    interpret: bool = False,
) -> jax.Array:
    """-> int32[M, B] class sums (MXU formulation)."""
    m, c, l2 = actions.shape
    fired = clause_matmul(actions.reshape(m * c, l2), lits, interpret=interpret)
    pol = jnp.tile(
        jnp.where(jnp.arange(c) % 2 == 0, 1, -1).astype(jnp.int32), m
    )
    contrib = fired * pol[:, None]
    return contrib.reshape(m, c, -1).sum(axis=1)
