"""Pallas TPU kernel: MXU-formulated TM clause evaluation.

Tiled integer matmul ``violations = A @ (1 - L)`` with the K (literal)
dimension streamed through VMEM (the classic K-loop: grid =
(clause tiles, batch tiles, literal tiles), accumulator scratch persists
across the K tiles), followed by the ==0 test in the epilogue.

MXU alignment: tiles are multiples of (128, 128); inputs are cast to the
matmul dtype (bf16 is exact here — violation counts are < 2^8 per tile and
accumulation happens in fp32 on the MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _clause_matmul_kernel(a_ref, nl_ref, nonempty_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    acc_ref[...] += jax.lax.dot(
        a_ref[...].astype(jnp.bfloat16),
        nl_ref[...].astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        viol = acc_ref[...]
        fired = (viol < 0.5) & (nonempty_ref[...] > 0)
        out_ref[...] = fired.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_b", "block_k", "interpret")
)
def clause_matmul(
    actions: jax.Array,  # {0,1}[NC, L2]
    lits: jax.Array,  # {0,1}[L2, B]
    *,
    block_c: int = 128,
    block_b: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """-> int32[NC, B] clause outputs via MXU matmul."""
    nc, l2 = actions.shape
    _, b = lits.shape
    bc, bb, bk = (min(block_c, nc), min(block_b, b), min(block_k, l2))
    ncp, bp, l2p = (-(-nc // bc) * bc, -(-b // bb) * bb, -(-l2 // bk) * bk)
    a = jnp.pad(actions.astype(jnp.int32), ((0, ncp - nc), (0, l2p - l2)))
    nl = jnp.pad(
        1 - lits.astype(jnp.int32), ((0, l2p - l2), (0, bp - b))
    )  # pad rows are 0 = no violation contribution
    nonempty = jnp.sum(a, axis=1, keepdims=True)  # [NCp, 1]
    nonempty = jnp.broadcast_to(nonempty, (ncp, bp))

    out = pl.pallas_call(
        _clause_matmul_kernel,
        grid=(ncp // bc, bp // bb, l2p // bk),
        in_specs=[
            pl.BlockSpec((bc, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bb), lambda i, j, k: (k, j)),
            pl.BlockSpec((bc, bb), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bc, bb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ncp, bp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bc, bb), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, nl, nonempty)
    return out[:nc, :b]
