"""Pure-jnp oracle for the MXU-formulated clause evaluation.

Insight: a clause fires iff NO included literal is 0, i.e.

    violations[c, b] = sum_k A[c, k] * (1 - lits[k, b])
    clause_out[c, b] = (violations == 0) & nonempty[c]

— an integer MATMUL, which is what the TPU's systolic MXU is built for.
The paper's bitwise AND network (LUT fabric) maps to the VPU; this
formulation trades 32x word parallelism for the MXU's 197 TFLOP/s.  The
cross-over (dense models / small batches favor MXU; sparse models / big
batches favor the packed VPU path) is benchmarked in fig9.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clause_matmul_ref(actions: jax.Array, lits: jax.Array) -> jax.Array:
    """actions: {0,1}[NC, L2] ; lits: {0,1}[L2, B] -> bool[NC, B]."""
    a = actions.astype(jnp.int32)
    viol = a @ (1 - lits.astype(jnp.int32))  # [NC, B]
    nonempty = jnp.sum(a, axis=1, keepdims=True) > 0
    return (viol == 0) & nonempty
