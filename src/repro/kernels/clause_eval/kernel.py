"""Pallas TPU kernel: dense bitpacked TM clause evaluation.

The paper's clause compute (Fig 2, green) — every included literal ANDed
into a 1-bit clause output — adapted to the TPU memory hierarchy:

  * the batch dimension is bit-packed 32-wide into uint32 lanes (the
    paper's word-batching, Fig 4.5), so one VPU op processes
    32 datapoints x 8x128 lanes;
  * the include mask block and the packed-literal block are staged in VMEM
    via BlockSpec; the literal reduction runs out of VREGs;
  * grid = (clause blocks x batch-word blocks), both parallel.

VMEM working set per step: BC*L2 (mask, int8-ish) + L2*BW*4 (literals)
+ BC*BW*4 (acc) bytes — BC/BW chosen so this sits well under ~16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ONES = 0xFFFFFFFF  # python int: safe to close over in kernels


def _clause_eval_kernel(actions_ref, lits_ref, out_ref):
    a = actions_ref[...]  # int32 {0,1} [BC, L2] (VMEM)
    lits = lits_ref[...]  # uint32 [L2, BW]      (VMEM)
    bc, l2 = a.shape
    bw = lits.shape[1]

    def body(k, acc):
        a_k = jax.lax.dynamic_index_in_dim(a, k, axis=1, keepdims=False)  # [BC]
        w_k = jax.lax.dynamic_index_in_dim(lits, k, axis=0, keepdims=False)  # [BW]
        masked = jnp.where((a_k == 1)[:, None], w_k[None, :], jnp.uint32(ONES))
        return acc & masked

    acc = jax.lax.fori_loop(0, l2, body, jnp.full((bc, bw), jnp.uint32(ONES), jnp.uint32))
    nonempty = jnp.sum(a, axis=1, keepdims=True) > 0
    out_ref[...] = jnp.where(nonempty, acc, jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("block_clauses", "block_words", "interpret"))
def clause_eval(
    actions: jax.Array,  # {0,1}[NC, L2] int32
    packed_lits: jax.Array,  # uint32[L2, W]
    *,
    block_clauses: int = 128,
    block_words: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """uint32[NC, W] clause output words (empty clause -> 0)."""
    nc, l2 = actions.shape
    l2_, w = packed_lits.shape
    assert l2 == l2_
    bc = min(block_clauses, nc)
    bw = min(block_words, w)
    nc_pad = -(-nc // bc) * bc
    w_pad = -(-w // bw) * bw
    actions = jnp.pad(actions.astype(jnp.int32), ((0, nc_pad - nc), (0, 0)))
    packed_lits = jnp.pad(packed_lits, ((0, 0), (0, w_pad - w)))

    out = pl.pallas_call(
        _clause_eval_kernel,
        grid=(nc_pad // bc, w_pad // bw),
        in_specs=[
            pl.BlockSpec((bc, l2), lambda i, j: (i, 0)),
            pl.BlockSpec((l2, bw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bc, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nc_pad, w_pad), jnp.uint32),
        interpret=interpret,
    )(actions, packed_lits)
    return out[:nc, :w]
