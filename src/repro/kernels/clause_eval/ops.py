"""jit'd public wrappers for the clause_eval kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import clause_eval
from .ref import class_sums_from_clause_words


@partial(jax.jit, static_argnames=("n_classes", "interpret"))
def tm_dense_class_sums(
    actions: jax.Array,  # {0,1}[M, C, 2F]
    packed_lits: jax.Array,  # uint32[2F, W]
    *,
    n_classes: int,
    interpret: bool = False,
) -> jax.Array:
    """Full dense bitpacked TM inference -> int32[M, B] class sums.

    Clause evaluation runs in the Pallas kernel; the (cheap) polarity
    summation is plain jnp on the kernel output.
    """
    m, c, l2 = actions.shape
    clause_words = clause_eval(
        actions.reshape(m * c, l2), packed_lits, interpret=interpret
    )
    pol = jnp.where(jnp.arange(c) % 2 == 0, 1, -1).astype(jnp.int32)
    pol = jnp.tile(pol, m)
    return class_sums_from_clause_words(clause_words, pol, n_classes)
