"""Pure-jnp oracle for the bitpacked clause-evaluation kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clause_eval_ref(actions: jax.Array, packed_lits: jax.Array) -> jax.Array:
    """Dense bitpacked clause evaluation.

    actions:     {0,1}[NC, L2]   include mask (NC = flattened class*clause)
    packed_lits: uint32[L2, W]   batch-bitpacked literals
    returns:     uint32[NC, W]   clause output words; empty clause -> 0
                                 (inference semantics)
    """
    ones = jnp.uint32(0xFFFFFFFF)

    def one_clause(a_row):
        masked = jnp.where(a_row.astype(bool)[:, None], packed_lits, ones)
        return jax.lax.reduce(masked, ones, jnp.bitwise_and, dimensions=(0,))

    out = jax.vmap(one_clause)(actions)  # [NC, W]
    nonempty = jnp.any(actions.astype(bool), axis=-1)
    return jnp.where(nonempty[:, None], out, jnp.uint32(0))


def class_sums_from_clause_words(
    clause_words: jax.Array, pol: jax.Array, n_classes: int
) -> jax.Array:
    """uint32[M*C, W], int32[M*C] -> int32[M, W*32]."""
    mc, w = clause_words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((clause_words[..., None] >> shifts) & 1).astype(jnp.int32)
    bits = bits.reshape(mc, w * 32)
    contrib = bits * pol[:, None]
    return contrib.reshape(n_classes, mc // n_classes, w * 32).sum(axis=1)
