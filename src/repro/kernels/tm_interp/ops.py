"""jit'd wrappers: DecodedPlan -> kernel operands -> class sums/predictions."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.compress import DecodedPlan
from .kernel import tm_interp


def plan_to_operands(
    plan: DecodedPlan, i_cap: int, m_cap: int | None = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host-side: flatten the plan into per-instruction operand vectors.

    Padded slots AND literal row 0 forever and never emit (last=0).

    When ``m_cap`` is given, class ids are validated against the class-sum
    bank depth HERE, at program-build time: the kernels clamp out-of-range
    rows (a physical-accumulator bound, like the hardware), which would
    silently corrupt boundary-row sums on a malformed program.  A bad id
    raises ``ValueError`` naming the offending instruction instead.
    """
    n_inc = plan.n_includes
    assert n_inc <= i_cap, f"plan has {n_inc} includes; instruction capacity {i_cap}"
    lit_idx = np.zeros(i_cap, np.int32)
    last = np.zeros(i_cap, np.int32)
    pol = np.zeros(i_cap, np.int32)
    cls = np.zeros(i_cap, np.int32)
    lit_idx[:n_inc] = plan.lit_idx
    # last include of each clause = where clause_id changes (or stream ends)
    if n_inc > 0:
        boundary = np.ones(n_inc, bool)
        boundary[:-1] = plan.clause_id[1:] != plan.clause_id[:-1]
        last[:n_inc] = boundary.astype(np.int32)
        pol[:n_inc] = plan.clause_pol[plan.clause_id]
        cls[:n_inc] = plan.clause_class[plan.clause_id]
        if m_cap is not None:
            bad = np.flatnonzero(
                (cls[:n_inc] < 0) | (cls[:n_inc] >= m_cap)
            )
            if bad.size:
                t = int(bad[0])
                raise ValueError(
                    f"instruction {t}: class id {int(cls[t])} out of range "
                    f"for class capacity m_cap={m_cap}; refusing to build a "
                    f"program that would corrupt the class-sum bank"
                )
    return lit_idx, last, pol, cls


def tm_compressed_class_sums(
    plan: DecodedPlan,
    packed_lits: jax.Array,  # uint32[2F, W] (interleaved literal rows)
    *,
    m_cap: int,
    i_cap: int,
    interpret: bool = False,
) -> jax.Array:
    """Compressed inference via the Pallas kernel -> int32[m_cap, B]."""
    lit_idx, last, pol, cls = plan_to_operands(plan, i_cap, m_cap=m_cap)
    return tm_interp(
        jnp.asarray(lit_idx),
        jnp.asarray(last),
        jnp.asarray(pol),
        jnp.asarray(cls),
        packed_lits,
        m_cap=m_cap,
        interpret=interpret,
    )


def pack_interleaved_literals(x: jax.Array) -> jax.Array:
    """{0,1}[B, F] -> uint32[2F, W] with complement rows interleaved."""
    from ...core.tm import pack_literals

    B = x.shape[0]
    pad = (-B) % 32
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return pack_literals(x)
