"""Pure-jnp oracle for the compressed-plan interpreter kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tm_interp_ref(
    lit_idx: jax.Array,  # int32[I]  literal slot per include
    last_flag: jax.Array,  # int32[I] 1 = last include of its clause
    pol: jax.Array,  # int32[I]  clause polarity (+1/-1), read when last
    cls: jax.Array,  # int32[I]  class id, read when last
    packed_lits: jax.Array,  # uint32[L2, W]
    m_cap: int,
) -> jax.Array:
    """Sequential oracle -> int32[m_cap, W*32] class sums.

    Padded instruction slots must have last_flag == 0 and follow all real
    instructions (their ANDs can only corrupt a clause that never emits).
    """
    l2, w = packed_lits.shape
    B = w * 32
    ones = jnp.uint32(0xFFFFFFFF)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def unpack(acc):
        bits = (acc[:, None] >> shifts) & 1
        return bits.reshape(B).astype(jnp.int32)

    def step(carry, t):
        acc, sums = carry
        word = packed_lits[lit_idx[t]]
        acc = acc & word
        emit = last_flag[t] == 1
        contrib = jnp.where(emit, pol[t], 0) * unpack(acc)
        sums = sums.at[jnp.clip(cls[t], 0, m_cap - 1)].add(contrib)
        acc = jnp.where(emit, jnp.full_like(acc, ones), acc)
        return (acc, sums), None

    acc0 = jnp.full((w,), ones, jnp.uint32)
    sums0 = jnp.zeros((m_cap, B), jnp.int32)
    (acc, sums), _ = jax.lax.scan(
        step, (acc0, sums0), jnp.arange(lit_idx.shape[0])
    )
    return sums
