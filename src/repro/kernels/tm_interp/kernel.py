"""Pallas TPU kernel: compressed TM inference from the decoded plan.

TPU adaptation of the paper's instruction-execution pipeline (Fig 5): the
offset chains are already prefix-summed (program-time decode), so the kernel
streams *absolute* literal indices.  Per instruction:

    fetch -> literal select (VMEM row gather) -> clause AND (VPU, 32
    datapoints/lane) -> on clause boundary: signed accumulate into the
    class-sum bank (VMEM scratch)

Layout:
  * grid = (batch-word blocks [parallel], instruction blocks [arbitrary]);
    the clause accumulator and class-sum bank live in VMEM scratch and
    persist across instruction blocks (the "K-loop" pattern);
  * the packed-literal panel for the current batch block stays resident in
    VMEM (L2 x BW uint32 = the accelerator's Feature Memory, Fig 4.5);
  * instruction operands are int32 vectors staged per block (the
    Instruction Memory, Fig 4.4).

This mirrors the eFPGA design point: model-agnostic compute, model = data.
Capacity (I_cap, L2, m_cap) is the synthesis-time choice; contents are
runtime-tunable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ONES = 0xFFFFFFFF  # python int: safe to close over in kernels


def _tm_interp_kernel(
    lit_idx_ref, last_ref, pol_ref, cls_ref, lits_ref, out_ref, acc_ref, sums_ref
):
    bi = lit_idx_ref.shape[0]
    bw = lits_ref.shape[1]
    B = bw * 32

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.full((1, bw), jnp.uint32(ONES), jnp.uint32)
        sums_ref[...] = jnp.zeros(sums_ref.shape, jnp.int32)

    lit_idx = lit_idx_ref[...]
    last = last_ref[...]
    pol = pol_ref[...]
    cls = cls_ref[...]
    lits = lits_ref[...]  # [L2, BW] uint32 — Feature Memory panel
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(t, carry):
        acc, sums = carry
        word = jax.lax.dynamic_index_in_dim(
            lits, lit_idx[t], axis=0, keepdims=False
        )  # [BW] — Literal Select
        acc = acc & word  # Clause Compute (32 datapoints/lane)
        emit = last[t] == 1
        bits = ((acc[:, None] >> shifts) & 1).reshape(1, B).astype(jnp.int32)
        contrib = jnp.where(emit, pol[t], 0) * bits  # [1, B]
        # physical accumulator bound; plan_to_operands(m_cap=...) rejects
        # out-of-range class ids at program-build time, so this never
        # silently redirects a malformed program's sums into a live row
        row = jnp.clip(cls[t], 0, sums.shape[0] - 1)
        sums = jax.lax.dynamic_update_slice(
            sums, jax.lax.dynamic_slice(sums, (row, 0), (1, B)) + contrib, (row, 0)
        )
        acc = jnp.where(emit, jnp.full_like(acc, jnp.uint32(ONES)), acc)
        return acc, sums

    acc0 = acc_ref[0, :]
    sums0 = sums_ref[...]
    acc, sums = jax.lax.fori_loop(0, bi, body, (acc0, sums0))
    acc_ref[...] = acc[None, :]
    sums_ref[...] = sums
    out_ref[...] = sums


@functools.partial(
    jax.jit, static_argnames=("m_cap", "block_instructions", "block_words", "interpret")
)
def tm_interp(
    lit_idx: jax.Array,  # int32[I_cap]
    last_flag: jax.Array,  # int32[I_cap]
    pol: jax.Array,  # int32[I_cap]
    cls: jax.Array,  # int32[I_cap]
    packed_lits: jax.Array,  # uint32[L2, W]
    *,
    m_cap: int,
    block_instructions: int = 512,
    block_words: int = 4,
    interpret: bool = False,
) -> jax.Array:
    """Compressed inference -> int32[m_cap, W*32] class sums."""
    i_cap = lit_idx.shape[0]
    l2, w = packed_lits.shape
    bi = min(block_instructions, i_cap)
    bw = min(block_words, w)
    i_pad = -(-i_cap // bi) * bi
    w_pad = -(-w // bw) * bw

    def padi(a):  # padded instructions: AND row 0 forever, never emit
        return jnp.pad(a, (0, i_pad - i_cap))

    lit_idx, last_flag, pol, cls = map(padi, (lit_idx, last_flag, pol, cls))
    packed_lits = jnp.pad(packed_lits, ((0, 0), (0, w_pad - w)))

    out = pl.pallas_call(
        _tm_interp_kernel,
        grid=(w_pad // bw, i_pad // bi),
        in_specs=[
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((l2, bw), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((m_cap, bw * 32), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m_cap, w_pad * 32), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((1, bw), jnp.uint32),  # clause accumulator
            pltpu.VMEM((m_cap, bw * 32), jnp.int32),  # class-sum bank
        ],
        interpret=interpret,
    )(lit_idx, last_flag, pol, cls, packed_lits)
    return out[:, : w * 32]
