"""Pallas TPU kernel: popcount bitplane inference over the decoded plan.

The paper's pitch is that compressed-TM inference is nothing but bitwise
AND/NOT plus popcount-style summation — yet the interpreter kernel
(``tm_interp``) expands the packed clause accumulator into ``int32[1, B]``
bit vectors on EVERY instruction and read-modify-writes the class-sum bank
with a ``dynamic_slice``/``dynamic_update_slice`` pair per step.  This
kernel keeps everything packed until one popcount reduction per
instruction block:

  1. The sequential sweep only ANDs packed ``uint32`` words: per
     instruction, ``acc &= lits[lit_idx[t]]`` (32 datapoints/lane) and, on
     a clause boundary, the emitted clause word is stored into a
     block-local emit buffer (zero when the instruction does not emit).
     No bit expansion, no sum-bank scatter inside the loop.
  2. Once per instruction block, the ``[bi, BW]`` emit buffer is
     bit-transposed in 32x32 tiles (5 masked shift/XOR rounds — the
     classic bitplane transpose), yielding per-datapoint words whose bit j
     is clause-output bit of instruction ``32c+j``.
  3. Class routing is scatter-free: the program is compiled (host-side,
     at program time) into per-class *polarity-bank* selection bitplanes
     ``mask_pos/mask_neg[m_cap, I/32]`` — bit j of chunk c selects
     instruction ``32c+j`` iff it emits a +/- clause of that class.  Class
     sums are then
         sums[m, b] += popcount(T[c, b] & mask_pos[m, c])
                     - popcount(T[c, b] & mask_neg[m, c])
     via ``jax.lax.population_count`` — the Fig 4.6 accumulate stage as
     32-way popcounts instead of 32 scalar adds.

Layout mirrors ``tm_interp``: grid = (batch-word blocks [parallel],
instruction blocks [arbitrary]); the packed clause accumulator and the
class-sum bank live in VMEM scratch and persist across instruction blocks;
the packed-literal panel (Feature Memory, Fig 4.5) stays VMEM-resident per
batch block.  Block shapes default to the measured table in
``kernels.tuning`` (a per-capacity synthesis-time choice, never a runtime
recompile).

``tm_popcount_xla`` is the same algorithm phrased as pure XLA ops (gather +
segmented AND scan + bit transpose + popcount): the portable fast path the
serving executors use on CPU/GPU, bit-exact with the Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..tuning import choose_blocks

ONES = 0xFFFFFFFF  # python int: safe to close over in kernels

# (shift, mask) rounds of the 32x32 bitplane transpose (Hacker's Delight
# 7-3, vectorized); applied to a reversed word axis so the result follows
# the little-endian convention used everywhere else in this repo:
# out word b holds, at bit j, bit b of input word j.
_TRANSPOSE_ROUNDS = (
    (16, 0x0000FFFF),
    (8, 0x00FF00FF),
    (4, 0x0F0F0F0F),
    (2, 0x33333333),
    (1, 0x55555555),
)


def bit_transpose32(x: jax.Array, axis: int) -> jax.Array:
    """Transpose 32x32 bit tiles held along ``axis`` (size 32) of uint32.

    ``out[..., b, ...]`` has bit j equal to bit b of ``x[..., j, ...]``.
    Five masked shift/XOR rounds, fully vectorized over all other axes.
    """
    x = jnp.moveaxis(x, axis, -1)[..., ::-1]
    lead = x.shape[:-1]
    for s, m in _TRANSPOSE_ROUNDS:
        m = jnp.uint32(m)
        y = x.reshape(*lead, 32 // (2 * s), 2, s)
        a, b = y[..., 0, :], y[..., 1, :]
        t = (a ^ (b >> s)) & m
        x = jnp.stack([a ^ t, b ^ (t << s)], axis=-2).reshape(*lead, 32)
    return jnp.moveaxis(x[..., ::-1], -1, axis)


def popcount_reduce(
    emit_words: jax.Array,  # uint32[I, W], I % 32 == 0; 0 unless emitting
    mask_pos: jax.Array,  # uint32[m_cap, I//32] or uint32[P, m_cap, I//32]
    mask_neg: jax.Array,  # same shape as mask_pos
) -> jax.Array:
    """Emit buffer + polarity-bank bitplanes -> int32[m_cap, W*32] sums.

    2-D masks are the classic unit-weight banks.  3-D masks are the
    repro.prune weighted form: plane ``b`` selects emitting instructions
    whose clause weight has bit ``b`` set, and the reduction becomes

        sums = sum_b ((pop(T & pos[b]) - pop(T & neg[b])) << b)

    — shifted popcounts, NO multiplies, so the weighted engine keeps the
    paper's bitwise-only execution contract.  Plane 0 of an all-ones
    weight vector reproduces the unit-weight banks bit-exactly."""
    i, w = emit_words.shape
    planes = bit_transpose32(emit_words.reshape(i // 32, 32, w), axis=1)
    # planes[c, b, w] bit j = clause-output bit b (datapoint 32w+b) of
    # instruction 32c+j; select per class with one AND, count with popcount
    if mask_pos.ndim == 2:
        pos = jax.lax.population_count(
            planes[None] & mask_pos[:, :, None, None]
        )
        neg = jax.lax.population_count(
            planes[None] & mask_neg[:, :, None, None]
        )
        sums = (pos.astype(jnp.int32) - neg.astype(jnp.int32)).sum(axis=1)
        return sums.transpose(0, 2, 1).reshape(mask_pos.shape[0], w * 32)
    p, m_cap, _ = mask_pos.shape
    pos = jax.lax.population_count(
        planes[None, None] & mask_pos[:, :, :, None, None]
    )  # [P, m, chunks, 32, W]
    neg = jax.lax.population_count(
        planes[None, None] & mask_neg[:, :, :, None, None]
    )
    per_plane = (pos.astype(jnp.int32) - neg.astype(jnp.int32)).sum(axis=2)
    shifts = jnp.arange(p, dtype=jnp.int32)[:, None, None, None]
    sums = jnp.left_shift(per_plane, shifts).sum(axis=0)  # [m, 32, W]
    return sums.transpose(0, 2, 1).reshape(m_cap, w * 32)


def _tm_popcount_kernel(
    lit_idx_ref, last_ref, mask_pos_ref, mask_neg_ref, lits_ref,
    out_ref, acc_ref, emit_ref, sums_ref,
):
    bi = lit_idx_ref.shape[0]
    bw = lits_ref.shape[1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.full((1, bw), jnp.uint32(ONES), jnp.uint32)
        sums_ref[...] = jnp.zeros(sums_ref.shape, jnp.int32)

    lit_idx = lit_idx_ref[...]
    last = last_ref[...]
    lits = lits_ref[...]  # [L2, BW] uint32 — Feature Memory panel

    def body(t, acc):
        word = jax.lax.dynamic_index_in_dim(
            lits, lit_idx[t], axis=0, keepdims=False
        )  # [BW] — Literal Select
        acc = acc & word  # Clause Compute: packed AND, nothing expanded
        emit = last[t] == 1
        pl.store(
            emit_ref,
            (pl.dslice(t, 1), slice(None)),
            jnp.where(emit, acc, jnp.uint32(0))[None, :],
        )
        return jnp.where(emit, jnp.full_like(acc, jnp.uint32(ONES)), acc)

    acc_ref[...] = jax.lax.fori_loop(0, bi, body, acc_ref[0, :])[None, :]
    # one bitplane transpose + popcount reduction per instruction block
    sums_ref[...] += popcount_reduce(
        emit_ref[...], mask_pos_ref[...], mask_neg_ref[...]
    )
    out_ref[...] = sums_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_instructions", "block_words", "interpret")
)
def tm_popcount(
    lit_idx: jax.Array,  # int32[I_cap]  absolute literal slot (padded: 0)
    last_flag: jax.Array,  # int32[I_cap] 1 = last include of its clause
    mask_pos: jax.Array,  # uint32[m_cap, ceil(I_cap/32)] +clause selectors
    mask_neg: jax.Array,  # uint32[m_cap, ceil(I_cap/32)] -clause selectors
    packed_lits: jax.Array,  # uint32[L2, W]
    *,
    block_instructions: int | None = None,
    block_words: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Popcount-bitplane inference -> int32[m_cap, W*32] class sums.

    Block shapes default to the measured ``kernels.tuning`` table for this
    capacity point; ``block_instructions`` must be a multiple of 32 (the
    class masks pack 32 instructions per word).

    3-D masks (``[P, m_cap, chunks]``, repro.prune weighted clauses) run
    the SAME kernel with the plane axis flattened into the class axis —
    the kernel popcounts ``P * m_cap`` banks — and the per-plane sums are
    combined outside with shifted adds (``<< b``), keeping the kernel body
    untouched and the whole path multiply-free.
    """
    if mask_pos.ndim == 3:
        p, m_cap, chunks = mask_pos.shape
        sums = tm_popcount(
            lit_idx, last_flag,
            mask_pos.reshape(p * m_cap, chunks),
            mask_neg.reshape(p * m_cap, chunks),
            packed_lits,
            block_instructions=block_instructions,
            block_words=block_words, interpret=interpret,
        ).reshape(p, m_cap, -1)
        shifts = jnp.arange(p, dtype=jnp.int32)[:, None, None]
        return jnp.left_shift(sums, shifts).sum(axis=0)
    i_cap = lit_idx.shape[0]
    m_cap = mask_pos.shape[0]
    l2, w = packed_lits.shape
    if block_instructions is not None and block_instructions % 32:
        raise ValueError(
            f"block_instructions must be a multiple of 32, got "
            f"{block_instructions}"
        )
    if block_instructions is None or block_words is None:
        auto_bi, auto_bw = choose_blocks(i_cap, w)
        block_instructions = block_instructions or auto_bi
        block_words = block_words or auto_bw
    # clip to the 32-aligned instruction depth; both operands are 32-aligned
    bi = max(32, min(block_instructions, -(-i_cap // 32) * 32))
    bw = min(block_words, w)
    i_pad = -(-i_cap // bi) * bi
    w_pad = -(-w // bw) * bw

    def padi(a):  # padded instructions: AND row 0 forever, never emit
        return jnp.pad(a, (0, i_pad - i_cap))

    lit_idx, last_flag = padi(lit_idx), padi(last_flag)
    mask_pos, mask_neg = (
        jnp.pad(m, ((0, 0), (0, i_pad // 32 - m.shape[1])))
        for m in (mask_pos, mask_neg)
    )
    packed_lits = jnp.pad(packed_lits, ((0, 0), (0, w_pad - w)))

    out = pl.pallas_call(
        _tm_popcount_kernel,
        grid=(w_pad // bw, i_pad // bi),
        in_specs=[
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((bi,), lambda j, i: (i,)),
            pl.BlockSpec((m_cap, bi // 32), lambda j, i: (0, i)),
            pl.BlockSpec((m_cap, bi // 32), lambda j, i: (0, i)),
            pl.BlockSpec((l2, bw), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((m_cap, bw * 32), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m_cap, w_pad * 32), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((1, bw), jnp.uint32),  # packed clause accumulator
            pltpu.VMEM((bi, bw), jnp.uint32),  # block emit buffer
            pltpu.VMEM((m_cap, bw * 32), jnp.int32),  # class-sum bank
        ],
        interpret=interpret,
    )(lit_idx, last_flag, mask_pos, mask_neg, packed_lits)
    return out[:, : w * 32]


def _segmented_and_scan(sel: jax.Array, start: jax.Array) -> jax.Array:
    """Inclusive AND scan over axis 0 with resets where ``start`` is True.

    Standard segmented-scan combine — associative, so XLA evaluates it in
    log2(I) parallel rounds instead of the interpreter's I sequential ones.
    """

    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[:, None], vb, va & vb)

    _, acc = jax.lax.associative_scan(combine, (start, sel), axis=0)
    return acc


@jax.jit
def tm_popcount_xla(
    lit_idx: jax.Array,  # int32[I_cap]
    last_flag: jax.Array,  # int32[I_cap]
    mask_pos: jax.Array,  # uint32[m_cap, ceil(I_cap/32)]
    mask_neg: jax.Array,  # uint32[m_cap, ceil(I_cap/32)]
    packed_lits: jax.Array,  # uint32[L2, W]
) -> jax.Array:
    """The popcount bitplane algorithm as pure XLA -> int32[m_cap, W*32].

    Bit-exact with ``tm_popcount``; this is what the serving executors run
    off-TPU (Pallas interpret mode emulates the grid and is far slower than
    native XLA on CPU).
    """
    i_cap = lit_idx.shape[0]
    i_pad = -(-i_cap // 32) * 32
    lit_idx = jnp.pad(lit_idx, (0, i_pad - i_cap))
    last_flag = jnp.pad(last_flag, (0, i_pad - i_cap))
    pad_chunks = i_pad // 32 - mask_pos.shape[-1]
    lead = ((0, 0),) * (mask_pos.ndim - 1)
    mask_pos = jnp.pad(mask_pos, lead + ((0, pad_chunks),))
    mask_neg = jnp.pad(mask_neg, lead + ((0, pad_chunks),))

    sel = jnp.take(packed_lits, lit_idx, axis=0)  # [I, W] literal select
    emit = last_flag == 1
    start = jnp.concatenate([jnp.ones((1,), bool), emit[:-1]])
    acc = _segmented_and_scan(sel, start)  # packed clause outputs
    emit_words = jnp.where(emit[:, None], acc, jnp.uint32(0))
    return popcount_reduce(emit_words, mask_pos, mask_neg)
