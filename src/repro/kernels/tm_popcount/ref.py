"""Pure-jnp oracle for the popcount bitplane kernel.

Deliberately naive: one instruction per ``lax.scan`` step, reading the
class routing straight out of the packed polarity-bank bitplanes (bit j of
mask chunk ``t // 32`` selects instruction t), expanding the clause word
and scatter-adding — i.e. none of the kernel's tricks.  Used to prove the
mask encoding and the block-parallel reduction independently; the kernel
itself is additionally proven against ``tm_interp/ref.py`` (same class
sums from the pol/cls operand encoding) in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tm_popcount_ref(
    lit_idx: jax.Array,  # int32[I]  literal slot per include
    last_flag: jax.Array,  # int32[I] 1 = last include of its clause
    mask_pos: jax.Array,  # uint32[m_cap, ceil(I/32)]
    mask_neg: jax.Array,  # uint32[m_cap, ceil(I/32)]
    packed_lits: jax.Array,  # uint32[L2, W]
) -> jax.Array:
    """Sequential oracle -> int32[m_cap, W*32] class sums."""
    m_cap = mask_pos.shape[0]
    _, w = packed_lits.shape
    B = w * 32
    ones = jnp.uint32(0xFFFFFFFF)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def unpack(acc):  # uint32[W] -> int32[B]
        return ((acc[:, None] >> shifts) & 1).reshape(B).astype(jnp.int32)

    def step(carry, t):
        acc, sums = carry
        acc = acc & packed_lits[lit_idx[t]]
        chunk, bit = t // 32, (t % 32).astype(jnp.uint32)
        sel_pos = ((mask_pos[:, chunk] >> bit) & 1).astype(jnp.int32)
        sel_neg = ((mask_neg[:, chunk] >> bit) & 1).astype(jnp.int32)
        emit = last_flag[t] == 1
        gate = jnp.where(emit, sel_pos - sel_neg, 0)  # int32[m_cap]
        sums = sums + gate[:, None] * unpack(acc)[None, :]
        acc = jnp.where(emit, jnp.full_like(acc, ones), acc)
        return (acc, sums), None

    acc0 = jnp.full((w,), ones, jnp.uint32)
    sums0 = jnp.zeros((m_cap, B), jnp.int32)
    (_, sums), _ = jax.lax.scan(
        step, (acc0, sums0), jnp.arange(lit_idx.shape[0])
    )
    return sums
