"""Host-side program build for the popcount bitplane path.

``DecodedPlan -> (lit_idx, last, mask_pos, mask_neg)``: the per-include
operand vectors of the interpreter path, plus the per-class polarity-bank
selection bitplanes the popcount reduction keys on.  This is where a
malformed program is REJECTED: a class id outside the accumulator bank or
a literal slot outside the feature memory raises ``ValueError`` naming the
offending instruction, instead of silently clamping into class 0 / row 0
at execution time.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.compress import DecodedPlan
from ..tm_interp.ops import plan_to_operands
from .kernel import tm_popcount, tm_popcount_xla


def pack_class_masks(
    last: np.ndarray,  # int32[I_cap] 1 = clause boundary (emit)
    pol: np.ndarray,  # int32[I_cap] +1/-1, read where last == 1
    cls: np.ndarray,  # int32[I_cap] class id, read where last == 1
    m_cap: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Emit metadata -> packed polarity banks uint32[m_cap, ceil(I/32)].

    Bit j of chunk c in ``mask_pos[m]`` selects instruction ``32c + j``
    iff it emits a positive clause of class m (``mask_neg`` likewise for
    negative clauses).  Raises on class ids outside ``[0, m_cap)`` at an
    emitting instruction — the program-build-time guard that replaces the
    execution-time clamp.
    """
    last = np.asarray(last)
    i_cap = last.shape[0]
    emitting = np.flatnonzero(last == 1)
    bad = emitting[(cls[emitting] < 0) | (cls[emitting] >= m_cap)]
    if bad.size:
        t = int(bad[0])
        raise ValueError(
            f"instruction {t}: class id {int(cls[t])} out of range for "
            f"class capacity m_cap={m_cap}; refusing to build a program "
            f"that would corrupt the class-sum bank"
        )
    n_chunks = -(-i_cap // 32) * 32 // 32
    mask_pos = np.zeros((m_cap, n_chunks), np.uint32)
    mask_neg = np.zeros((m_cap, n_chunks), np.uint32)
    bit = (np.uint32(1) << (emitting % 32).astype(np.uint32))
    chunk = emitting // 32
    for bank, sign in ((mask_pos, 1), (mask_neg, -1)):
        sel = pol[emitting] == sign
        np.bitwise_or.at(bank, (cls[emitting][sel], chunk[sel]), bit[sel])
    return mask_pos, mask_neg


def pack_class_masks_weighted(
    last: np.ndarray,  # int32[I_cap] 1 = clause boundary (emit)
    pol: np.ndarray,  # int32[I_cap] +1/-1, read where last == 1
    cls: np.ndarray,  # int32[I_cap] class id, read where last == 1
    weights: np.ndarray,  # int32[I_cap] clause weight, read where last == 1
    m_cap: int,
    weight_planes: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Weighted emit metadata -> bitplane-decomposed polarity banks
    ``uint32[weight_planes, m_cap, ceil(I/32)]`` (repro.prune).

    Plane ``b`` selects instruction ``32c + j`` iff it emits a clause of
    that class AND bit ``b`` of the clause's weight is set, so the
    popcount reduction recovers ``weight * clause_output`` as
    ``sum_b (popcount << b)`` — shifted popcounts, no multiplies.  An
    all-ones weight vector occupies plane 0 only, reproducing the
    unit-weight banks exactly.  Raises when a weight needs more planes
    than provisioned (``weight_planes`` is a synthesis-time mask depth —
    the capacity knob the popcount engine validates)."""
    weights = np.asarray(weights)
    emitting = np.flatnonzero(np.asarray(last) == 1)
    w_emit = weights[emitting]
    if emitting.size:
        need = int(w_emit.max()).bit_length()
        if need > weight_planes:
            t = int(emitting[int(np.argmax(w_emit))])
            raise ValueError(
                f"instruction {t}: clause weight {int(weights[t])} needs "
                f"{need} bitplanes but the plan provisions "
                f"weight_planes={weight_planes}; re-negotiate the envelope"
            )
    planes = []
    for b in range(weight_planes):
        sel = np.zeros_like(np.asarray(last))
        sel[emitting] = (w_emit >> b) & 1
        planes.append(pack_class_masks(last * sel, pol, cls, m_cap))
    mask_pos = np.stack([p for p, _ in planes])
    mask_neg = np.stack([n for _, n in planes])
    return mask_pos, mask_neg


def plan_to_popcount_operands(
    plan: DecodedPlan,
    i_cap: int,
    m_cap: int,
    *,
    l2_cap: int | None = None,
    weight_planes: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten + validate the plan into popcount-kernel operands.

    Reuses the interpreter's operand flattening, bounds-checks literal
    slots against ``l2_cap`` when given, and packs the class masks —
    ``pack_class_masks`` owns the class-capacity validation (emitting
    instructions are the only ones the popcount routing ever reads).

    ``weight_planes`` controls the mask layout: ``None`` keeps the classic
    2-D banks for weightless plans (and auto-sizes 3-D banks for weighted
    ones); an explicit int always builds 3-D ``[P, m_cap, chunks]`` banks
    at exactly that synthesis-time depth — what the popcount engine pins
    so weighted/weightless swaps never change a compiled shape.
    """
    lit_idx, last, pol, cls = plan_to_operands(plan, i_cap)
    if l2_cap is not None and plan.n_includes > 0:
        bad = np.flatnonzero(
            (lit_idx[: plan.n_includes] < 0)
            | (lit_idx[: plan.n_includes] >= l2_cap)
        )
        if bad.size:
            t = int(bad[0])
            raise ValueError(
                f"instruction {t}: literal slot {int(lit_idx[t])} out of "
                f"range for feature memory depth {l2_cap}"
            )
    if weight_planes is None and plan.clause_weight is None:
        mask_pos, mask_neg = pack_class_masks(last, pol, cls, m_cap)
        return lit_idx, last, mask_pos, mask_neg
    planes = plan.weight_planes if weight_planes is None else weight_planes
    wts = np.ones(i_cap, np.int32)
    if plan.n_includes > 0:
        wts[: plan.n_includes] = plan.weights[plan.clause_id]
    mask_pos, mask_neg = pack_class_masks_weighted(
        last, pol, cls, wts, m_cap, planes
    )
    return lit_idx, last, mask_pos, mask_neg


def tm_popcount_class_sums(
    plan: DecodedPlan,
    packed_lits: jax.Array,  # uint32[2F, W] (interleaved literal rows)
    *,
    m_cap: int,
    i_cap: int,
    implementation: str = "pallas",
    interpret: bool = False,
) -> jax.Array:
    """Compressed inference via the popcount path -> int32[m_cap, B].

    ``implementation='pallas'`` runs the Pallas kernel (pass
    ``interpret=True`` off-TPU); ``'xla'`` runs the bit-exact pure-XLA
    formulation (the portable serving fast path).
    """
    lit_idx, last, mask_pos, mask_neg = plan_to_popcount_operands(
        plan, i_cap, m_cap, l2_cap=int(packed_lits.shape[0])
    )
    args = (
        jnp.asarray(lit_idx), jnp.asarray(last),
        jnp.asarray(mask_pos), jnp.asarray(mask_neg), packed_lits,
    )
    if implementation == "pallas":
        return tm_popcount(*args, interpret=interpret)
    if implementation == "xla":
        return tm_popcount_xla(*args)
    raise ValueError(
        f"unknown implementation {implementation!r}; choose 'pallas' or 'xla'"
    )
