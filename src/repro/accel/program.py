"""TMProgram: the versioned, wire-transportable deployment artifact.

ETHEREAL's insight, applied to our Fig-8 loop: the *compressed program*
— not the dense model — is the thing that ships.  A ``TMProgram`` bundles
the uint16 include-instruction stream with the capacity envelope it was
compiled against and a checksum, so a training node can ``to_bytes()`` it
onto the wire and a serving node can ``from_bytes()`` + ``load`` it into
a live accelerator with no shared process state:

    art  = accelerator.compile(model)        # stamp + stream + checksum
    blob = art.to_bytes()                    # -> network / flash / disk
    ...
    art2 = TMProgram.from_bytes(blob)        # integrity-checked
    accelerator.load("slot", art2)           # reprogram: data movement

Layout (all little-endian):

    header   4s  magic  b"TMPG"
             H   format version (1)
             H   reserved (0)
             I   payload length in bytes
             I   CRC-32 of the payload
    payload  6I  capacity stamp (instruction, feature, class, clause,
                 include capacities, batch_words)
             4I  model dims (n_classes, n_clauses, n_features,
                 n_instructions)
             H*  the instruction stream, n_instructions uint16 words

``from_bytes`` refuses truncated blobs, wrong magic, future format
versions and checksum mismatches with specific errors — a corrupted
artifact must never reach a live accelerator.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from ..core.compress import CompressedModel
from .capacity import CapacityPlan

MAGIC = b"TMPG"
FORMAT_VERSION = 1

_HEADER = struct.Struct("<4sHHII")
_CAPS = struct.Struct("<6I")
_DIMS = struct.Struct("<4I")


@dataclasses.dataclass(frozen=True, eq=False)
class TMProgram:
    """One deployable program: capacity stamp + instruction stream.

    The stamp records the envelope the artifact was compiled for — a
    serving node whose own plan differs can still load it as long as the
    model fits (``CapacityPlan.validate`` at load time decides)."""

    capacity: CapacityPlan
    model: CompressedModel
    format_version: int = FORMAT_VERSION

    # -- identity ------------------------------------------------------------

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, TMProgram)
            and self.format_version == other.format_version
            and self.capacity == other.capacity
            and self.model.n_classes == other.model.n_classes
            and self.model.n_clauses == other.model.n_clauses
            and self.model.n_features == other.model.n_features
            and np.array_equal(self.model.instructions,
                               other.model.instructions)
        )

    __hash__ = None  # mutable-array payload; identity-hashing would lie

    # -- wire format ---------------------------------------------------------

    def _payload(self) -> bytes:
        m = self.model
        return (
            _CAPS.pack(*(self.capacity.as_dict()[k]
                         for k in CapacityPlan.KNOBS))
            + _DIMS.pack(m.n_classes, m.n_clauses, m.n_features,
                         m.n_instructions)
            + np.ascontiguousarray(m.instructions, dtype="<u2").tobytes()
        )

    @property
    def checksum(self) -> int:
        """CRC-32 of the payload (what the header carries on the wire)."""
        return zlib.crc32(self._payload())

    @property
    def n_bytes(self) -> int:
        return _HEADER.size + _CAPS.size + _DIMS.size + 2 * self.model.n_instructions

    def to_bytes(self) -> bytes:
        payload = self._payload()
        header = _HEADER.pack(
            MAGIC, self.format_version, 0, len(payload), zlib.crc32(payload)
        )
        return header + payload

    @classmethod
    def from_bytes(cls, blob: bytes) -> "TMProgram":
        blob = bytes(blob)
        if len(blob) < _HEADER.size:
            raise ValueError(
                f"truncated TMProgram artifact: {len(blob)} bytes is "
                f"smaller than the {_HEADER.size}-byte header"
            )
        magic, version, _, payload_len, crc = _HEADER.unpack_from(blob)
        if magic != MAGIC:
            raise ValueError(
                f"not a TMProgram artifact (magic {magic!r}, "
                f"expected {MAGIC!r})"
            )
        if version > FORMAT_VERSION:
            raise ValueError(
                f"TMProgram format version {version} is newer than this "
                f"runtime understands (<= {FORMAT_VERSION}); upgrade the "
                f"serving node"
            )
        payload = blob[_HEADER.size:]
        if len(payload) != payload_len:
            raise ValueError(
                f"truncated TMProgram artifact: header promises "
                f"{payload_len} payload bytes, got {len(payload)}"
            )
        if zlib.crc32(payload) != crc:
            raise ValueError(
                "TMProgram checksum mismatch — the artifact was corrupted "
                "in transit; refusing to load it into a live accelerator"
            )
        caps = _CAPS.unpack_from(payload, 0)
        n_classes, n_clauses, n_features, n_instructions = _DIMS.unpack_from(
            payload, _CAPS.size
        )
        expect = _CAPS.size + _DIMS.size + 2 * n_instructions
        if payload_len != expect:
            # a CRC-consistent blob can still LIE about its own shape
            # (buggy producer): dims promising more words than present, or
            # trailing words the dims disown — both would ship a wrong
            # model, so both are hard errors
            raise ValueError(
                f"inconsistent TMProgram artifact: dims declare "
                f"{n_instructions} instructions ({expect} payload bytes) "
                f"but the payload carries {payload_len}"
            )
        stream = np.frombuffer(
            payload, dtype="<u2", count=n_instructions,
            offset=_CAPS.size + _DIMS.size,
        ).astype(np.uint16)
        return cls(
            capacity=CapacityPlan(**dict(zip(CapacityPlan.KNOBS, caps))),
            model=CompressedModel(
                instructions=stream,
                n_classes=n_classes,
                n_clauses=n_clauses,
                n_features=n_features,
            ),
            format_version=version,
        )
