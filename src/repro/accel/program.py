"""TMProgram: the versioned, wire-transportable deployment artifact.

ETHEREAL's insight, applied to our Fig-8 loop: the *compressed program*
— not the dense model — is the thing that ships.  A ``TMProgram`` bundles
the uint16 include-instruction stream with the capacity envelope it was
compiled against and a checksum, so a training node can ``to_bytes()`` it
onto the wire and a serving node can ``from_bytes()`` + ``load`` it into
a live accelerator with no shared process state:

    art  = accelerator.compile(model)        # stamp + stream + checksum
    blob = art.to_bytes()                    # -> network / flash / disk
    ...
    art2 = TMProgram.from_bytes(blob)        # integrity-checked
    accelerator.load("slot", art2)           # reprogram: data movement

Layout (all little-endian):

    header   4s  magic  b"TMPG"
             H   format version (1 or 2)
             H   reserved (0)
             I   payload length in bytes
             I   CRC-32 of the payload
    v1       6I  capacity stamp (instruction, feature, class, clause,
    payload      include capacities, batch_words)
             4I  model dims (n_classes, n_clauses, n_features,
                 n_instructions)
             H*  the instruction stream, n_instructions uint16 words
    v2       7I  capacity stamp (v1's six + weight_planes)
    payload  4I  model dims (as v1)
             I   n_weights (per-clause weight count; 0 = weightless)
             H*  the instruction stream, n_instructions uint16 words
             H*  the clause-weight vector, n_weights uint16 words

Version policy (repro.prune weighted clauses): a weightless model whose
envelope has no weight planes beyond the implicit one serializes as v1 —
BYTE-IDENTICAL to every pre-prune artifact (the golden-fixture guarantee).
Weighted models (or plans provisioning ``weight_planes > 1``) emit v2.
``from_bytes`` loads both; the CRC covers the weight vector, so corrupted
weight bytes are refused exactly like corrupted instructions.

``from_bytes`` refuses truncated blobs, wrong magic, future format
versions and checksum mismatches with specific errors — a corrupted
artifact must never reach a live accelerator.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Optional

import numpy as np

from ..core.compress import CompressedModel
from .capacity import CapacityPlan

MAGIC = b"TMPG"
FORMAT_VERSION = 2

# the v1 wire order is FROZEN: exactly the six knobs that existed when v1
# shipped, regardless of what CapacityPlan.KNOBS grows to
_V1_KNOBS = (
    "instruction_capacity", "feature_capacity", "class_capacity",
    "clause_capacity", "include_capacity", "batch_words",
)
_V2_KNOBS = _V1_KNOBS + ("weight_planes",)

_HEADER = struct.Struct("<4sHHII")
_CAPS = struct.Struct("<6I")
_CAPS_V2 = struct.Struct("<7I")
_DIMS = struct.Struct("<4I")
_NWEIGHTS = struct.Struct("<I")


@dataclasses.dataclass(frozen=True, eq=False)
class TMProgram:
    """One deployable program: capacity stamp + instruction stream.

    The stamp records the envelope the artifact was compiled for — a
    serving node whose own plan differs can still load it as long as the
    model fits (``CapacityPlan.validate`` at load time decides)."""

    capacity: CapacityPlan
    model: CompressedModel
    format_version: Optional[int] = None  # None -> minimal covering version

    def __post_init__(self):
        version = self.format_version
        if version is None:
            # emit the OLDEST format that covers the artifact: weightless
            # models in a plane-free envelope stay byte-identical v1
            version = 1 if (
                not self.model.weighted and self.capacity.weight_planes == 1
            ) else 2
            object.__setattr__(self, "format_version", version)
        if version == 1 and self.model.weighted:
            raise ValueError(
                "TMProgram format v1 cannot carry clause weights; "
                "serialize weighted models as v2"
            )

    # -- identity ------------------------------------------------------------

    def __eq__(self, other) -> bool:
        if not (
            isinstance(other, TMProgram)
            and self.format_version == other.format_version
            and self.capacity == other.capacity
            and self.model.n_classes == other.model.n_classes
            and self.model.n_clauses == other.model.n_clauses
            and self.model.n_features == other.model.n_features
            and np.array_equal(self.model.instructions,
                               other.model.instructions)
        ):
            return False
        a, b = self.model.clause_weights, other.model.clause_weights
        if (a is None) != (b is None):
            return False
        return a is None or bool(np.array_equal(a, b))

    __hash__ = None  # mutable-array payload; identity-hashing would lie

    # -- wire format ---------------------------------------------------------

    def _payload(self) -> bytes:
        m = self.model
        caps = self.capacity.as_dict()
        dims = _DIMS.pack(
            m.n_classes, m.n_clauses, m.n_features, m.n_instructions
        )
        stream = np.ascontiguousarray(m.instructions, dtype="<u2").tobytes()
        if self.format_version == 1:
            return (
                _CAPS.pack(*(caps[k] for k in _V1_KNOBS)) + dims + stream
            )
        weights = b"" if m.clause_weights is None else (
            np.ascontiguousarray(m.clause_weights, dtype="<u2").tobytes()
        )
        return (
            _CAPS_V2.pack(*(caps[k] for k in _V2_KNOBS))
            + dims
            + _NWEIGHTS.pack(m.n_weights)
            + stream
            + weights
        )

    @property
    def checksum(self) -> int:
        """CRC-32 of the payload (what the header carries on the wire)."""
        return zlib.crc32(self._payload())

    @property
    def n_bytes(self) -> int:
        if self.format_version == 1:
            return (_HEADER.size + _CAPS.size + _DIMS.size
                    + 2 * self.model.n_instructions)
        return (_HEADER.size + _CAPS_V2.size + _DIMS.size + _NWEIGHTS.size
                + 2 * (self.model.n_instructions + self.model.n_weights))

    def to_bytes(self) -> bytes:
        payload = self._payload()
        header = _HEADER.pack(
            MAGIC, self.format_version, 0, len(payload), zlib.crc32(payload)
        )
        return header + payload

    @classmethod
    def from_bytes(cls, blob: bytes) -> "TMProgram":
        blob = bytes(blob)
        if len(blob) < _HEADER.size:
            raise ValueError(
                f"truncated TMProgram artifact: {len(blob)} bytes is "
                f"smaller than the {_HEADER.size}-byte header"
            )
        magic, version, _, payload_len, crc = _HEADER.unpack_from(blob)
        if magic != MAGIC:
            raise ValueError(
                f"not a TMProgram artifact (magic {magic!r}, "
                f"expected {MAGIC!r})"
            )
        if version > FORMAT_VERSION:
            raise ValueError(
                f"TMProgram format version {version} is newer than this "
                f"runtime understands (<= {FORMAT_VERSION}); upgrade the "
                f"serving node"
            )
        payload = blob[_HEADER.size:]
        if len(payload) != payload_len:
            raise ValueError(
                f"truncated TMProgram artifact: header promises "
                f"{payload_len} payload bytes, got {len(payload)}"
            )
        if zlib.crc32(payload) != crc:
            raise ValueError(
                "TMProgram checksum mismatch — the artifact was corrupted "
                "in transit; refusing to load it into a live accelerator"
            )
        if version == 1:
            caps_s, knobs, n_weights_s = _CAPS, _V1_KNOBS, 0
        else:
            caps_s, knobs, n_weights_s = _CAPS_V2, _V2_KNOBS, _NWEIGHTS.size
        caps = caps_s.unpack_from(payload, 0)
        n_classes, n_clauses, n_features, n_instructions = _DIMS.unpack_from(
            payload, caps_s.size
        )
        n_weights = 0
        if version >= 2:
            (n_weights,) = _NWEIGHTS.unpack_from(
                payload, caps_s.size + _DIMS.size
            )
        expect = (caps_s.size + _DIMS.size + n_weights_s
                  + 2 * (n_instructions + n_weights))
        if payload_len != expect:
            # a CRC-consistent blob can still LIE about its own shape
            # (buggy producer): dims promising more words than present, or
            # trailing words the dims disown — both would ship a wrong
            # model, so both are hard errors
            raise ValueError(
                f"inconsistent TMProgram artifact: dims declare "
                f"{n_instructions} instructions + {n_weights} weights "
                f"({expect} payload bytes) but the payload carries "
                f"{payload_len}"
            )
        stream_off = caps_s.size + _DIMS.size + n_weights_s
        stream = np.frombuffer(
            payload, dtype="<u2", count=n_instructions, offset=stream_off,
        ).astype(np.uint16)
        weights = None
        if n_weights:
            weights = np.frombuffer(
                payload, dtype="<u2", count=n_weights,
                offset=stream_off + 2 * n_instructions,
            ).astype(np.uint16)
        return cls(
            capacity=CapacityPlan(**dict(zip(knobs, caps))),
            model=CompressedModel(
                instructions=stream,
                n_classes=n_classes,
                n_clauses=n_clauses,
                n_features=n_features,
                clause_weights=weights,
            ),
            format_version=version,
        )
