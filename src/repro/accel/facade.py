"""The Accelerator façade — one public entry point for deploying and
retuning runtime-tunable TMs (MATADOR's "single automated toolchain API"
applied to our serving stack).

    # negotiate the synthesis-time envelope from the model population
    acc = Accelerator.for_models([model_a, model_b], headroom=0.5)

    # train node: compile the portable artifact and ship it
    blob = acc.compile(model_a).to_bytes()

    # serving node: load = integrity check + pure data movement
    acc.load("tenant", blob)
    preds = acc.infer("tenant", x)

    # the Fig-8 loop: retune in the field, never resynthesize
    acc.load("tenant", acc.compile(model_b), provenance="recal:drift")
    assert acc.compile_cache_size() == 1

The façade auto-selects the fastest eligible engine plugin via the
capability flags (popcount off-mesh, the sharded shard_map when a mesh is
provisioned); pass ``engine=`` to pin one, ``engine_options=`` for
per-engine knobs (e.g. ``{"implementation": "pallas"}``).

Everything underneath is the existing serving machinery: an engine plugin
(``accel.engines``), the versioned slot registry, the priority-lane
batcher, the continuous-batching scheduler and metrics (``serve_tm``).
The async front door is exposed too: ``start()``/``stop()`` run the
scheduler loop and ``async_submit(slot, x, priority=, timeout_ms=)``
serves admission-controlled deadline-aware traffic without anyone calling
``flush()``.  The façade IS a valid ``RecalController`` server —
``repro.recal`` runs against it unchanged, with a live loop or without.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from ..core.compress import CompressedModel
from .capacity import CapacityPlan
from .program import TMProgram


class Accelerator:
    """A deployed accelerator: negotiated capacity + one engine plugin +
    the multi-tenant serving surface (slots, batching, hot-swap,
    rollback)."""

    def __init__(
        self,
        plan: Optional[CapacityPlan] = None,
        *,
        engine: Optional[str] = None,
        mesh=None,
        engine_options: Optional[dict] = None,
        history_depth: int = 4,
    ):
        # deferred: serve_tm.server imports accel.engine — importing it at
        # module scope would cycle through the package inits
        from ..serve_tm.server import TMServer

        self.plan = plan if plan is not None else CapacityPlan()
        # engine selection/construction is the serving node's job (the
        # ServingNode boundary): TMServer runs the same deterministic
        # select_engine/make_engine path the façade used to duplicate
        self.server = TMServer(
            self.plan, engine=engine, mesh=mesh,
            engine_options=engine_options, history_depth=history_depth,
        )
        self.engine = self.server.executor

    @classmethod
    def for_models(
        cls,
        models: Iterable[CompressedModel],
        *,
        headroom: float = 0.0,
        batch_words: int = 4,
        engine: Optional[str] = None,
        mesh=None,
        engine_options: Optional[dict] = None,
        history_depth: int = 4,
    ) -> "Accelerator":
        """Capacity-negotiated construction: derive the minimal quantized
        envelope for ``models`` (see ``CapacityPlan.for_models``) and
        deploy an engine at that shape."""
        plan = CapacityPlan.for_models(
            models, headroom=headroom, batch_words=batch_words
        )
        return cls(
            plan, engine=engine, mesh=mesh, engine_options=engine_options,
            history_depth=history_depth,
        )

    # -- the deployment artifact path ---------------------------------------

    def compile(self, model: CompressedModel) -> TMProgram:
        """Model -> portable ``TMProgram`` artifact, stamped with this
        accelerator's capacity envelope.  Raises ``CapacityExceeded`` when
        the model doesn't fit the deployed engine's buffers — the EXACT
        check ``load`` will repeat, so compile-time is where a misfit
        surfaces, not the serving node's load path.  (Load revalidates by
        design: artifacts routinely cross process/node boundaries, so the
        one extra host-side stream decode per publication is the price of
        never trusting the wire.)"""
        self.engine.validate_model(model)
        return TMProgram(capacity=self.plan, model=model)

    def load(
        self,
        slot: str,
        artifact: "TMProgram | bytes | CompressedModel",
        provenance: str = "load",
    ):
        """Install an artifact (or raw ``to_bytes()`` blob, or a bare
        model) into ``slot`` — integrity-checked, capacity-validated, then
        pure data movement with the usual drain-then-swap discipline."""
        return self.server.register(slot, artifact, provenance=provenance)

    # -- serving delegation (the façade IS a TMServer-shaped object) ---------

    def register(self, slot, model, provenance: str = "install"):
        return self.server.register(slot, model, provenance=provenance)

    def rollback(self, slot: str):
        return self.server.rollback(slot)

    def submit(self, slot: str, x: np.ndarray, **kw):
        return self.server.submit(slot, x, **kw)

    async def async_submit(self, slot: str, x: np.ndarray, **kw):
        """Admission-controlled submit for async callers (priority lanes,
        deadlines); requires the scheduler loop (``start()``)."""
        return await self.server.async_submit(slot, x, **kw)

    def start(self) -> None:
        """Start the continuous-batching scheduler loop."""
        self.server.start()

    def stop(self, drain: bool = True) -> None:
        self.server.stop(drain=drain)

    @property
    def scheduler_running(self) -> bool:
        return self.server.scheduler_running

    def flush(self) -> None:
        self.server.flush()

    def infer(self, slot: str, x: np.ndarray) -> np.ndarray:
        return self.server.infer(slot, x)

    def class_sums(self, slot: str, x: np.ndarray) -> np.ndarray:
        return self.server.class_sums(slot, x)

    def compile_cache_size(self) -> int:
        return self.server.compile_cache_size()

    # -- the ServingNode boundary (fleet/recal operate on this surface) ------

    def validate_model(self, model) -> None:
        """The exact will-it-fit check this node's engine applies on
        install (raises ``CapacityExceeded``)."""
        self.server.validate_model(model)

    def queue_depth(self, slot=None, priority=None) -> int:
        return self.server.queue_depth(slot, priority)

    def metrics_snapshot(self) -> dict:
        return self.server.metrics_snapshot()

    def installed_checksum(self, slot: str):
        return self.server.installed_checksum(slot)

    def installed_artifact(self, slot: str):
        return self.server.installed_artifact(slot)

    @property
    def capacity(self) -> CapacityPlan:
        return self.plan

    @property
    def registry(self):
        return self.server.registry

    @property
    def metrics(self):
        return self.server.metrics

    def slots(self) -> Sequence[str]:
        return self.server.registry.names()

    def __repr__(self) -> str:
        return (
            f"Accelerator(engine={self.engine.name!r}, "
            f"plan={self.plan.as_dict()})"
        )
