"""Capacity negotiation: the "synthesis-time" envelope as a first-class API.

The paper's Fig-6 argument is that memory depths — instruction memory,
feature memory, the class-sum bank, clause tables — are fixed when the
accelerator is synthesized, and everything *inside* them is runtime
state.  ``CapacityPlan`` is that envelope.  Instead of hand-picking
numbers, ``CapacityPlan.for_models`` derives the minimal word-quantized
plan that fits a model population (plus optional headroom for the models
recalibration will grow), and ``fits`` / ``violations`` / ``widen_to``
answer the deployment questions directly.

Exceeding the envelope is no longer a free-text ``ValueError``:
``CapacityExceeded`` carries the offending knob, the required depth and
the provisioned depth, so callers (and the recal publication gate) can
react programmatically — e.g. re-negotiate with ``widen_to``.

Quantization: depths are rounded up to the hardware word grain —
instruction memory to 32 (the popcount selection bitplanes pack 32
instructions per ``uint32`` chunk), feature memory to 16 (the uint16
stream protocol ships features 16 per word and the 2F interleaved
literal rows pack into whole ``uint32`` words), batch in 32-datapoint
bit-packed words by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..core.compress import CompressedModel, decode_to_plan

# knob -> rounding grain (the word-quantization rules above)
QUANTA: Dict[str, int] = {
    "instruction_capacity": 32,
    "feature_capacity": 16,
    "class_capacity": 1,
    "clause_capacity": 1,
    "include_capacity": 1,
    "batch_words": 1,
    "weight_planes": 1,
}

# the knobs recalibration can grow (include streams get denser, clauses
# fill in); class count and input dimensionality are pinned by the task,
# so headroom never inflates them — they only pick up quantization slack
HEADROOM_KNOBS = frozenset(
    {"instruction_capacity", "clause_capacity", "include_capacity"}
)


class CapacityExceeded(ValueError):
    """A model needs more of one synthesis-time buffer than the plan
    provides.  ``knob`` names the ``CapacityPlan`` field, ``required`` the
    depth the model needs, ``capacity`` the depth provisioned — enough for
    a caller to re-negotiate (``plan.widen_to(model)``) instead of parsing
    an error string.  Subclasses ``ValueError`` so legacy guards keep
    working."""

    def __init__(self, knob: str, required: int, capacity: int, what: str = ""):
        self.knob = knob
        self.required = int(required)
        self.capacity = int(capacity)
        self.what = what or knob
        super().__init__(
            f"model {self.what} needs {knob} >= {self.required} but the "
            f"negotiated plan provides {self.capacity}; re-negotiate the "
            f"envelope (CapacityPlan.widen_to / for_models) — the eFPGA "
            f"analogue is resynthesizing with a deeper {self.what}"
        )


def _quantize(knob: str, value: int) -> int:
    q = QUANTA[knob]
    return max(q, ((int(value) + q - 1) // q) * q)


def model_requirements(
    model: CompressedModel,
    knobs: Optional[Iterable[str]] = None,
    decoded=None,
) -> Dict[str, int]:
    """Per-knob minimal depths for one compressed model.

    Instruction memory must hold the full stream (covers the include
    count, which can only be smaller); the clause-table extents come from
    the decoded plan — the clause tables must hold the densest class, the
    include slots the widest clause.  Decoding only happens when a
    clause-table knob is actually requested (``knobs``); pass an
    already-``decoded`` plan to avoid a second stream walk.
    """
    wanted = set(CapacityPlan.KNOBS if knobs is None else knobs)
    req: Dict[str, int] = {}
    if "instruction_capacity" in wanted:
        req["instruction_capacity"] = model.n_instructions
    if "feature_capacity" in wanted:
        req["feature_capacity"] = model.n_features
    if "class_capacity" in wanted:
        req["class_capacity"] = model.n_classes
    if wanted & {"clause_capacity", "include_capacity"}:
        if decoded is None:
            decoded = decode_to_plan(model)
        if "clause_capacity" in wanted:
            cpc = decoded.clauses_per_class(model.n_classes)
            req["clause_capacity"] = int(cpc.max()) if cpc.size else 0
        if "include_capacity" in wanted:
            ipc = decoded.includes_per_clause()
            req["include_capacity"] = int(ipc.max()) if ipc.size else 0
    if "weight_planes" in wanted:
        # bitplanes of the largest clause weight (repro.prune); 1 for
        # weightless models, so legacy populations negotiate exactly the
        # envelope they always did
        req["weight_planes"] = model.weight_planes
    return req


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """The serving deployment's synthesis-time capacity envelope (Fig 6
    memory-depth customization, extended with the clause-table dims the
    plan/sharded layouts need).  Everything inside these bounds is runtime
    state; exceeding them raises ``CapacityExceeded``."""

    instruction_capacity: int = 4096   # instruction memory / include-list depth
    feature_capacity: int = 256        # Boolean features per datapoint
    class_capacity: int = 16           # class-sum accumulator bank depth
    clause_capacity: int = 64          # clauses per class (clause tables)
    include_capacity: int = 32         # includes per clause (clause-major)
    batch_words: int = 4               # 32 datapoints per bit-packed word
    weight_planes: int = 1             # clause-weight bitplanes (repro.prune)

    KNOBS = (
        "instruction_capacity", "feature_capacity", "class_capacity",
        "clause_capacity", "include_capacity", "batch_words",
        "weight_planes",
    )

    def __post_init__(self):
        for knob in self.KNOBS:
            v = getattr(self, knob)
            if not isinstance(v, (int, np.integer)) or v < 1:
                raise ValueError(
                    f"CapacityPlan.{knob} must be a positive integer, "
                    f"got {v!r}"
                )

    @property
    def batch_capacity(self) -> int:
        return self.batch_words * 32

    @property
    def clause_total_capacity(self) -> int:
        return self.class_capacity * self.clause_capacity

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.KNOBS}

    # -- negotiation ---------------------------------------------------------

    @classmethod
    def for_models(
        cls,
        models: Iterable[CompressedModel],
        *,
        headroom: float = 0.0,
        batch_words: int = 4,
    ) -> "CapacityPlan":
        """The minimal word-quantized plan fitting every model in
        ``models``.  ``headroom`` is fractional slack applied BEFORE
        quantization to the knobs recalibration can grow
        (``HEADROOM_KNOBS``: instruction/clause/include depths; 0.5 =
        provision 50% above today's population).  Task-pinned dims
        (classes, features) take only quantization slack — inflating a
        fixed compiled shape the task can never use would cost every
        engine call.  ``batch_words`` is traffic-, not model-shaped, so
        it is passed through (in whole 32-datapoint words)."""
        models = list(models)
        if not models:
            raise ValueError(
                "CapacityPlan.for_models needs at least one model to "
                "negotiate an envelope from"
            )
        if headroom < 0:
            raise ValueError(f"headroom must be >= 0, got {headroom}")
        need: Dict[str, int] = {}
        for model in models:
            for knob, req in model_requirements(model).items():
                need[knob] = max(need.get(knob, 0), req)
        knobs = {
            knob: _quantize(
                knob,
                int(np.ceil(req * (1.0 + headroom)))
                if knob in HEADROOM_KNOBS else req,
            )
            for knob, req in need.items()
        }
        return cls(batch_words=int(batch_words), **knobs)

    def violations(
        self,
        model: CompressedModel,
        knobs: Optional[Iterable[str]] = None,
        decoded=None,
    ) -> List[Tuple[str, int, int]]:
        """``(knob, required, provided)`` for every knob ``model`` blows
        through (empty = fits), in ``KNOBS`` order.  ``knobs`` restricts
        the check to a subset — engines validate only the buffers their
        layout actually has (``Engine.validated_knobs``); the default is
        the full envelope (what ``for_models`` negotiates, sufficient for
        every engine).  ``decoded`` forwards an already-decoded plan so
        callers that decode anyway don't pay a second stream walk."""
        req = model_requirements(model, knobs, decoded)
        return [
            (knob, req[knob], getattr(self, knob))
            for knob in self.KNOBS
            if knob in req and req[knob] > getattr(self, knob)
        ]

    def fits(
        self,
        model: CompressedModel,
        knobs: Optional[Iterable[str]] = None,
    ) -> bool:
        return not self.violations(model, knobs)

    def validate(
        self,
        model: CompressedModel,
        knobs: Optional[Iterable[str]] = None,
        decoded=None,
    ) -> None:
        """Raise ``CapacityExceeded`` for the first violated knob (in
        ``KNOBS`` order, so the report is deterministic)."""
        bad = self.violations(model, knobs, decoded)
        if bad:
            knob, req, cap = bad[0]
            raise CapacityExceeded(knob, req, cap)

    def widen_to(self, model: CompressedModel) -> "CapacityPlan":
        """The smallest quantized plan >= self that also fits ``model``
        (the re-negotiation diagnostic a ``CapacityExceeded`` points at)."""
        knobs = self.as_dict()
        for knob, req in model_requirements(model).items():
            knobs[knob] = max(knobs[knob], _quantize(knob, req))
        return CapacityPlan(**knobs)

    def shrink_to(self, model: CompressedModel, decoded=None) -> "CapacityPlan":
        """``widen_to``'s mirror for the prune pass: the smallest quantized
        plan <= self that still fits ``model`` — what a pruned artifact's
        envelope re-negotiates DOWN to (the eFPGA analogue: resynthesize
        with shallower memories and reclaim the BRAM).  ``batch_words`` is
        traffic-shaped and passes through unchanged; no knob ever grows
        (shrink_to of a model that doesn't fit keeps the current depth —
        use ``widen_to`` for that direction)."""
        knobs = self.as_dict()
        for knob, req in model_requirements(model, decoded=decoded).items():
            knobs[knob] = min(knobs[knob], _quantize(knob, req))
        return CapacityPlan(**knobs)

    def shrink_diagnostics(
        self, model: CompressedModel, decoded=None
    ) -> List[Tuple[str, int, int]]:
        """``(knob, provisioned, reclaimable_depth)`` for every knob a
        pruned ``model`` lets the deployment shrink (quantized; empty =
        the envelope is already minimal for this model).  The read-only
        companion of ``shrink_to`` — what the recal controller logs when
        a prune pass makes the published program smaller than the
        envelope it ships into."""
        shrunk = self.shrink_to(model, decoded)
        return [
            (knob, getattr(self, knob), getattr(shrunk, knob))
            for knob in self.KNOBS
            if getattr(shrunk, knob) < getattr(self, knob)
        ]
