"""The formal Engine plugin protocol + registry.

An *engine* is one realization of the runtime-tunable accelerator: a
fixed-capacity compiled artifact that models are programmed INTO (pure
data movement) rather than compiled FOR.  Every engine honours one
contract:

  ``program(model)``        host-side reprogram: decode the compressed
                            model into the engine's fixed-capacity
                            buffers.  Capacity validation is uniform —
                            the base class runs ``plan.validate(model)``
                            (raising ``CapacityExceeded``) before the
                            engine-specific ``_program``.
  ``class_sums(prog, x)``   {0,1}[B, F] -> int32[B, n_classes]
  ``compile_cache_size()``  # compiled variants of THIS engine's jitted
                            program — the zero-resynthesis property; must
                            stay 1 across model swaps.
  ``staging``               the engine's preallocated
                            [batch_capacity, feature_capacity] uint8
                            feature staging array; the batcher packs
                            request rows straight into it
                            (``Batcher.next_batch(out=...)``).

Engines self-describe through capability flags set by the
``@register_engine`` decorator:

  ``supports_donation``     the engine donates its per-call device
                            feature buffer to XLA (the facade scopes the
                            off-TPU "donation declined" warning to these
                            call sites only);
  ``needs_mesh``            the engine consumes a device mesh (today:
                            the sharded clause-major shard_map);
  ``priority``              relative speed rank used by ``select_engine``
                            to auto-pick the fastest eligible engine;
  ``validated_knobs``       which ``CapacityPlan`` buffers the engine's
                            layout actually instantiates — ``program``
                            validates exactly those (e.g. the clause
                            tables bound only the sharded engine).

Construction is uniform: ``make_engine(name, plan, **options)`` — mesh
and implementation knobs are per-engine options, not special-cased
branches.
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Any, Dict, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from ..core.compress import decode_to_plan
from .capacity import CapacityExceeded, CapacityPlan

# name -> engine class; populated by @register_engine (engines.py registers
# the four built-ins on import)
ENGINES: Dict[str, type] = {}


@runtime_checkable
class Engine(Protocol):
    """Structural type of an accelerator engine (see module docstring)."""

    name: str
    supports_donation: bool
    needs_mesh: bool
    priority: int
    validated_knobs: tuple
    plan: CapacityPlan

    def program(self, model) -> Dict[str, Any]: ...

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray: ...

    def compile_cache_size(self) -> int: ...


def register_engine(
    name: str,
    *,
    supports_donation: bool = False,
    needs_mesh: bool = False,
    priority: int = 0,
):
    """Class decorator registering an engine plugin under ``name`` and
    stamping its capability flags.  Re-registering a taken name raises —
    plugin identity must be unambiguous for auto-selection to be
    deterministic."""

    def deco(cls):
        if name in ENGINES and ENGINES[name] is not cls:
            raise ValueError(
                f"engine name {name!r} already registered to "
                f"{ENGINES[name].__name__}"
            )
        cls.name = name
        cls.supports_donation = bool(supports_donation)
        cls.needs_mesh = bool(needs_mesh)
        cls.priority = int(priority)
        ENGINES[name] = cls
        return cls

    return deco


def engine_names() -> list:
    return sorted(ENGINES)


def select_engine(
    plan: Optional[CapacityPlan] = None, *, mesh=None
) -> str:
    """Deterministically pick the fastest eligible engine name.

    With a mesh, mesh-consuming engines (``needs_mesh``) are the eligible
    set — the caller provisioned devices for exactly them.  Without one,
    the fastest mesh-free engine wins.  Ties break lexicographically so
    selection is stable across processes.  ``plan`` is part of the
    contract (today every engine serves every plan point; a plugin whose
    eligibility depends on the capacity point will consume it here)."""
    if mesh is not None:
        eligible = [c for c in ENGINES.values() if c.needs_mesh]
    else:
        eligible = [c for c in ENGINES.values() if not c.needs_mesh]
    if not eligible:
        raise ValueError(
            f"no eligible engine (mesh={'yes' if mesh is not None else 'no'}; "
            f"registered: {engine_names() or 'none'})"
        )
    return max(eligible, key=lambda c: (c.priority, c.name)).name


def make_engine(
    engine: "str | EngineBase", plan: CapacityPlan, *, mesh=None, **options
) -> "EngineBase":
    """Uniform plugin construction: name (or a built instance) -> engine.

    ``options`` go to the engine verbatim; the mesh is forwarded only to
    engines that declare ``needs_mesh`` (capability-flag-driven, not a
    per-name special case)."""
    if isinstance(engine, EngineBase):
        return engine
    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; registered: {engine_names()}"
        )
    cls = ENGINES[engine]
    if cls.needs_mesh and mesh is not None:
        options = {**options, "mesh": mesh}
    return cls(plan, **options)


def _private_jit(fn, **jit_kwargs):
    """jit over a FRESH closure: JAX keys its compilation cache on the
    callable, so wrapping gives this engine instance its own cache."""

    def inner(*args, **kwargs):
        return fn(*args, **kwargs)

    return jax.jit(inner, **jit_kwargs)


@contextlib.contextmanager
def _donation_declined_ok():
    """Buffer donation is an optimization hint; off-TPU XLA may decline it
    and warn — expected on CPU test/CI containers, not actionable.  Scoped
    to the donating engine's dispatch instead of mutating process-global
    warning state at import (the old module-level ``filterwarnings``)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


class EngineBase:
    """Shared engine mechanics: capacity validation, the staging array,
    private-jit dispatch (with donation-warning scoping for donating
    engines)."""

    name = "?"
    supports_donation = False
    needs_mesh = False
    priority = 0
    # which plan buffers this engine's layout instantiates (subclasses
    # narrow this; the clause tables, e.g., only exist in the sharded
    # layout).  CapacityPlan.for_models always provisions the full set.
    validated_knobs: tuple = CapacityPlan.KNOBS
    # what instruction_capacity must hold for THIS layout: "stream" = the
    # full uint16 stream (the interp engine's instruction memory);
    # "includes" = only the include slots (the plan/popcount operand
    # vectors — boundary EXTENDs never materialize there, so an
    # EXTEND-heavy stream still fits)
    instruction_metric = "stream"
    # engines whose reprogram consumes the DecodedPlan set this; the base
    # decodes the stream exactly once and shares it between validation
    # and _program (a swap must not pay repeated host-side stream walks)
    needs_decoded_plan = False

    def __init__(self, plan: CapacityPlan):
        self.plan = plan
        self._staging: Optional[np.ndarray] = None

    # legacy spelling (ServeCapacity era); same object
    @property
    def capacity(self) -> CapacityPlan:
        return self.plan

    def model_violations(self, model, decoded=None) -> list:
        """``(knob, required, provided)`` for every buffer of THIS layout
        the model blows through, honouring the engine's
        ``instruction_metric`` (a plan/popcount deployment only needs the
        include slots, not the full stream depth)."""
        knobs = list(self.validated_knobs)
        metric_is_includes = (
            "instruction_capacity" in knobs
            and self.instruction_metric == "includes"
        )
        if metric_is_includes:
            knobs.remove("instruction_capacity")
        if decoded is None and (
            metric_is_includes
            or set(knobs) & {"clause_capacity", "include_capacity"}
        ):
            # both the clause-extent requirements and the include metric
            # read the decoded plan: walk the stream once, share it
            decoded = decode_to_plan(model)
        bad = self.plan.violations(model, knobs, decoded)
        if metric_is_includes and (
            decoded.n_includes > self.plan.instruction_capacity
        ):
            bad.insert(0, (
                "instruction_capacity", decoded.n_includes,
                self.plan.instruction_capacity,
            ))
        return bad

    def validate_model(self, model, decoded=None) -> None:
        """Raise ``CapacityExceeded`` when ``model`` doesn't fit this
        engine's buffers (what ``Accelerator.compile`` gates on — the
        exact check the load path will repeat)."""
        bad = self.model_violations(model, decoded)
        if bad:
            raise CapacityExceeded(*bad[0])

    def program(self, model) -> Dict[str, Any]:
        """Validate ``model`` against the buffers this engine actually
        has, then run the engine-specific reprogram (pure data
        movement).  The instruction stream is decoded at most ONCE per
        install, shared between validation and the reprogram."""
        decoded = decode_to_plan(model) if self.needs_decoded_plan else None
        self.validate_model(model, decoded)
        return self._program(model, decoded)

    def _program(self, model, decoded) -> Dict[str, Any]:
        raise NotImplementedError

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def compile_cache_size(self) -> int:
        return self._fn._cache_size()

    def _dispatch(self, *args):
        """Run the engine's private jit; donating engines scope the
        off-TPU donation-declined warning to exactly this call site."""
        if self.supports_donation:
            with _donation_declined_ok():
                return self._fn(*args)
        return self._fn(*args)

    @property
    def staging(self) -> np.ndarray:
        """The engine's preallocated [batch_capacity, feature_capacity]
        uint8 feature staging array.  The batcher packs request rows
        straight into it (``Batcher.next_batch(out=...)``) and the engines
        consume it as their one fixed operand shape — no per-flush host
        allocation."""
        if self._staging is None:
            p = self.plan
            self._staging = np.zeros(
                (p.batch_capacity, p.feature_capacity), np.uint8
            )
        return self._staging

    def _pad_x(self, x: np.ndarray) -> np.ndarray:
        """{0,1}[B, F] -> the staging array (zero-padded to capacity).

        When ``x`` is already a view of ``self.staging`` (the batcher
        packed it there), it is returned as-is — zero copies."""
        p = self.plan
        B, F = x.shape
        if B > p.batch_capacity:
            raise CapacityExceeded(
                "batch_words", -(-B // 32), p.batch_words, "batch"
            )
        if F > p.feature_capacity:
            raise CapacityExceeded(
                "feature_capacity", F, p.feature_capacity, "n_features"
            )
        st = self.staging
        if np.shares_memory(x, st):
            if (x.__array_interface__["data"][0]
                    == st.__array_interface__["data"][0]):
                # a leading view — the batcher packed rows [0, B) in place
                # and zeroed the remainder (next_batch(out=) contract)
                return st
            # any other overlapping view would be corrupted by the zero
            # fill below; detach it first
            x = np.array(x)
        st.fill(0)
        st[:B, :F] = x
        return st
