"""repro.accel — the capability-negotiated Accelerator façade.

The one public surface for deploying and retuning runtime-tunable TMs:

  capacity.py   CapacityPlan (the word-quantized synthesis-time envelope,
                auto-derived from a model population) + CapacityExceeded
  engine.py     the formal Engine plugin protocol: @register_engine,
                capability flags (supports_donation / needs_mesh /
                priority), uniform make_engine, deterministic
                select_engine
  engines.py    the four built-in plugins: interp / plan / sharded /
                popcount
  program.py    TMProgram — the versioned, checksummed, wire-portable
                deployment artifact (to_bytes / from_bytes)
  facade.py     Accelerator — negotiate, compile, ship, load, serve,
                recalibrate; never resynthesize

``repro.serve_tm`` remains the serving machinery underneath (server,
batcher, registry, metrics); its old executor-level names are thin
deprecation shims onto this package.
"""

from .capacity import (
    HEADROOM_KNOBS,
    QUANTA,
    CapacityExceeded,
    CapacityPlan,
    model_requirements,
)
from .engine import (
    ENGINES,
    Engine,
    EngineBase,
    engine_names,
    make_engine,
    register_engine,
    select_engine,
)
from .engines import InterpEngine, PlanEngine, PopcountEngine, ShardedEngine
from .program import FORMAT_VERSION, TMProgram
from .facade import Accelerator

# the structured serving exceptions and the ServingNode boundary are
# stable public API on BOTH packages: deployment code that talks to an
# Accelerator should not need a second import tree to catch its errors.
# (Submodule imports only — safe against either package initializing
# first; serve_tm's own init imports accel submodules the same way.)
from ..serve_tm.batching import DeadlineExceeded
from ..serve_tm.node import NodeDown, ServingNode
from ..serve_tm.scheduler import EngineFault, Overloaded

__all__ = [
    "Accelerator",
    "CapacityExceeded",
    "CapacityPlan",
    "DeadlineExceeded",
    "ENGINES",
    "Engine",
    "EngineBase",
    "EngineFault",
    "FORMAT_VERSION",
    "HEADROOM_KNOBS",
    "InterpEngine",
    "NodeDown",
    "Overloaded",
    "PlanEngine",
    "PopcountEngine",
    "QUANTA",
    "ServingNode",
    "ShardedEngine",
    "TMProgram",
    "engine_names",
    "make_engine",
    "model_requirements",
    "register_engine",
    "select_engine",
]
