"""The four built-in engine plugins: interp / plan / sharded / popcount.

One ``CompressedModel`` contract, four realizations (all bit-exact
against the ``core.tm.batch_class_sums`` oracle — enforced by
tests/test_serve_tm.py and tests/test_accel.py):

  * ``interp``   — the paper-faithful stream interpreter
    (``core.interp.interpret_stream``): one instruction per scan step over
    the fixed-depth instruction memory.
  * ``plan``     — the decoded-plan fast path
    (``core.interp.plan_class_sums``): gather + segmented reduction,
    parallel across includes and datapoints.
  * ``sharded``  — the ``dist.tm_sharded`` clause-major shard_map executor
    (classes over ``model``, batch over the data axes); on a 1x1 mesh this
    is the single-device realization of the Fig-7 multi-core split.
    Takes the mesh as a per-engine option (``needs_mesh`` capability).
  * ``popcount`` — the popcount bitplane fast path
    (``kernels.tm_popcount``): clause outputs stay packed ``uint32`` until
    a clause boundary; class sums come from ``lax.population_count``
    against per-class polarity-bank selection bitplanes.  Pallas kernel on
    TPU, the bit-exact pure-XLA twin elsewhere (``implementation``
    option); donates its per-call staging copy (``supports_donation``).

Every engine instance owns a PRIVATE jit cache (a fresh closure over the
underlying function), so ``compile_cache_size()`` counts only this
engine's compilations.  Serving buffers are device-resident: ``program()``
moves the decoded program to the accelerator ONCE (``jax.device_put``);
per-flush features are packed by the batcher straight into the
preallocated host staging array (``EngineBase.staging``).

Capacity validation is uniform (``EngineBase.program`` runs
``plan.validate`` first), so the per-engine ``_program`` bodies are pure
decode + data movement.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import _pad_to
from ..core.compress import CompressedModel, decode_to_plan
from ..core.interp import interpret_stream, pack_features, pad_plan, plan_class_sums
from ..core.tm import literals, pack_literals
from ..dist.sharding import _axis_sizes
from ..dist.tm_sharded import (
    TMShardedConfig,
    build_tm_sharded,
    fill_clause_tables,
)
from ..kernels.tm_popcount.kernel import tm_popcount, tm_popcount_xla
from ..kernels.tm_popcount.ops import plan_to_popcount_operands
from ..kernels.tuning import choose_blocks
from .capacity import CapacityExceeded, CapacityPlan
from .engine import EngineBase, _private_jit, register_engine


@register_engine("interp", priority=10)
class InterpEngine(EngineBase):
    """Paper-faithful fixed-capacity stream interpreter (Fig 4.4-4.6)."""

    validated_knobs = (
        "instruction_capacity", "feature_capacity", "class_capacity",
    )

    def __init__(self, plan: CapacityPlan):
        super().__init__(plan)
        self._fn = _private_jit(
            interpret_stream.__wrapped__, static_argnames=("m_cap",)
        )

    def _program(self, model: CompressedModel, decoded=None) -> Dict[str, Any]:
        p = self.plan
        imem = np.zeros(p.instruction_capacity, np.uint16)
        imem[: model.n_instructions] = model.instructions
        # per-clause weight memory, indexed by the interpreter's finalize
        # ordinal (non-empty clauses in emission order).  Always present at
        # instruction-capacity depth (a clause needs >= 1 instruction, so
        # it can never be too small) and all-ones for weightless models:
        # one operand signature -> one compiled program across weighted and
        # weightless swaps.
        wmem = np.ones(p.instruction_capacity, np.int32)
        if model.clause_weights is not None:
            wmem[: model.n_weights] = model.clause_weights
        return {
            "imem": jnp.asarray(imem),
            "wmem": jnp.asarray(wmem),
            "n_inst": jnp.int32(model.n_instructions),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        p = self.plan
        B = x.shape[0]
        packed = pack_features(
            jnp.asarray(self._pad_x(x)), p.feature_capacity, p.batch_words
        )
        sums = self._fn(
            prog["imem"], prog["n_inst"], packed, jnp.int32(B), prog["wmem"],
            m_cap=p.class_capacity,
        )
        return np.asarray(sums)[: prog["n_classes"], :B].T


@register_engine("plan", priority=20)
class PlanEngine(EngineBase):
    """Decoded-plan engine: gather + segmented min/sum (beyond-paper)."""

    # clause_capacity bounds the segment table: per-class max clauses <=
    # clause_capacity (with n_classes <= class_capacity) implies
    # n_clauses_total <= clause_total_capacity, so a model that passes
    # compile-time validation can never blow the load-path table fill.
    # instruction_capacity bounds the include operand vectors only —
    # boundary EXTENDs never materialize in the decoded plan
    validated_knobs = (
        "instruction_capacity", "feature_capacity", "class_capacity",
        "clause_capacity",
    )
    instruction_metric = "includes"
    needs_decoded_plan = True

    def __init__(self, plan: CapacityPlan):
        super().__init__(plan)
        self._fn = _private_jit(
            plan_class_sums.__wrapped__,
            static_argnames=("n_clause_cap", "m_cap"),
        )

    def _program(self, model: CompressedModel, decoded=None) -> Dict[str, Any]:
        p = self.plan
        plan = decoded if decoded is not None else decode_to_plan(model)
        if plan.n_clauses_total > p.clause_total_capacity:
            # unreachable after validation; kept as a corruption guard on
            # the class_cap*clause_cap-deep segment table
            raise CapacityExceeded(
                "clause_capacity",
                -(-plan.n_clauses_total // p.class_capacity),
                p.clause_capacity,
                "total clauses",
            )
        li, ci, cc, cp = pad_plan(
            plan, p.instruction_capacity, p.clause_total_capacity
        )
        return {
            "li": jnp.asarray(li), "ci": jnp.asarray(ci),
            "cc": jnp.asarray(cc), "cp": jnp.asarray(cp),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        p = self.plan
        B = x.shape[0]
        lits = literals(jnp.asarray(self._pad_x(x)))  # [B_cap, 2*F_cap]
        sums = self._fn(
            prog["li"], prog["ci"], prog["cc"], prog["cp"], lits,
            n_clause_cap=p.clause_total_capacity, m_cap=p.class_capacity,
        )
        return np.asarray(sums)[:B, : prog["n_classes"]]


def _popcount_engine_xla(lit_idx, last, mask_pos, mask_neg, x_staged):
    """Staged features -> packed interleaved literals -> popcount sums."""
    return tm_popcount_xla.__wrapped__(
        lit_idx, last, mask_pos, mask_neg, pack_literals(x_staged)
    )


def _popcount_engine_pallas(
    lit_idx, last, mask_pos, mask_neg, x_staged,
    *, block_instructions, block_words, interpret,
):
    return tm_popcount.__wrapped__(
        lit_idx, last, mask_pos, mask_neg, pack_literals(x_staged),
        block_instructions=block_instructions, block_words=block_words,
        interpret=interpret,
    )


@register_engine("popcount", supports_donation=True, priority=30)
class PopcountEngine(EngineBase):
    """Popcount bitplane engine (kernels/tm_popcount): packed clause
    words end-to-end, class sums via ``lax.population_count`` against the
    program's polarity-bank selection bitplanes.

    The program (operand vectors + class masks) is pushed to the device
    ONCE at ``program()`` (``jax.device_put``); each engine call ships only
    the staging block, donated to XLA so the feature buffer is recycled
    across flushes rather than accumulating.
    """

    validated_knobs = (
        "instruction_capacity", "feature_capacity", "class_capacity",
        "weight_planes",  # the selection-bank depth is a compiled shape
    )
    instruction_metric = "includes"  # operand vectors hold includes only
    needs_decoded_plan = True

    def __init__(self, plan: CapacityPlan, implementation: str | None = None):
        super().__init__(plan)
        if implementation is None:
            # the Pallas kernel is the TPU artifact; its interpret-mode
            # emulation loses to the bit-exact XLA twin everywhere else
            implementation = (
                "pallas" if jax.default_backend() == "tpu" else "xla"
            )
        if implementation not in ("pallas", "xla"):
            raise ValueError(
                f"unknown implementation {implementation!r}; "
                f"choose 'pallas' or 'xla'"
            )
        self.implementation = implementation
        if implementation == "pallas":
            bi, bw = choose_blocks(
                plan.instruction_capacity, plan.batch_words
            )
            engine = functools.partial(
                _popcount_engine_pallas,
                block_instructions=bi, block_words=bw,
                interpret=jax.default_backend() != "tpu",
            )
        else:
            engine = _popcount_engine_xla
        self._fn = _private_jit(engine, donate_argnums=(4,))

    def _program(self, model: CompressedModel, decoded=None) -> Dict[str, Any]:
        p = self.plan
        plan = decoded if decoded is not None else decode_to_plan(model)
        # masks are built at the PLAN's plane depth (not the model's), so
        # the compiled mask shape is a synthesis-time constant: weighted
        # and weightless models swap through the same compiled program
        lit_idx, last, mask_pos, mask_neg = plan_to_popcount_operands(
            plan, p.instruction_capacity, p.class_capacity,
            l2_cap=2 * p.feature_capacity,
            weight_planes=p.weight_planes,
        )
        # the reprogram is pure data movement: resident on-device until the
        # next swap, never retraced (fixed capacity shapes)
        return {
            "lit_idx": jax.device_put(lit_idx),
            "last": jax.device_put(last),
            "mask_pos": jax.device_put(mask_pos),
            "mask_neg": jax.device_put(mask_neg),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        B = x.shape[0]
        # fresh device copy of the staging block; the engine donates it
        staged = jnp.asarray(self._pad_x(x))
        sums = self._dispatch(
            prog["lit_idx"], prog["last"],
            prog["mask_pos"], prog["mask_neg"], staged,
        )
        return np.asarray(sums)[: prog["n_classes"], :B].T


@register_engine("sharded", needs_mesh=True, priority=5)
class ShardedEngine(EngineBase):
    """dist.tm_sharded clause-major engine on a (data, model) mesh.

    Built once at CAPACITY shape (classes padded to the model axis, clause
    tables at clause/include capacity); programming a model fills the
    fixed-shape tables, so swaps never touch the compiled shard_map.
    """

    validated_knobs = (
        "feature_capacity", "class_capacity",
        "clause_capacity", "include_capacity",
    )
    needs_decoded_plan = True

    def __init__(self, plan: CapacityPlan, mesh=None):
        super().__init__(plan)
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        self.mesh = mesh
        cfg = TMShardedConfig(
            name="serve", n_classes=plan.class_capacity,
            n_clauses=plan.clause_capacity,
            n_features=plan.feature_capacity,
            batch=plan.batch_capacity,
            include_cap=plan.include_capacity,
        )
        fn, _ = build_tm_sharded(cfg, mesh)
        # route through _private_jit like every other engine so the
        # compile_cache_size() == 1 contract is enforced uniformly
        self._fn = _private_jit(fn)
        self._Mp = _pad_to(
            plan.class_capacity, _axis_sizes(mesh).get("model", 1)
        )

    def _program(self, model: CompressedModel, decoded=None) -> Dict[str, Any]:
        p = self.plan
        plan = decoded if decoded is not None else decode_to_plan(model)
        # plan.validate already bounded clauses/includes per class; the
        # table fill re-checks as a corruption guard
        idx, pol = fill_clause_tables(
            plan, self._Mp, p.clause_capacity, p.include_capacity,
            2 * p.feature_capacity,
        )
        return {
            "idx": jnp.asarray(idx), "pol": jnp.asarray(pol),
            "n_classes": model.n_classes,
            "n_features": model.n_features,
        }

    def class_sums(self, prog: Dict[str, Any], x: np.ndarray) -> np.ndarray:
        p = self.plan
        B = x.shape[0]
        lits = np.asarray(
            literals(jnp.asarray(self._pad_x(x), bool))
        ).astype(np.int8)  # [B_cap, 2*F_cap]
        lits1 = np.concatenate(
            [lits, np.ones((p.batch_capacity, 1), np.int8)], axis=1
        )
        sums = self._fn(prog["idx"], prog["pol"], jnp.asarray(lits1))
        return np.asarray(sums)[:B, : prog["n_classes"]]
