"""Fig 6 analog: memory-depth customization options.

Sweeps the accelerator's instruction-memory depth (the eFPGA BRAM
customization) and reports the BRAM-byte budget of each depth plus which of
the paper's edge datasets fit (the vertical lines in Fig 6)."""

from __future__ import annotations

from repro.core.runtime import AcceleratorConfig
from .tm_bench_common import trained_tm

DATASETS = ("emg", "har", "gesture", "sensorless", "gas")
DEPTHS = (1 << 12, 1 << 13, 1 << 14, 1 << 15, 1 << 16)


def run():
    rows = []
    needs = {}
    for name in DATASETS:
        tm = trained_tm(name)
        needs[name] = (tm.model.n_instructions, tm.cfg.n_features)
        rows.append((
            f"fig6/{name}_required_depth", 0.0,
            f"instructions={tm.model.n_instructions};features={tm.cfg.n_features}",
        ))
    for depth in DEPTHS:
        acfg = AcceleratorConfig(
            instruction_capacity=depth, feature_capacity=1 << 12,
            class_capacity=16, batch_words=1,
        )
        fitting = [n for n, (i, f) in needs.items() if i <= depth and f <= 1 << 12]
        rows.append((
            f"fig6/depth_{depth}_bram_bytes", 0.0,
            f"bram={acfg.bram_bytes};fits={'+'.join(fitting) or 'none'}",
        ))
    return rows
