"""Table 1 analog: resource usage of the accelerator configurations.

The paper reports LUT/FF/BRAM per configuration (Base/Single/Multi) —
unmeasurable here.  We report what drives them: instruction-memory bytes,
feature-memory bytes, accumulator-bank bytes (the BRAM budget of each
AcceleratorConfig), the MNIST-scale compression ratio that makes the model
fit on-chip, and the compiled-program size of the jitted interpreter (the
"logic" analog).
"""

from __future__ import annotations

import numpy as np

from repro.core.runtime import Accelerator, AcceleratorConfig
from .tm_bench_common import synthetic_mnist_scale, time_call


CONFIGS = {
    # memory-depth choices mirroring the paper's Base / Single / Multi
    "base": AcceleratorConfig(
        instruction_capacity=1 << 14, feature_capacity=1 << 11,
        class_capacity=16, batch_words=1,
    ),
    "single_core": AcceleratorConfig(
        instruction_capacity=1 << 15, feature_capacity=1 << 12,
        class_capacity=32, batch_words=1,
    ),
    "multi_core_5x": AcceleratorConfig(
        instruction_capacity=1 << 15, feature_capacity=1 << 12,
        class_capacity=32, batch_words=1,
    ),
}


def run():
    rows = []
    cfg, model = synthetic_mnist_scale()
    dense_bytes = cfg.n_tas // 8
    rows.append((
        "table1/mnist_model_dense_bytes", 0.0, dense_bytes,
    ))
    rows.append((
        "table1/mnist_model_instructions", 0.0, model.n_instructions,
    ))
    rows.append((
        "table1/mnist_model_compressed_bytes", 0.0, model.n_bytes,
    ))
    rows.append((
        "table1/mnist_compression_ratio_pct", 0.0,
        round(100 * model.compression_ratio(cfg), 2),
    ))

    for name, acfg in CONFIGS.items():
        cores = 5 if name == "multi_core_5x" else 1
        bram = acfg.bram_bytes * cores
        rows.append((f"table1/{name}_bram_bytes", 0.0, bram))
        fits = model.n_instructions <= acfg.instruction_capacity
        if cores == 1 and fits:
            eng = Accelerator(acfg)
            from repro.core.runtime import build_instruction_stream

            eng.feed(build_instruction_stream(model))
            x = np.zeros((32, cfg.n_features), np.uint8)
            t = time_call(eng.infer, x, repeats=5, warmup=1)
            rows.append((
                f"table1/{name}_interp_us_per_32batch", round(t * 1e6, 1),
                f"fits_mnist={fits}",
            ))
        else:
            # the paper's base A7035 config likewise does NOT hold MNIST —
            # it targets the smaller edge datasets (Fig 6 discussion)
            rows.append((f"table1/{name}_fits_mnist", 0.0, fits))
    return rows
