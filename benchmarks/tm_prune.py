"""Prune-pass benchmark: the accuracy-vs-bytes-vs-throughput frontier.

Walks a trained model through the ``repro.prune`` compression ladder —

    baseline -> prune_exact -> exact+merge (PrunePolicy) -> prune_ranked

— and, at every rung, re-negotiates a fresh ``CapacityPlan`` from the
pruned artifact (the envelope-renegotiation story: smaller programs buy
tighter compiled shapes) and times every registered engine against it.
Emits ``BENCH_tm_prune.json`` (CWD) plus the harness CSV rows.

    PYTHONPATH=src python -m benchmarks.run --only tm_prune

The correctness proofs ride the bench, as in ``tm_kernels``:

  * exact/merge points are asserted BIT-EXACT against the unpruned dense
    weighted oracle, per engine;
  * the ranked point's holdout accuracy is asserted within ``tolerance``
    of the unpruned baseline;
  * bytes are asserted monotonically non-increasing along the frontier
    (the ``PrunePolicy`` size gate makes this a hard invariant).

``BENCH_TINY=1`` shrinks training for the CI smoke step.
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.accel.capacity import CapacityPlan
from repro.accel.engine import make_engine
from repro.core import include_actions
from repro.core.compress import encode
from repro.core.tm import batch_class_sums_weighted, predict_weighted, state_from_actions
from repro.prune import PrunePolicy, PruneReport, PruneResult, prune_exact

from .tm_bench_common import time_call, trained_tm

OUT_PATH = "BENCH_tm_prune.json"

DATASET = "emg"
TOLERANCE = 0.02
ENGINES = ("interp", "plan", "popcount", "sharded")


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _oracle_sums(cfg, acts, X, weights=None):
    w = None if weights is None else jnp.asarray(weights, jnp.int32)
    return np.asarray(batch_class_sums_weighted(
        cfg, state_from_actions(cfg, acts), jnp.asarray(X), w
    ))


def _accuracy(cfg, acts, weights, X, y) -> float:
    w = None if weights is None else jnp.asarray(weights, jnp.int32)
    pred = np.asarray(predict_weighted(
        cfg, state_from_actions(cfg, acts), jnp.asarray(X), w
    ))
    return float((pred == np.asarray(y)).mean())


def _frontier(cfg, acts, x_hold, y_hold):
    """[(name, PruneResult)] — the compression ladder, each rung built
    from the ORIGINAL actions so the reports count cumulative work."""
    n = int(acts.any(-1).sum())
    base = PruneResult(
        actions=acts, weights=None,
        report=PruneReport(stages=(), n_clauses_before=n, n_clauses_after=n),
    )
    return [
        ("baseline", base),
        ("prune_exact", prune_exact(cfg, acts)),
        ("exact_merge", PrunePolicy().apply(cfg, acts)),
        ("prune_ranked", PrunePolicy(tolerance=TOLERANCE).apply(
            cfg, acts, X=x_hold, y=y_hold
        )),
    ]


def _bench_point(name, cfg, result, X, ref_sums, repeats):
    """Encode one rung, renegotiate its envelope, time every engine."""
    model = encode(cfg, result.actions, clause_weights=result.weights)
    plan = CapacityPlan.for_models(
        [model], batch_words=max(1, X.shape[0] // 32)
    )
    exact_claim = name in ("baseline", "prune_exact", "exact_merge")

    point = {
        "point": name,
        "bytes": model.n_bytes,
        "n_instructions": model.n_instructions,
        "n_clauses": int(result.actions.any(-1).sum()),
        "weighted": result.weights is not None,
        "stages": list(result.report.stages),
        "bit_exact": exact_claim,
        "capacity": {
            "instruction_capacity": plan.instruction_capacity,
            "clause_capacity": plan.clause_capacity,
            "include_capacity": plan.include_capacity,
            "weight_planes": plan.weight_planes,
        },
        "backends": {},
    }
    rows = []
    for backend in ENGINES:
        opts = {"implementation": "xla"} if backend == "popcount" else {}
        eng = make_engine(backend, plan, **opts)
        prog = eng.program(model)
        sums = eng.class_sums(prog, X)
        if exact_claim:
            # the lossless rungs must reproduce the UNPRUNED sums bit for
            # bit on every engine — the claim the report publishes
            assert np.array_equal(sums, ref_sums), (
                f"{name}/{backend}: pruned class sums diverge from the "
                f"unpruned oracle"
            )
        t = time_call(lambda: eng.class_sums(prog, X), repeats=repeats)
        B = X.shape[0]
        point["backends"][backend] = {
            "us_per_call": t * 1e6,
            "throughput_dps": B / t,
        }
        rows.append((
            f"tm_prune_{name}_{backend}",
            f"{t * 1e6:.1f}",
            f"dps={B / t:.0f};bytes={model.n_bytes}",
        ))
    return model, point, rows


def run():
    tiny = _tiny()
    tm = (
        trained_tm(DATASET, n_clauses=24, epochs=2) if tiny
        else trained_tm(DATASET)
    )
    cfg = tm.cfg
    acts = np.asarray(include_actions(cfg, tm.state)).astype(bool)
    x_hold, y_hold = tm.x_test, tm.y_test

    batch_words = 1 if tiny else 2
    B = batch_words * 32
    X = np.asarray(x_hold[:B], np.uint8)
    ref_sums = _oracle_sums(cfg, acts, X)
    baseline_acc = _accuracy(cfg, acts, None, x_hold, y_hold)

    report = {
        "bench": "tm_prune",
        "tiny": tiny,
        "dataset": DATASET,
        "tolerance": TOLERANCE,
        "baseline_accuracy": baseline_acc,
        "frontier": [],
    }
    rows = []
    repeats = 5 if tiny else 20
    for name, result in _frontier(cfg, acts, x_hold, y_hold):
        model, point, point_rows = _bench_point(
            name, cfg, result, X, ref_sums, repeats
        )
        point["accuracy"] = _accuracy(
            cfg, result.actions, result.weights, x_hold, y_hold
        )
        report["frontier"].append(point)
        rows.extend(point_rows)

    # -- frontier invariants (assert here, gate again in check_regression) --
    pts = report["frontier"]
    for prev, cur in zip(pts, pts[1:]):
        assert cur["bytes"] <= prev["bytes"], (
            f"frontier bytes grew: {prev['point']} {prev['bytes']}B -> "
            f"{cur['point']} {cur['bytes']}B"
        )
    ranked = pts[-1]
    assert ranked["accuracy"] >= baseline_acc - TOLERANCE, (
        f"ranked point fell out of tolerance: {ranked['accuracy']:.4f} < "
        f"{baseline_acc:.4f} - {TOLERANCE}"
    )
    report["ranked_accuracy"] = ranked["accuracy"]
    report["ranked_bytes_shrink_vs_baseline"] = (
        1.0 - ranked["bytes"] / pts[0]["bytes"]
    )

    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return rows
