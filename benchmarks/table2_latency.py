"""Table 2 analog: latency/energy across the paper's five edge datasets,
single-datapoint vs batched (the paper's B/S/M vs ESP32 comparison).

Measured columns: jitted compressed-interpreter wall time on this CPU (the
"software MCU" analog) for batch=1 and batch=32, and the decoded-plan
parallel executor (beyond-paper path).  Modeled columns: eFPGA cycles ->
latency/energy from the paper's 4-cycle/200MHz/0.35W constants.
"""

from __future__ import annotations

import numpy as np

from repro.core.compress import decode_to_plan
from repro.core.interp import pack_features, interpret_stream, pad_plan, plan_class_sums
from .tm_bench_common import (
    modeled_efpga_energy_j,
    modeled_efpga_latency_s,
    time_call,
    trained_tm,
)

import jax.numpy as jnp

DATASETS = ("emg", "har", "gesture", "sensorless", "gas")


def run():
    rows = []
    for name in DATASETS:
        tm = trained_tm(name)
        cfg, model = tm.cfg, tm.model
        n_inst = model.n_instructions
        i_cap = max(1024, 1 << int(np.ceil(np.log2(n_inst + 1))))
        f_cap = 1 << int(np.ceil(np.log2(cfg.n_features + 1)))
        imem = np.zeros(i_cap, np.uint16)
        imem[:n_inst] = model.instructions
        imem_j = jnp.asarray(imem)

        x1 = tm.x_test[:32]  # one word = up to 32 datapoints

        def run_interp(x):
            packed = pack_features(jnp.asarray(x), f_cap, 1)
            return interpret_stream(imem_j, jnp.int32(n_inst), packed,
                                    jnp.int32(x.shape[0]), m_cap=16)

        t_single = time_call(run_interp, tm.x_test[:1], repeats=10)
        t_batch = time_call(run_interp, x1, repeats=10)

        # decoded-plan parallel executor (beyond-paper)
        plan = decode_to_plan(model)
        ncl_cap = cfg.n_classes * cfg.n_clauses
        li, ci, cc, cp = (jnp.asarray(a) for a in pad_plan(plan, i_cap, ncl_cap))
        lits32 = np.stack(
            [tm.x_test[:32], 1 - tm.x_test[:32]], axis=-1
        ).reshape(32, -1).astype(np.int8)

        def run_plan(lits):
            return plan_class_sums(li, ci, cc, cp, jnp.asarray(lits),
                                   n_clause_cap=ncl_cap, m_cap=16)

        t_plan = time_call(run_plan, lits32, repeats=10)

        lat_model = modeled_efpga_latency_s(n_inst)
        e_model = modeled_efpga_energy_j(n_inst)
        rows.append((
            f"table2/{name}_acc", 0.0, round(tm.accuracy, 3),
        ))
        rows.append((
            f"table2/{name}_instructions", 0.0, n_inst,
        ))
        rows.append((
            f"table2/{name}_interp_single_us", round(t_single * 1e6, 1),
            f"batched32_us={t_batch * 1e6:.1f}",
        ))
        rows.append((
            f"table2/{name}_interp_per_dp_us", round(t_batch / 32 * 1e6, 2),
            f"batch_speedup={t_single * 32 / t_batch:.1f}x",
        ))
        rows.append((
            f"table2/{name}_plan_batched32_us", round(t_plan * 1e6, 1),
            f"plan_vs_interp={t_batch / t_plan:.1f}x",
        ))
        rows.append((
            f"table2/{name}_efpga_model_batch32_us", round(lat_model * 1e6, 2),
            f"energy_uJ={e_model * 1e6:.2f}",
        ))
    return rows
