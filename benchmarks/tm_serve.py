"""Serving-subsystem benchmark: multi-tenant batched throughput + hot-swap
under traffic, per engine, plus the ``repro.accel`` artifact deploy path
(compile -> serialize -> load -> first prediction).  Emits
``BENCH_tm_serve.json`` (CWD) and the harness CSV rows.

    PYTHONPATH=src python -m benchmarks.run --only tm_serve

``BENCH_TINY=1`` shrinks capacities and traffic for the CI smoke step.
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.accel import Accelerator, TMProgram, engine_names
from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.serve_tm import ServeCapacity, TMServer

OUT_PATH = "BENCH_tm_serve.json"


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _random_model(rng, M, C, F, density=0.03):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_preds(cfg, acts, X) -> np.ndarray:
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    ).argmax(1).astype(np.int32)


def _bench_backend(backend: str, capacity: ServeCapacity, tiny: bool) -> dict:
    rng = np.random.default_rng(7)
    # tenant A and its recalibrated successor B: different class count AND
    # feature count (the acceptance-criteria swap)
    dims_a = (6, 12, 48) if tiny else (10, 24, 96)
    dims_b = (4, 8, 32) if tiny else (7, 16, 64)
    cfg_a, acts_a, model_a = _random_model(rng, *dims_a)
    cfg_b, acts_b, model_b = _random_model(rng, *dims_b)
    n_requests = 16 if tiny else 64
    max_rows = 8 if tiny else 24

    server = TMServer(capacity, backend=backend)
    server.register("tenant", model_a)

    bit_exact = True

    def traffic(cfg, acts, n):
        nonlocal bit_exact
        handles = []
        for _ in range(n):
            x = rng.integers(
                0, 2, (int(rng.integers(1, max_rows + 1)), cfg.n_features)
            ).astype(np.uint8)
            handles.append((server.submit("tenant", x), cfg, acts, x))
        server.flush()
        for h, c, a, x in handles:
            if not np.array_equal(h.result(), _oracle_preds(c, a, x)):
                bit_exact = False

    # warm the engine outside the metrics window (first call compiles);
    # the direct class_sums hook bypasses the queue and records nothing
    server.class_sums("tenant", np.zeros((1, cfg_a.n_features), np.uint8))

    traffic(cfg_a, acts_a, n_requests)
    # hot swap mid-traffic: queued rows drain under A, then B installs
    for _ in range(4):
        x = rng.integers(0, 2, (5, cfg_a.n_features)).astype(np.uint8)
        server.submit("tenant", x)
    server.register("tenant", model_b)
    traffic(cfg_b, acts_b, n_requests)

    summary = server.metrics.summary()
    summary["compile_cache_size"] = server.compile_cache_size()
    summary["bit_exact"] = bit_exact
    summary["model_a"] = dict(zip(("n_classes", "n_clauses", "n_features"),
                                  dims_a))
    summary["model_b"] = dict(zip(("n_classes", "n_clauses", "n_features"),
                                  dims_b))
    summary["artifact"] = _bench_artifact_path(
        backend, capacity, cfg_a, acts_a, model_a
    )
    return summary


def _bench_artifact_path(backend, capacity, cfg, acts, model) -> dict:
    """The repro.accel deploy path on a COLD accelerator: compile ->
    to_bytes -> from_bytes -> load -> first prediction.  first_pred_us
    includes the engine's one-time jit (the "synthesis" the deploy pays
    exactly once); load_us is the pure-data-movement reprogram."""
    acc = Accelerator(capacity, engine=backend)
    t0 = time.perf_counter()
    art = acc.compile(model)
    t1 = time.perf_counter()
    blob = art.to_bytes()
    t2 = time.perf_counter()
    art2 = TMProgram.from_bytes(blob)
    t3 = time.perf_counter()
    acc.load("deploy", art2)
    t4 = time.perf_counter()
    x = np.zeros((1, cfg.n_features), np.uint8)
    pred = acc.infer("deploy", x)
    t5 = time.perf_counter()
    oracle = _oracle_preds(cfg, acts, x)
    return {
        "bytes": len(blob),
        "compile_us": (t1 - t0) * 1e6,
        "serialize_us": (t2 - t1) * 1e6,
        "deserialize_us": (t3 - t2) * 1e6,
        "load_us": (t4 - t3) * 1e6,
        "first_pred_us": (t5 - t4) * 1e6,
        "total_us": (t5 - t0) * 1e6,
        "bit_exact": bool(np.array_equal(pred, oracle)),
    }


def run():
    tiny = _tiny()
    capacity = ServeCapacity(
        instruction_capacity=1024 if tiny else 4096,
        feature_capacity=64 if tiny else 128,
        class_capacity=16,
        clause_capacity=32,
        include_capacity=16 if tiny else 24,
        batch_words=2 if tiny else 4,
    )
    report = {
        "bench": "tm_serve",
        "tiny": tiny,
        "capacity": {
            "instruction_capacity": capacity.instruction_capacity,
            "feature_capacity": capacity.feature_capacity,
            "class_capacity": capacity.class_capacity,
            "clause_capacity": capacity.clause_capacity,
            "include_capacity": capacity.include_capacity,
            "batch_capacity": capacity.batch_capacity,
        },
        "backends": {},
    }
    rows = []
    for backend in engine_names():
        summary = _bench_backend(backend, capacity, tiny)
        report["backends"][backend] = summary
        art = summary["artifact"]
        rows.append((
            f"tm_serve_{backend}",
            f"{summary['engine_us']['p50']:.1f}",
            f"dps={summary['throughput_dps']:.0f}"
            f";fill={summary['fill_ratio']:.2f}"
            f";cache={summary['compile_cache_size']}"
            f";exact={int(summary['bit_exact'])}"
            f";art_total_us={art['total_us']:.0f}"
            f";art_load_us={art['load_us']:.0f}"
            f";art_bytes={art['bytes']}",
        ))
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return rows
