"""Serving-subsystem benchmark: multi-tenant batched throughput + hot-swap
under live scheduler traffic, per engine, plus the ``repro.accel``
artifact deploy path (compile -> serialize -> load -> first prediction)
and the continuous-batching OVERLOAD scenario (10x offered load, mixed
priority lanes, deadline shedding, admission control) compared against a
single-lane FIFO baseline.  Emits ``BENCH_tm_serve.json`` (CWD) and the
harness CSV rows.

    PYTHONPATH=src python -m benchmarks.run --only tm_serve

``BENCH_TINY=1`` shrinks capacities and traffic for the CI smoke step.
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.accel import Accelerator, TMProgram, engine_names
from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.serve_tm import DeadlineExceeded, ServeCapacity, TMServer

OUT_PATH = "BENCH_tm_serve.json"

# overload traffic mix: fraction of offered requests per priority lane
OVERLOAD_MIX = {"critical": 0.1, "high": 0.2, "normal": 0.4, "low": 0.3}

# per-lane deadline budget as a multiple of the estimated backlog drain
# time; low's budget is 1/10th of the drain (the "10x offered load"
# definition: ten times more backlog than its SLO horizon can absorb).
# The non-low lanes also get an absolute floor so a scheduling hiccup on
# a busy CI box can't shed traffic the scenario needs completed.
OVERLOAD_DEADLINE_MULT = {
    "critical": 3.0, "high": 2.0, "normal": 1.5, "low": 0.1,
}
OVERLOAD_DEADLINE_FLOOR_S = {
    "critical": 0.25, "high": 0.25, "normal": 0.25, "low": 0.0,
}


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _random_model(rng, M, C, F, density=0.03):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_preds(cfg, acts, X) -> np.ndarray:
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    ).argmax(1).astype(np.int32)


def _bench_backend(backend: str, capacity: ServeCapacity, tiny: bool) -> dict:
    rng = np.random.default_rng(7)
    # tenant A and its recalibrated successor B: different class count AND
    # feature count (the acceptance-criteria swap)
    dims_a = (6, 12, 48) if tiny else (10, 24, 96)
    dims_b = (4, 8, 32) if tiny else (7, 16, 64)
    cfg_a, acts_a, model_a = _random_model(rng, *dims_a)
    cfg_b, acts_b, model_b = _random_model(rng, *dims_b)
    n_requests = 16 if tiny else 64
    max_rows = 8 if tiny else 24

    server = TMServer(capacity, backend=backend)
    server.register("tenant", model_a)

    bit_exact = True

    def traffic(cfg, acts, n):
        nonlocal bit_exact
        handles = []
        for _ in range(n):
            x = rng.integers(
                0, 2, (int(rng.integers(1, max_rows + 1)), cfg.n_features)
            ).astype(np.uint8)
            handles.append((server.submit("tenant", x), cfg, acts, x))
        for h, c, a, x in handles:
            if not np.array_equal(h.wait(timeout=120.0),
                                  _oracle_preds(c, a, x)):
                bit_exact = False

    # warm the engine outside the metrics window (first call compiles);
    # the direct class_sums hook bypasses the queue and records nothing
    server.class_sums("tenant", np.zeros((1, cfg_a.n_features), np.uint8))

    # traffic rides the continuous-batching loop: no flush() anywhere —
    # the scheduler forms every batch and completes every handle
    server.start()
    try:
        traffic(cfg_a, acts_a, n_requests)
        # LIVE hot swap: queued rows drain under A (the swap holds the
        # scheduler lock across drain + install), then B takes over
        with server.scheduler.lock:
            pend = [
                (server.submit(
                    "tenant",
                    rng.integers(0, 2, (5, cfg_a.n_features)).astype(
                        np.uint8
                    ),
                ))
                for _ in range(4)
            ]
            server.register("tenant", model_b)
        for h in pend:
            h.wait(timeout=120.0)
        traffic(cfg_b, acts_b, n_requests)
    finally:
        server.stop()

    summary = server.metrics.summary()
    summary["compile_cache_size"] = server.compile_cache_size()
    summary["bit_exact"] = bit_exact
    summary["model_a"] = dict(zip(("n_classes", "n_clauses", "n_features"),
                                  dims_a))
    summary["model_b"] = dict(zip(("n_classes", "n_clauses", "n_features"),
                                  dims_b))
    summary["artifact"] = _bench_artifact_path(
        backend, capacity, cfg_a, acts_a, model_a
    )
    return summary


def _bench_artifact_path(backend, capacity, cfg, acts, model) -> dict:
    """The repro.accel deploy path on a COLD accelerator: compile ->
    to_bytes -> from_bytes -> load -> first prediction.  first_pred_us
    includes the engine's one-time jit (the "synthesis" the deploy pays
    exactly once); load_us is the pure-data-movement reprogram."""
    acc = Accelerator(capacity, engine=backend)
    t0 = time.perf_counter()
    art = acc.compile(model)
    t1 = time.perf_counter()
    blob = art.to_bytes()
    t2 = time.perf_counter()
    art2 = TMProgram.from_bytes(blob)
    t3 = time.perf_counter()
    acc.load("deploy", art2)
    t4 = time.perf_counter()
    x = np.zeros((1, cfg.n_features), np.uint8)
    pred = acc.infer("deploy", x)
    t5 = time.perf_counter()
    oracle = _oracle_preds(cfg, acts, x)
    return {
        "bytes": len(blob),
        "compile_us": (t1 - t0) * 1e6,
        "serialize_us": (t2 - t1) * 1e6,
        "deserialize_us": (t3 - t2) * 1e6,
        "load_us": (t4 - t3) * 1e6,
        "first_pred_us": (t5 - t4) * 1e6,
        "total_us": (t5 - t0) * 1e6,
        "bit_exact": bool(np.array_equal(pred, oracle)),
    }


def _overload_trace(rng, capacity, tiny):
    """Deterministic mixed-priority burst: ~10x more rows than the low
    lane's SLO horizon can absorb, request sizes of a quarter batch."""
    n_batches = 8 if tiny else 20
    rows_per_req = max(1, capacity.batch_capacity // 4)
    n_requests = n_batches * capacity.batch_capacity // rows_per_req
    lanes = []
    for lane, frac in OVERLOAD_MIX.items():
        lanes.extend([lane] * max(1, round(frac * n_requests)))
    lanes = lanes[:n_requests]
    rng.shuffle(lanes)
    return lanes, rows_per_req


def _drain_all_terminal(handles):
    served = 0
    for h in handles:
        try:
            h.wait(timeout=300.0)
            served += 1
        except DeadlineExceeded:
            pass
    return served


def _bench_overload(capacity, tiny: bool) -> dict:
    """The continuous-batching overload scenario: a burst of ~10x offered
    load in mixed priority lanes with per-lane deadlines, served by the
    running scheduler loop, vs the SAME burst through a single-lane FIFO
    baseline (all-normal, no deadlines).  The lane run must keep the
    critical lane fast (p99 below the FIFO p99) and shed-free while the
    low lane sheds/rejects — the edge-SLO shape the runtime exists for."""
    rng = np.random.default_rng(21)
    dims = (6, 12, 48) if tiny else (8, 16, 64)
    cfg, acts, model = _random_model(rng, *dims)
    lanes, rows_per_req = _overload_trace(rng, capacity, tiny)
    offered_rows = len(lanes) * rows_per_req
    blocks = [
        rng.integers(0, 2, (rows_per_req, cfg.n_features)).astype(np.uint8)
        for _ in lanes
    ]

    def fresh_server(**kw):
        server = TMServer(capacity, backend="plan", **kw)
        server.register("edge", model)
        # warm (compile) outside every timing window
        server.class_sums("edge", np.zeros((1, cfg.n_features), np.uint8))
        return server

    # calibrate one full-batch engine pass -> backlog drain estimate
    server = fresh_server()
    xb = rng.integers(
        0, 2, (capacity.batch_capacity, cfg.n_features)
    ).astype(np.uint8)
    t_batch = min(
        _timed(lambda: server.class_sums("edge", xb)) for _ in range(3)
    )
    est_drain_s = (offered_rows / capacity.batch_capacity) * t_batch * 1.5

    def lane_budget_ms(lane):
        return (
            OVERLOAD_DEADLINE_MULT[lane] * est_drain_s
            + OVERLOAD_DEADLINE_FLOOR_S[lane]
        ) * 1e3

    # -- FIFO baseline: same burst, one lane, no deadlines ------------------
    server.start()
    try:
        with server.scheduler.lock:  # queue the whole burst, then serve
            fifo_handles = [server.submit("edge", x) for x in blocks]
        _drain_all_terminal(fifo_handles)
    finally:
        server.stop()
    fifo = server.metrics.summary()["lanes"]["normal"]

    # -- the lane run: mixed priorities, deadlines, admission control -------
    # the low lane also gets a tight queue-depth budget so sustained
    # overload produces structured admission rejects, not just sheds
    server = fresh_server(
        lane_depth_rows={"low": 2 * capacity.batch_capacity}
    )
    server.start()
    handles = []
    try:
        import asyncio

        from repro.serve_tm import Overloaded

        async def burst():
            with server.scheduler.lock:
                for lane, x in zip(lanes, blocks):
                    try:
                        handles.append(await server.async_submit(
                            "edge", x, priority=lane,
                            timeout_ms=lane_budget_ms(lane),
                        ))
                    except Overloaded:
                        pass  # counted by the server's admission metrics

        asyncio.run(burst())
        _drain_all_terminal(handles)
    finally:
        server.stop()
    summary = server.metrics.summary()

    lane_stats = summary["lanes"]
    return {
        "backend": "plan",
        "offered_requests": len(lanes),
        "offered_rows": offered_rows,
        "rows_per_request": rows_per_req,
        "offered_load_x": 1.0 / OVERLOAD_DEADLINE_MULT["low"],
        "mix": OVERLOAD_MIX,
        "t_batch_us": t_batch * 1e6,
        "est_drain_ms": est_drain_s * 1e3,
        "deadline_budget_ms": {p: lane_budget_ms(p) for p in OVERLOAD_MIX},
        "fifo_baseline": {
            "completed": fifo["completed"],
            "p50_us": fifo["latency_us"]["p50"],
            "p99_us": fifo["latency_us"]["p99"],
        },
        "lanes": lane_stats,
        "sheds": summary["sheds"],
        "admission_rejects": summary["admission_rejects"],
        "deadline_misses": summary["deadline_misses"],
        "critical_p99_us": lane_stats["critical"]["latency_us"]["p99"],
        "fifo_p99_us": fifo["latency_us"]["p99"],
        "critical_vs_fifo_speedup": (
            fifo["latency_us"]["p99"]
            / max(lane_stats["critical"]["latency_us"]["p99"], 1e-9)
        ),
        "slo_attainment": {
            p: lane_stats[p]["slo_attainment"] for p in OVERLOAD_MIX
        },
        "compile_cache_size": server.compile_cache_size(),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run():
    tiny = _tiny()
    capacity = ServeCapacity(
        instruction_capacity=1024 if tiny else 4096,
        feature_capacity=64 if tiny else 128,
        class_capacity=16,
        clause_capacity=32,
        include_capacity=16 if tiny else 24,
        batch_words=2 if tiny else 4,
    )
    report = {
        "bench": "tm_serve",
        "tiny": tiny,
        "capacity": {
            "instruction_capacity": capacity.instruction_capacity,
            "feature_capacity": capacity.feature_capacity,
            "class_capacity": capacity.class_capacity,
            "clause_capacity": capacity.clause_capacity,
            "include_capacity": capacity.include_capacity,
            "batch_capacity": capacity.batch_capacity,
        },
        "backends": {},
    }
    rows = []
    for backend in engine_names():
        summary = _bench_backend(backend, capacity, tiny)
        report["backends"][backend] = summary
        art = summary["artifact"]
        rows.append((
            f"tm_serve_{backend}",
            f"{summary['engine_us']['p50']:.1f}",
            f"dps={summary['throughput_dps']:.0f}"
            f";fill={summary['fill_ratio']:.2f}"
            f";cache={summary['compile_cache_size']}"
            f";exact={int(summary['bit_exact'])}"
            f";art_total_us={art['total_us']:.0f}"
            f";art_load_us={art['load_us']:.0f}"
            f";art_bytes={art['bytes']}",
        ))
    overload = _bench_overload(capacity, tiny)
    report["overload"] = overload
    rows.append((
        "tm_serve_overload",
        f"{overload['critical_p99_us']:.1f}",
        f"fifo_p99_us={overload['fifo_p99_us']:.0f}"
        f";speedup={overload['critical_vs_fifo_speedup']:.1f}"
        f";crit_shed={overload['lanes']['critical']['shed']}"
        f";low_shed={overload['lanes']['low']['shed']}"
        f";rejects={overload['admission_rejects']}"
        f";crit_slo={overload['slo_attainment']['critical']:.2f}",
    ))
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return rows
