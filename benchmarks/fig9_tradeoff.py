"""Fig 9 / Fig 1 analog: the flexibility-vs-speed trade-off.

The paper trades throughput for runtime tunability (interpreter) against
MATADOR's hardwired per-model circuits.  The same trade exists one level up
in this framework:

  * ``interp``  — the faithful sequential interpreter (fully tunable: new
    model = new buffer contents, zero recompiles)
  * ``plan``    — decoded-plan parallel executor (tunable; plan rebuilt on
    the host in O(n_inst))
  * ``dense``   — bitpacked dense clause evaluation (the MATADOR analog:
    specialized to a model SIZE; fastest batched path, recompiles when the
    architecture changes)

All three computed on the same trained models, batch=32 and batch=256.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import include_actions, pack_literals
from repro.core.compress import decode_to_plan
from repro.core.interp import interpret_stream, pack_features, pad_plan, plan_class_sums
from repro.kernels.clause_eval.ref import clause_eval_ref, class_sums_from_clause_words
from .tm_bench_common import time_call, trained_tm

DATASETS = ("emg", "gas")


def run():
    rows = []
    for name in DATASETS:
        tm = trained_tm(name)
        cfg, model = tm.cfg, tm.model
        n_inst = model.n_instructions
        i_cap = max(1024, 1 << int(np.ceil(np.log2(n_inst + 1))))
        f_cap = 1 << int(np.ceil(np.log2(cfg.n_features + 1)))
        imem = np.zeros(i_cap, np.uint16)
        imem[:n_inst] = model.instructions
        imem_j = jnp.asarray(imem)

        for B in (32, 256):
            x = np.resize(tm.x_test, (B, cfg.n_features)).astype(np.uint8)
            W = B // 32

            def run_interp(xx):
                packed = pack_features(jnp.asarray(xx), f_cap, W)
                return interpret_stream(imem_j, jnp.int32(n_inst), packed,
                                        jnp.int32(B), m_cap=16)

            t_interp = time_call(run_interp, x, repeats=5)

            plan = decode_to_plan(model)
            ncl = cfg.n_classes * cfg.n_clauses
            li, ci, cc, cp = (jnp.asarray(a) for a in pad_plan(plan, i_cap, ncl))
            lits = np.stack([x, 1 - x], -1).reshape(B, -1).astype(np.int8)

            def run_plan(ll):
                return plan_class_sums(li, ci, cc, cp, jnp.asarray(ll),
                                       n_clause_cap=ncl, m_cap=16)

            t_plan = time_call(run_plan, lits, repeats=5)

            actions = jnp.asarray(
                np.asarray(include_actions(cfg, tm.state)).reshape(
                    cfg.n_classes * cfg.n_clauses, cfg.n_literals
                ).astype(np.int32)
            )
            pol = jnp.tile(
                jnp.where(jnp.arange(cfg.n_clauses) % 2 == 0, 1, -1), cfg.n_classes
            ).astype(jnp.int32)
            packed = pack_literals(jnp.asarray(x))

            def run_dense(pk):
                words = clause_eval_ref(actions, pk)
                return class_sums_from_clause_words(words, pol, cfg.n_classes)

            run_dense_j = jax.jit(run_dense)
            t_dense = time_call(run_dense_j, packed, repeats=5)

            # MXU formulation (kernels/clause_matmul ref): clause = zero-
            # violation integer matmul — the systolic-array adaptation
            from repro.kernels.clause_matmul.ref import clause_matmul_ref

            lits_T = jnp.asarray(lits.T.astype(np.int32))  # [2F, B]

            def run_mxu(ll):
                fired = clause_matmul_ref(actions, ll).astype(jnp.int32)
                return (fired * pol[:, None]).reshape(
                    cfg.n_classes, cfg.n_clauses, -1
                ).sum(axis=1)

            run_mxu_j = jax.jit(run_mxu)
            t_mxu = time_call(run_mxu_j, lits_T, repeats=5)

            rows.append((
                f"fig9/{name}_B{B}_interp_us", round(t_interp * 1e6, 1),
                f"per_dp_us={t_interp / B * 1e6:.2f}",
            ))
            rows.append((
                f"fig9/{name}_B{B}_plan_us", round(t_plan * 1e6, 1),
                f"speedup_vs_interp={t_interp / t_plan:.1f}x",
            ))
            rows.append((
                f"fig9/{name}_B{B}_dense_us", round(t_dense * 1e6, 1),
                f"speedup_vs_interp={t_interp / t_dense:.1f}x",
            ))
            rows.append((
                f"fig9/{name}_B{B}_mxu_us", round(t_mxu * 1e6, 1),
                f"speedup_vs_interp={t_interp / t_mxu:.1f}x",
            ))
    return rows
