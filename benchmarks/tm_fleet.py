"""Fleet benchmark: routed replica pools + canary artifact rollouts.

Four scenarios, emitted to ``BENCH_tm_fleet.json`` (CWD) plus harness
CSV rows:

  * **pool sweep** — the same workload routed over pools of 1 / 2 / 4
    heterogeneous-engine nodes; aggregate throughput is the SUM of the
    per-node engine rates.  (This container is single-core, so the sweep
    models n independent accelerator boxes: each node's backlog is
    drained with no host contention and the per-node rates add, exactly
    as n real edge boards would.  Wall-clock across threads would only
    measure GIL arbitration.)  Every routed reply is checked bit-exact
    against the dense oracle.
  * **mid-traffic rollout** — a live 4-node fleet (loops running) keeps
    serving router traffic while a new ``TMProgram`` ships canary →
    wave → fleet-wide; the gate is ZERO dropped requests and every
    reply matching the old or the new program's oracle.
  * **canary failure** — a bad artifact dies at the canary's accuracy
    gate and the WHOLE fleet rolls back: every node must end on the old
    checksum with rollback provenance.
  * **chaos** — a 4-node pool of ``ChaosNode``-wrapped servers (seeded
    injected errors, latency, ``Overloaded`` storms, hung handles) with
    mixed-priority traffic; ONE node is killed mid-traffic and later
    revived.  The gates, asserted in-bench and schema-gated by
    ``check_regression.py``: ZERO critical-lane requests lost or
    incorrect (every handle resolves with a bit-exact prediction or a
    structured error — none block forever), the dead node quarantined
    within the consecutive-failure threshold, and the fleet recovered
    through the half-open probe after revival.

    PYTHONPATH=src python -m benchmarks.run --only tm_fleet

``BENCH_TINY=1`` shrinks capacities and traffic for the CI smoke step.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.accel import CapacityPlan, TMProgram
from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.fleet import (
    ChaosNode,
    FleetHealth,
    FleetPool,
    RetryPolicy,
    RolloutAborted,
    RolloutManager,
    Router,
)
from repro.serve_tm import TMServer

OUT_PATH = "BENCH_tm_fleet.json"

POOL_SIZES = (1, 2, 4)
ENGINE_CYCLE = ("interp", "plan", "popcount", "sharded")


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _random_model(rng, M, C, F, density=0.03):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_sums(cfg, acts, X) -> np.ndarray:
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    )


def _oracle_preds(cfg, acts, X) -> np.ndarray:
    return _oracle_sums(cfg, acts, X).argmax(1).astype(np.int32)


def _make_pool(n, capacity, slot, artifact, warm_features):
    """n heterogeneous-engine TMServer nodes, warmed outside any window."""
    pool = FleetPool()
    for i in range(n):
        node = TMServer(capacity, engine=ENGINE_CYCLE[i % len(ENGINE_CYCLE)])
        node.register(slot, artifact)
        node.class_sums(slot, np.zeros((1, warm_features), np.uint8))
        pool.add(f"n{i}", node)
    return pool


# -- scenario 1: pool sweep --------------------------------------------------


def _bench_pool_sweep(capacity, tiny):
    """One fixed workload, routed over 1/2/4-node pools.  Loops stay
    stopped so the router's least-depth choice spreads the queue, then
    each node drains its own backlog contention-free (n independent
    boxes); aggregate dps = sum of node rates (schema.py rollup)."""
    rng = np.random.default_rng(11)
    dims = (5, 12, 40) if tiny else (8, 16, 64)
    cfg, acts, model = _random_model(rng, *dims)
    art = TMProgram(capacity=capacity, model=model)
    n_requests = 8 if tiny else 48
    rows = capacity.batch_capacity
    blocks = [
        rng.integers(0, 2, (rows, cfg.n_features)).astype(np.uint8)
        for _ in range(n_requests)
    ]
    oracles = [_oracle_preds(cfg, acts, x) for x in blocks]

    points = []
    for n in POOL_SIZES:
        pool = _make_pool(n, capacity, "m", art, cfg.n_features)
        router = Router(pool)
        handles = [router.submit("m", x) for x in blocks]
        routed = {}
        for h in handles:
            routed[h.routed_to] = routed.get(h.routed_to, 0) + 1
        for _, node in pool.items():  # each box drains its own backlog
            node.flush()
        bit_exact = all(
            np.array_equal(h.result(), y) for h, y in zip(handles, oracles)
        )
        summary = pool.metrics_summary()
        agg = summary["aggregate"]
        points.append({
            "nodes": n,
            "engines": [type(node.executor).__name__
                        for _, node in pool.items()],
            "requests": n_requests,
            "rows": agg["rows"],
            "throughput_dps": agg["throughput_dps"],
            "per_node_dps": {
                name: s["throughput_dps"]
                for name, s in summary["nodes"].items()
            },
            "fill_ratio": agg["fill_ratio"],
            "routed": routed,
            "bit_exact": bit_exact,
        })
    dps = {p["nodes"]: p["throughput_dps"] for p in points}
    return {
        "model": dict(zip(("n_classes", "n_clauses", "n_features"), dims)),
        "rows_per_request": rows,
        "points": points,
        "scaling_2x_vs_1x": dps[2] / dps[1],
        "scaling_4x_vs_1x": dps[4] / dps[1],
    }


# -- scenario 2: mid-traffic rollout -----------------------------------------


def _bench_rollout_under_traffic(capacity, tiny):
    """A live 4-node fleet serves router traffic from a background
    thread while v2 ships canary -> wave -> fleet.  Gates: zero dropped
    requests, every reply matches the old OR new program's oracle, and
    post-rollout traffic runs on v2."""
    rng = np.random.default_rng(13)
    dims = (5, 12, 40) if tiny else (8, 16, 64)
    cfg1, acts1, m1 = _random_model(rng, *dims)
    cfg2, acts2, m2 = _random_model(rng, *dims)
    v1 = TMProgram(capacity=capacity, model=m1)
    v2 = TMProgram(capacity=capacity, model=m2)
    pool = _make_pool(4, capacity, "edge", v1, cfg1.n_features)
    router = Router(pool)

    n_blocks = 6 if tiny else 24
    rows = max(2, capacity.batch_capacity // 4)
    blocks = [
        rng.integers(0, 2, (rows, cfg1.n_features)).astype(np.uint8)
        for _ in range(n_blocks)
    ]
    holdout = rng.integers(
        0, 2, (16 if tiny else 64, cfg1.n_features)
    ).astype(np.uint8)
    y2 = _oracle_preds(cfg2, acts2, holdout)  # the NEW program's truth

    served = []  # (handle, x)
    stop = threading.Event()

    def traffic():
        i = 0
        while not stop.is_set():
            x = blocks[i % n_blocks]
            served.append((router.submit("edge", x), x))
            i += 1
            # stay under the single-core live service rate: an offered
            # load above it grows every queue without bound and the
            # rollout's gate waits inherit the backlog
            time.sleep(0.001 if tiny else 0.004)

    pool.start_all()
    t_thread = threading.Thread(target=traffic, daemon=True)
    try:
        t_thread.start()
        time.sleep(0.05)  # traffic in flight before the rollout starts
        t0 = time.perf_counter()
        report = RolloutManager(pool).rollout(
            "edge", v2, holdout_x=holdout, holdout_y=y2,
            min_accuracy=0.99,  # v2 must ace its own holdout on every node
        )
        rollout_s = time.perf_counter() - t0
        time.sleep(0.05)  # post-rollout traffic on the new program
    finally:
        stop.set()
        t_thread.join(timeout=30.0)
        for h, _ in served:  # everything admitted must complete
            try:
                h.wait(timeout=300.0)
            except Exception:
                pass
        pool.stop_all()

    dropped = incorrect = on_v1 = on_v2 = 0
    for h, x in served:
        if h.status != "done":  # expired (shed) or still pending
            dropped += 1
            continue
        preds = h.result()
        if np.array_equal(preds, _oracle_preds(cfg1, acts1, x)):
            on_v1 += 1
        elif np.array_equal(preds, _oracle_preds(cfg2, acts2, x)):
            on_v2 += 1
        else:
            incorrect += 1

    fleet_on_v2 = all(
        node.installed_checksum("edge") == v2.checksum
        for _, node in pool.items()
    )
    return {
        "nodes": 4,
        "requests": len(served),
        "dropped": dropped,
        "incorrect": incorrect,
        "served_on_old": on_v1,
        "served_on_new": on_v2,
        "rollout_ms": rollout_s * 1e3,
        "completed": report.completed,
        "baseline_accuracy": report.baseline_accuracy,
        "fleet_on_new_checksum": fleet_on_v2,
        "stages": [
            {
                "stage": s.stage,
                "nodes": list(s.nodes),
                "install_ms": s.install_s * 1e3,
                "verify_ms": s.verify_s * 1e3,
                "bit_exact": s.bit_exact,
                "accuracy": s.accuracy,
            }
            for s in report.stages
        ],
    }


# -- scenario 3: canary failure ----------------------------------------------


def _bench_canary_failure(capacity, tiny):
    """A bad artifact must die at the canary and the fleet must retreat:
    every node back on the old checksum, rollback provenance recorded."""
    rng = np.random.default_rng(17)
    dims = (5, 12, 40) if tiny else (8, 16, 64)
    cfg1, acts1, m1 = _random_model(rng, *dims)
    _, _, bad = _random_model(rng, *dims)
    v1 = TMProgram(capacity=capacity, model=m1)
    v_bad = TMProgram(capacity=capacity, model=bad)
    pool = _make_pool(4, capacity, "edge", v1, cfg1.n_features)
    holdout = rng.integers(
        0, 2, (16 if tiny else 64, cfg1.n_features)
    ).astype(np.uint8)
    y1 = _oracle_preds(cfg1, acts1, holdout)  # CURRENT program's truth

    t0 = time.perf_counter()
    aborted = None
    try:
        RolloutManager(pool).rollout(
            "edge", v_bad, holdout_x=holdout, holdout_y=y1,
        )
    except RolloutAborted as e:
        aborted = e
    abort_s = time.perf_counter() - t0

    fleet_consistent = all(
        node.installed_checksum("edge") == v1.checksum
        for _, node in pool.items()
    )
    rolled = aborted.report.rolled_back if aborted else ()
    provenance_ok = aborted is not None and all(
        pool.node(name).registry.get("edge").provenance.startswith(
            "rollback:"
        )
        for name in rolled
    )
    return {
        "nodes": 4,
        "aborted": aborted is not None,
        "failed_stage": aborted.stage if aborted else None,
        "canary_accuracy": (
            aborted.report.stages[-1].accuracy if aborted else None
        ),
        "baseline_accuracy": (
            aborted.report.baseline_accuracy if aborted else None
        ),
        "rolled_back": list(rolled),
        "fleet_consistent_on_old": fleet_consistent,
        "rollback_provenance_ok": provenance_ok,
        "abort_ms": abort_s * 1e3,
    }


# -- scenario 4: chaos (kill a node mid-traffic) -----------------------------


def _bench_chaos(capacity, tiny):
    """Four ChaosNode-wrapped heterogeneous servers under mixed-priority
    load; one node is killed mid-traffic and revived later.  The driver
    resubmits critical requests on structured errors; the bench asserts
    zero critical requests lost or incorrect, quarantine within the
    consecutive-failure threshold, and half-open-probe recovery."""
    rng = np.random.default_rng(19)
    dims = (5, 12, 40) if tiny else (8, 16, 64)
    cfg, acts, model = _random_model(rng, *dims)
    art = TMProgram(capacity=capacity, model=model)
    victim = "n1"

    pool = FleetPool()
    chaos = {}
    for i in range(4):
        name = f"n{i}"
        inner = TMServer(capacity, engine=ENGINE_CYCLE[i])
        inner.register("edge", art)
        inner.class_sums("edge", np.zeros((1, cfg.n_features), np.uint8))
        node = ChaosNode(
            inner, name=name, seed=100 + i,
            error_rate=0.03, latency_rate=0.04, latency_s=0.0005,
            overload_rate=0.02,
            # only the victim hangs: its kill() resolves the hung handles
            # (a hang on a node that never dies would block forever BY
            # DESIGN — that pathology is exercised in the unit tests)
            hang_rate=0.05 if name == victim else 0.0,
        )
        chaos[name] = node
        pool.add(name, node)
    consecutive_threshold = 3
    health = FleetHealth(
        pool=pool,
        consecutive_failures=consecutive_threshold,
        probe_after_s=0.05,
        heartbeat_timeout_s=600.0,  # the breaker, not the sweep, quarantines
    )
    router = Router(pool, health=health, retry=RetryPolicy(
        max_attempts=6, backoff_base_s=0.002, backoff_max_s=0.02,
    ))

    n_critical = 24 if tiny else 96
    kill_at, revive_at = n_critical // 3, (2 * n_critical) // 3
    rows = max(2, capacity.batch_capacity // 4)
    blocks = [
        rng.integers(0, 2, (rows, cfg.n_features)).astype(np.uint8)
        for _ in range(8)
    ]
    oracle = [
        (_oracle_preds(cfg, acts, x), _oracle_sums(cfg, acts, x))
        for x in blocks
    ]
    wait_s = 1.0 if tiny else 2.0

    background = []  # handles from the load generator
    stop = threading.Event()

    def load():
        i = 0
        while not stop.is_set():
            try:
                background.append(
                    router.submit("edge", blocks[i % len(blocks)],
                                  priority="normal")
                )
            except Exception:
                pass  # overload/exhausted retries: load is best-effort
            i += 1
            time.sleep(0.002 if tiny else 0.004)

    counts = {
        "lost": 0, "correct": 0, "incorrect": 0,
        "resubmits": 0, "structured_errors": 0,
    }
    quarantine_seen_at = None
    failures_at_quarantine = None
    served_by_victim_after_revive = 0

    def serve_critical(i):
        x = blocks[i % len(blocks)]
        want_preds, want_sums = oracle[i % len(blocks)]
        for attempt in range(12):
            if attempt:
                counts["resubmits"] += 1
            try:
                h = router.submit("edge", x, priority="critical",
                                  timeout_ms=2000.0)
            except Exception:
                counts["structured_errors"] += 1
                time.sleep(0.002)
                continue
            try:
                preds = h.wait(timeout=wait_s)
            except TimeoutError:
                continue  # hung handle: the retry budget moves on
            except Exception:
                counts["structured_errors"] += 1
                continue
            ok = (
                np.array_equal(preds, want_preds)
                and np.array_equal(np.asarray(h.class_sums), want_sums)
            )
            if ok and i >= revive_at and h.routed_to == victim:
                nonlocal_served[0] += 1
            return ok
        return None  # lost: every retry exhausted

    nonlocal_served = [0]
    pool.start_all()
    t_thread = threading.Thread(target=load, daemon=True)
    t0 = time.perf_counter()
    try:
        t_thread.start()
        for i in range(n_critical):
            if i == kill_at:
                chaos[victim].kill()
            if i == revive_at:
                chaos[victim].revive()
                chaos[victim].rates["hang"] = 0.0  # no unkillable hangs
                time.sleep(health.probe_after_s + 0.02)  # cooldown elapses
            ok = serve_critical(i)
            if ok is True:
                counts["correct"] += 1
            elif ok is False:
                counts["incorrect"] += 1
            else:
                counts["lost"] += 1
            if (
                quarantine_seen_at is None
                and health.state(victim) == "quarantined"
            ):
                quarantine_seen_at = i
                failures_at_quarantine = (
                    health.summary()[victim]["consecutive_failures"]
                )
    finally:
        stop.set()
        t_thread.join(timeout=30.0)
        for h in background:  # everything admitted must reach a terminal
            try:
                h.wait(timeout=300.0)
            except Exception:
                pass
        pool.stop_all()
    elapsed_s = time.perf_counter() - t0
    served_by_victim_after_revive = nonlocal_served[0]

    unresolved = sum(
        1 for h in background if h.status == "pending"
    )
    summary = health.summary()
    vict = summary[victim]
    quarantined = quarantine_seen_at is not None
    within_threshold = (
        quarantined
        and failures_at_quarantine is not None
        and failures_at_quarantine <= consecutive_threshold
    )
    # recovery = the breaker reopened the node via a half-open probe and
    # it is routable again.  "degraded" counts: a straggler-suspect
    # verdict on a recent (fault-injected) latency spike is orthogonal
    # to the quarantine/probe cycle under test.
    recovered = (
        vict["probes"] >= 1
        and vict["state"] not in ("quarantined", "half_open")
    )
    fleet_metrics = pool.metrics_summary()["aggregate"]

    # the acceptance gates, asserted here AND schema-gated in CI
    assert counts["lost"] == 0, f"critical requests lost: {counts}"
    assert counts["incorrect"] == 0, f"critical mismatches: {counts}"
    assert unresolved == 0, f"{unresolved} handles never reached terminal"
    assert quarantined, f"victim never quarantined: {vict}"
    assert within_threshold, (
        f"quarantine took {failures_at_quarantine} consecutive failures "
        f"(threshold {consecutive_threshold})"
    )
    assert recovered, f"victim not recovered via half-open probe: {vict}"

    return {
        "nodes": 4,
        "killed": victim,
        "killed_at_request": kill_at,
        "revived_at_request": revive_at,
        "critical_requests": n_critical,
        "critical_lost": counts["lost"],
        "critical_incorrect": counts["incorrect"],
        "critical_correct": counts["correct"],
        "critical_resubmits": counts["resubmits"],
        "structured_errors": counts["structured_errors"],
        "unresolved_handles": unresolved,
        "background_requests": len(background),
        "quarantined": quarantined,
        "quarantine_seen_at_request": quarantine_seen_at,
        "failures_at_quarantine": failures_at_quarantine,
        "quarantine_within_threshold": within_threshold,
        "recovered": recovered,
        "served_by_killed_after_revive": served_by_victim_after_revive,
        "fleet_retries": fleet_metrics["retries"],
        "fleet_failovers": fleet_metrics["failovers"],
        "fleet_quarantines": fleet_metrics["quarantines"],
        "fleet_probes": fleet_metrics["probes"],
        "health": summary,
        "elapsed_ms": elapsed_s * 1e3,
    }


def run():
    tiny = _tiny()
    capacity = CapacityPlan(
        instruction_capacity=1024 if tiny else 4096,
        feature_capacity=64 if tiny else 128,
        class_capacity=16,
        clause_capacity=32,
        include_capacity=16 if tiny else 24,
        batch_words=2 if tiny else 4,
    )
    sweep = _bench_pool_sweep(capacity, tiny)
    rollout = _bench_rollout_under_traffic(capacity, tiny)
    canary = _bench_canary_failure(capacity, tiny)
    chaos = _bench_chaos(capacity, tiny)
    report = {
        "bench": "tm_fleet",
        "tiny": tiny,
        "capacity": {
            "instruction_capacity": capacity.instruction_capacity,
            "feature_capacity": capacity.feature_capacity,
            "batch_capacity": capacity.batch_capacity,
        },
        "pool_sweep": sweep,
        "rollout_under_traffic": rollout,
        "canary_failure": canary,
        "chaos": chaos,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)

    rows = []
    for p in sweep["points"]:
        rows.append((
            f"tm_fleet_pool{p['nodes']}",
            f"{1e6 * p['rows'] / max(p['throughput_dps'], 1e-9):.1f}",
            f"dps={p['throughput_dps']:.0f}"
            f";fill={p['fill_ratio']:.2f}"
            f";exact={int(p['bit_exact'])}",
        ))
    rows.append((
        "tm_fleet_rollout",
        f"{rollout['rollout_ms'] * 1e3:.0f}",
        f"dropped={rollout['dropped']}"
        f";incorrect={rollout['incorrect']}"
        f";on_new={rollout['served_on_new']}"
        f";stages={len(rollout['stages'])}"
        f";scal4x={sweep['scaling_4x_vs_1x']:.2f}",
    ))
    rows.append((
        "tm_fleet_canary",
        f"{canary['abort_ms'] * 1e3:.0f}",
        f"aborted={int(canary['aborted'])}"
        f";stage={canary['failed_stage']}"
        f";consistent={int(canary['fleet_consistent_on_old'])}"
        f";prov_ok={int(canary['rollback_provenance_ok'])}",
    ))
    rows.append((
        "tm_fleet_chaos",
        f"{chaos['elapsed_ms'] * 1e3:.0f}",
        f"lost={chaos['critical_lost']}"
        f";resub={chaos['critical_resubmits']}"
        f";quar={int(chaos['quarantined'])}"
        f";rec={int(chaos['recovered'])}"
        f";failover={chaos['fleet_failovers']}",
    ))
    return rows
