"""Kernel-level microbenchmark: the compressed-inference engines head to
head over a small capacity sweep.  Emits ``BENCH_tm_kernels.json`` (CWD)
and the harness CSV rows — the seed of the kernel perf trajectory the
regression gate tracks.

    PYTHONPATH=src python -m benchmarks.run --only tm_kernels

Backends (all bit-exact, asserted per sweep point):

  * ``interp``   — core.interp.interpret_stream, the paper-faithful
    sequential stream interpreter (one instruction per scan step);
  * ``plan``     — core.interp.plan_class_sums, gather + segmented reduce;
  * ``popcount`` — kernels.tm_popcount, packed clause words + bitplane
    transpose + ``lax.population_count`` class reduction (XLA twin of the
    Pallas kernel — what serving runs off-TPU).

``BENCH_TINY=1`` shrinks the sweep for the CI smoke step.  ``BENCH_PALLAS=1``
additionally times the Pallas kernels in interpret mode (CPU emulation —
slow, relative ordering only; excluded from the regression-gated numbers).
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig
from repro.core.compress import decode_to_plan, encode
from repro.core.interp import interpret_stream, pack_features, pad_plan, plan_class_sums
from repro.core.tm import literals
from repro.kernels.tm_interp.kernel import tm_interp
from repro.kernels.tm_interp.ops import pack_interleaved_literals, plan_to_operands
from repro.kernels.tm_popcount.kernel import tm_popcount, tm_popcount_xla
from repro.kernels.tm_popcount.ops import plan_to_popcount_operands
from repro.kernels.tuning import choose_blocks

from .tm_bench_common import time_call

OUT_PATH = "BENCH_tm_kernels.json"


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _with_pallas() -> bool:
    return os.environ.get("BENCH_PALLAS", "0") == "1"


def _sweep(tiny: bool):
    """(name, i_cap, n_features, m_cap, batch_words, n_clauses/class)."""
    if tiny:
        return [("tiny", 512, 64, 8, 1, 16)]
    return [
        ("small", 1024, 128, 16, 2, 32),
        ("medium", 2048, 256, 16, 4, 48),
        # the ServeCapacity() default deployment point — the acceptance
        # criterion (popcount >= 2x interp) is judged here
        ("default", 4096, 256, 16, 4, 64),
    ]


def _synthetic_point(rng, i_cap, n_features, m_cap, n_clauses, fill=0.85):
    """A random model whose include count fills ~``fill`` of ``i_cap``."""
    M = m_cap // 2 if m_cap > 2 else m_cap  # model under capacity, like prod
    density = min(0.5, fill * i_cap / (M * n_clauses * 2 * n_features))
    cfg = TMConfig(n_classes=M, n_clauses=n_clauses, n_features=n_features)
    while True:
        acts = rng.random((M, n_clauses, 2 * n_features)) < density
        model = encode(cfg, acts)
        plan = decode_to_plan(model)
        if plan.n_includes <= i_cap:
            return cfg, model, plan
        density *= 0.9


def _bench_point(name, i_cap, n_features, m_cap, batch_words, n_clauses):
    rng = np.random.default_rng(11)
    cfg, model, plan = _synthetic_point(rng, i_cap, n_features, m_cap, n_clauses)
    B = batch_words * 32
    X = rng.integers(0, 2, (B, n_features)).astype(np.uint8)
    n_inst = model.n_instructions
    f_cap, l2_cap = n_features, 2 * n_features

    # ---- operand staging (program time, off the clock) -------------------
    imem = np.zeros(i_cap, np.uint16)
    imem[:n_inst] = model.instructions
    packed_feat = pack_features(jnp.asarray(X), f_cap, batch_words)
    args_interp = (jnp.asarray(imem), jnp.int32(n_inst), packed_feat,
                   jnp.int32(B))

    ncl_cap = max(64, -(-plan.n_clauses_total // 64) * 64)
    li, ci, cc, cp = pad_plan(plan, i_cap, ncl_cap)
    lits_bool = literals(jnp.asarray(X))
    args_plan = tuple(jnp.asarray(a) for a in (li, ci, cc, cp)) + (lits_bool,)

    packed_lits = pack_interleaved_literals(jnp.asarray(X))
    pc_ops = plan_to_popcount_operands(plan, i_cap, m_cap, l2_cap=l2_cap)
    args_pc = tuple(jnp.asarray(a) for a in pc_ops) + (packed_lits,)

    calls = {
        "interp": lambda: interpret_stream(*args_interp, m_cap=m_cap),
        "plan": lambda: plan_class_sums(
            *args_plan, n_clause_cap=ncl_cap, m_cap=m_cap
        ),
        "popcount": lambda: tm_popcount_xla(*args_pc),
    }
    if _with_pallas():
        it_ops = plan_to_operands(plan, i_cap, m_cap=m_cap)
        args_it = tuple(jnp.asarray(a) for a in it_ops) + (packed_lits,)
        bi, bw = choose_blocks(i_cap, batch_words)
        calls["interp_pallas"] = lambda: tm_interp(
            *args_it, m_cap=m_cap, interpret=True
        )
        calls["popcount_pallas"] = lambda: tm_popcount(
            *args_pc, block_instructions=bi, block_words=bw, interpret=True
        )

    # ---- bit-exactness across engines (the proof rides the bench) -------
    ref = np.asarray(calls["interp"]())[:cfg.n_classes, :B]
    exact = {
        "plan": bool(
            (np.asarray(calls["plan"]())[:, :cfg.n_classes].T == ref).all()
        ),
        "popcount": bool(
            (np.asarray(calls["popcount"]())[:cfg.n_classes, :B] == ref).all()
        ),
    }

    bytes_moved = {
        "interp": 2 * i_cap + 4 * f_cap * batch_words + 4 * m_cap * B,
        "plan": 8 * i_cap + 8 * ncl_cap + B * l2_cap + 4 * B * m_cap,
        "popcount": (8 * i_cap + 8 * m_cap * (-(-i_cap // 32))
                     + 4 * l2_cap * batch_words + 4 * m_cap * B),
    }

    point = {
        "capacity": {
            "instruction_capacity": i_cap,
            "feature_capacity": n_features,
            "class_capacity": m_cap,
            "batch_words": batch_words,
            "batch": B,
        },
        "model": {
            "n_classes": cfg.n_classes,
            "n_clauses": cfg.n_clauses,
            "n_instructions": n_inst,
        },
        "bit_exact": exact,
        "backends": {},
    }
    rows = []
    for backend, fn in calls.items():
        repeats = 5 if backend.endswith("_pallas") else 20
        t = time_call(fn, repeats=repeats)
        stats = {
            "us_per_call": t * 1e6,
            "throughput_dps": B / t,
            "instructions_per_s": n_inst / t,
        }
        if backend in bytes_moved:
            stats["bytes_moved_per_call"] = bytes_moved[backend]
        point["backends"][backend] = stats
        rows.append((
            f"tm_kernels_{name}_{backend}",
            f"{t * 1e6:.1f}",
            f"dps={B / t:.0f};ips={n_inst / t:.0f}",
        ))
    point["speedup_popcount_vs_interp"] = (
        point["backends"]["popcount"]["throughput_dps"]
        / point["backends"]["interp"]["throughput_dps"]
    )
    return point, rows


def run():
    tiny = _tiny()
    report = {
        "bench": "tm_kernels",
        "tiny": tiny,
        "sweep": [],
    }
    rows = []
    for name, *caps in _sweep(tiny):
        point, point_rows = _bench_point(name, *caps)
        point["name"] = name
        report["sweep"].append(point)
        rows.extend(point_rows)
    last = report["sweep"][-1]
    report["default_point"] = last["name"]
    report["speedup_popcount_vs_interp"] = last["speedup_popcount_vs_interp"]
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)
    return rows
