"""Shared benchmark plumbing: trained models per dataset + timing helpers.

Hardware-model constants (paper Table 1 / Fig 5): the base accelerator
executes one include instruction in 4 clock cycles at 200 MHz on the A7035;
energy uses the paper's reported base-config power envelope (~0.35 W for
the Artix-7 class device).  These are MODELED numbers — the real
measurements in the paper came from the FPGA; we reproduce the evaluation
structure and report the model inputs explicitly.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig, accuracy, fit, include_actions, init_state
from repro.core.compress import CompressedModel, encode
from repro.data.pipeline import TM_DATASETS, booleanized_tm_dataset

CYCLES_PER_INSTRUCTION = 4  # Fig 5 pipeline
BASE_FREQ_HZ = 200e6  # Table 1, base config
BASE_POWER_W = 0.35  # modeled Artix-7 class envelope
BATCH_WORDS = 1  # 32 datapoints per pass (paper batching)


@dataclass
class TrainedTM:
    name: str
    cfg: TMConfig
    state: jax.Array
    model: CompressedModel
    accuracy: float
    x_test: np.ndarray
    y_test: np.ndarray


@lru_cache(maxsize=None)
def trained_tm(dataset: str, n_clauses: int = 60, epochs: int = 8) -> TrainedTM:
    spec = TM_DATASETS[dataset]
    xb, y, booler = booleanized_tm_dataset(spec, 1500, seed=0)
    xt, yt, _ = booleanized_tm_dataset(spec, 512, seed=1, booleanizer=booler)
    cfg = TMConfig(
        n_classes=spec.n_classes, n_clauses=n_clauses,
        n_features=booler.n_boolean_features,
    )
    state = init_state(cfg, jax.random.key(0))
    state = fit(cfg, state, jax.random.key(1), jnp.asarray(xb), jnp.asarray(y),
                epochs=epochs, batch=250)
    acc = accuracy(cfg, state, jnp.asarray(xt), jnp.asarray(yt))
    model = encode(cfg, np.asarray(include_actions(cfg, state)))
    return TrainedTM(dataset, cfg, state, model, acc, xt, yt)


def synthetic_mnist_scale() -> tuple[TMConfig, CompressedModel]:
    """Paper's MNIST numbers: 10 classes x 200 clauses x 1568 literals,
    ~17k includes (0.54% density)."""
    rng = np.random.default_rng(0)
    cfg = TMConfig(n_classes=10, n_clauses=200, n_features=784)
    acts = rng.random((10, 200, 1568)) < 17000 / 3136000
    return cfg, encode(cfg, acts)


def time_call(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    """-> median seconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def modeled_efpga_latency_s(n_instructions: int) -> float:
    return n_instructions * CYCLES_PER_INSTRUCTION / BASE_FREQ_HZ


def modeled_efpga_energy_j(n_instructions: int) -> float:
    return modeled_efpga_latency_s(n_instructions) * BASE_POWER_W
