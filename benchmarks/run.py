"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table2,fig6,fig9]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
Wall times are CPU-container measurements of the jitted JAX paths; the
eFPGA-model columns (cycles/latency/energy) are derived from the paper's
published pipeline/frequency constants (see tm_bench_common.py).
"""

from __future__ import annotations

import argparse
import sys

ALL = ("table1", "table2", "fig6", "fig9", "tm_serve", "tm_recal",
       "tm_kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=",".join(ALL))
    args = ap.parse_args()
    wanted = [w.strip() for w in args.only.split(",") if w.strip()]

    print("name,us_per_call,derived")
    for name in wanted:
        if name == "table1":
            from .table1_resources import run as r
        elif name == "table2":
            from .table2_latency import run as r
        elif name == "fig6":
            from .fig6_memory import run as r
        elif name == "fig9":
            from .fig9_tradeoff import run as r
        elif name == "tm_serve":
            from .tm_serve import run as r
        elif name == "tm_recal":
            from .tm_recal import run as r
        elif name == "tm_kernels":
            from .tm_kernels import run as r
        else:
            print(f"unknown benchmark {name}", file=sys.stderr)
            continue
        for row in r():
            print(",".join(str(x) for x in row), flush=True)


if __name__ == "__main__":
    main()
