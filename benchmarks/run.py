"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <suite>[,<suite>...]]
    PYTHONPATH=src python -m benchmarks.run --list

``--list`` prints the available suite names (for shell completion and CI
matrix generation) and exits 0.  ``--only`` selects suites so a CI job only pays for what it checks
(unknown names fail fast with exit code 2 — a typo must not silently
skip a gate).  Prints ``name,us_per_call,derived`` CSV rows per the
harness contract.  Wall times are CPU-container measurements of the
jitted JAX paths; the eFPGA-model columns (cycles/latency/energy) are
derived from the paper's published pipeline/frequency constants (see
tm_bench_common.py).
"""

from __future__ import annotations

import argparse
import importlib
import sys

# suite name -> module (lazy import: suites pull in jax at import time).
# ALL derives from this table, so adding a suite here is the ONLY step —
# a name in ALL can never silently dispatch to the wrong module.
SUITES = {
    "table1": "table1_resources",
    "table2": "table2_latency",
    "fig6": "fig6_memory",
    "fig9": "fig9_tradeoff",
    "tm_serve": "tm_serve",
    "tm_recal": "tm_recal",
    "tm_kernels": "tm_kernels",
    "tm_fleet": "tm_fleet",
    "tm_prune": "tm_prune",
}
ALL = tuple(SUITES)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", type=str, default=",".join(ALL), metavar="SUITE[,SUITE]",
        help=f"comma-separated subset of {', '.join(ALL)}",
    )
    ap.add_argument(
        "--list", action="store_true",
        help="print the available suite names (one per line) and exit 0",
    )
    args = ap.parse_args()
    if args.list:
        for name in ALL:
            print(name)
        return 0
    wanted = [w.strip() for w in args.only.split(",") if w.strip()]
    unknown = [w for w in wanted if w not in SUITES]
    if unknown:
        print(
            f"unknown benchmark suite(s) {', '.join(unknown)}; "
            f"choose from: {', '.join(ALL)}",
            file=sys.stderr,
        )
        return 2

    print("name,us_per_call,derived")
    for name in wanted:
        mod = importlib.import_module(f".{SUITES[name]}", __package__)
        for row in mod.run():
            print(",".join(str(x) for x in row), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
