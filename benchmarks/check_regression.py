"""Bench-regression gate for CI.

Compares every ``BENCH_*.json`` in the current directory against the copy
committed on a baseline git ref (default ``origin/main``) and fails when
any ``throughput_dps`` value dropped more than ``--max-drop`` (default
20%).  Values are matched by their JSON path (top-level and nested, e.g.
``backends.plan.throughput_dps``), so per-backend regressions can't hide
behind an improved sibling.

Every current bench file is also SCHEMA-validated (regardless of whether a
baseline exists): required top-level keys, a boolean ``tiny`` flag, at
least one ``throughput_dps`` value, and per-bench invariants (e.g.
``BENCH_tm_kernels.json`` must carry a non-empty sweep whose points all
report the ``interp``/``plan``/``popcount`` backends plus the
popcount-vs-interp speedup).  A malformed bench file fails the gate — a
bench that silently stops emitting throughput would otherwise dodge the
regression check forever.

Skips the REGRESSION comparison cleanly when:
  * the baseline ref has no copy of a bench file (first time a bench
    lands — today's bench trajectory starts empty), or
  * the tiny-mode flags differ (a tiny run is not comparable to a full
    run), or
  * git/the ref is unavailable (shallow clone without the baseline).

Baseline policy: the repo commits FULL-mode (``tiny: false``) bench files
only.  CI regenerates every bench with ``BENCH_TINY=1`` and therefore
always lands in the tiny-mismatch skip — in CI this gate is a schema +
comparability check, deliberately NOT a cross-machine wall-clock
comparison (shared-runner timings vs the authoring machine would flake
at any threshold).  The throughput comparison bites where it is
meaningful: full-mode runs on the machine class that produced the
committed baseline (local perf work, nightly/dedicated runners).

    python benchmarks/check_regression.py [--ref origin/main]
                                          [--max-drop 0.20] [--dir .]
"""

from __future__ import annotations

import argparse
import glob
import importlib.util
import json
import os
import subprocess
import sys

# the summary()/aggregate() key schema, loaded by FILE PATH so this gate
# stays a standalone script (no src/ on sys.path, no jax import) — the
# module is deliberately import-free pure data (see its docstring)
_SCHEMA_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    "..", "src", "repro", "serve_tm", "schema.py",
)
_spec = importlib.util.spec_from_file_location("_serve_schema_mod",
                                               _SCHEMA_PATH)
SCHEMA = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(SCHEMA)


def baseline_json(ref: str, name: str, repo_dir: str):
    """The bench file as committed on ``ref`` (None when absent)."""
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            capture_output=True, cwd=repo_dir, timeout=30,
        )
    except (OSError, subprocess.TimeoutExpired) as e:
        print(f"  [skip] git unavailable for {ref}:{name}: {e}")
        return None
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError as e:
        print(f"  [skip] baseline {ref}:{name} is not valid JSON: {e}")
        return None


def throughput_paths(obj, prefix=""):
    """-> {json.path: value} for every numeric throughput_dps key."""
    found = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else k
            if k == "throughput_dps" and isinstance(v, (int, float)):
                found[path] = float(v)
            else:
                found.update(throughput_paths(v, path))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            found.update(throughput_paths(v, f"{prefix}[{i}]"))
    return found


def _kernels_schema(data: dict):
    """BENCH_tm_kernels.json-specific invariants -> error strings."""
    errs = []
    sweep = data.get("sweep")
    if not isinstance(sweep, list) or not sweep:
        return ["sweep must be a non-empty list"]
    for point in sweep:
        pname = point.get("name", "?")
        backends = point.get("backends", {})
        missing = {"interp", "plan", "popcount"} - set(backends)
        if missing:
            errs.append(f"sweep[{pname}] missing backends {sorted(missing)}")
            continue
        for b, stats in backends.items():
            if not isinstance(stats.get("throughput_dps"), (int, float)):
                errs.append(f"sweep[{pname}].{b} lacks throughput_dps")
        if not isinstance(
            point.get("speedup_popcount_vs_interp"), (int, float)
        ):
            errs.append(f"sweep[{pname}] lacks speedup_popcount_vs_interp")
        exact = point.get("bit_exact", {})
        for b in ("plan", "popcount"):
            if exact.get(b) is not True:
                errs.append(f"sweep[{pname}] backend {b} not bit-exact")
    if not isinstance(
        data.get("speedup_popcount_vs_interp"), (int, float)
    ):
        errs.append("missing top-level speedup_popcount_vs_interp")
    return errs


def _serve_schema(data: dict):
    """BENCH_tm_serve.json-specific invariants -> error strings.

    Beyond per-backend throughput/bit-exactness, the continuous-batching
    overload scenario must report every priority lane's p50/p99 + SLO
    attainment and satisfy the lane-scheduling acceptance shape: the
    critical lane beats the single-lane FIFO baseline's p99, sheds
    nothing, and the low lane absorbs the overload (sheds and/or
    admission rejects)."""
    errs = []
    backends = data.get("backends")
    if not isinstance(backends, dict) or not backends:
        errs.append("backends must be a non-empty object")
    else:
        for b, s in backends.items():
            if s.get("bit_exact") is not True:
                errs.append(f"backends.{b} not bit-exact")
            if s.get("compile_cache_size") != 1:
                errs.append(f"backends.{b} compile_cache_size != 1")
            # every per-backend summary carries the FULL metrics schema
            # (single source of truth: serve_tm/schema.py)
            missing = [k for k in SCHEMA.SUMMARY_KEYS if k not in s]
            if missing:
                errs.append(f"backends.{b} summary missing {missing}")
    ov = data.get("overload")
    if not isinstance(ov, dict):
        return errs + ["missing 'overload' scenario"]
    for key in ("offered_load_x", "offered_rows", "fifo_p99_us",
                "critical_p99_us", "sheds", "admission_rejects"):
        if not isinstance(ov.get(key), (int, float)):
            errs.append(f"overload.{key} missing/non-numeric")
    lanes = ov.get("lanes")
    if not isinstance(lanes, dict):
        return errs + ["overload.lanes missing"]
    for lane in SCHEMA.LANES:
        stats = lanes.get(lane)
        if not isinstance(stats, dict):
            errs.append(f"overload.lanes.{lane} missing")
            continue
        missing = [k for k in SCHEMA.LANE_KEYS if k not in stats]
        if missing:
            errs.append(f"overload.lanes.{lane} missing {missing}")
        for pct in SCHEMA.PCT2_KEYS:
            if not {"p50", "p99"} <= set(stats.get(pct, {})):
                errs.append(f"overload.lanes.{lane}.{pct} lacks p50/p99")
        if not isinstance(stats.get("slo_attainment"), (int, float)):
            errs.append(f"overload.lanes.{lane}.slo_attainment missing")
    if errs:
        return errs
    if lanes["critical"]["shed"] != 0:
        errs.append("overload shed critical traffic (must be 0)")
    if lanes["low"]["shed"] + lanes["low"]["rejected"] <= 0:
        errs.append("overload produced no low-lane sheds/rejects")
    if ov["critical_p99_us"] >= ov["fifo_p99_us"]:
        errs.append(
            f"critical lane p99 {ov['critical_p99_us']:.0f}us did not beat "
            f"the FIFO baseline p99 {ov['fifo_p99_us']:.0f}us"
        )
    return errs


def _fleet_schema(data: dict):
    """BENCH_tm_fleet.json-specific invariants -> error strings.

    The pool sweep must be a non-empty bit-exact 1/2/4 scan, the
    mid-traffic rollout must complete all three stages with ZERO dropped
    and zero incorrect requests, the canary-failure scenario must abort
    at the canary and leave the fleet consistent on the old checksum,
    and the chaos scenario (one of four nodes killed mid-traffic under
    injected faults) must lose ZERO critical requests, quarantine the
    dead node within the circuit-breaker threshold window, and recover
    it through a half-open probe after revival — with every per-node
    health dict carrying the full schema.  Full-mode runs additionally
    gate the scaling claim (4-node aggregate >= 2x 1-node); tiny CI runs
    skip that one check — a shared runner's relative engine speeds are
    not the claim."""
    errs = []
    sweep = data.get("pool_sweep")
    if not isinstance(sweep, dict) or not sweep.get("points"):
        return ["pool_sweep.points must be a non-empty list"]
    for p in sweep["points"]:
        n = p.get("nodes", "?")
        if not isinstance(p.get("throughput_dps"), (int, float)):
            errs.append(f"pool_sweep point nodes={n} lacks throughput_dps")
        if p.get("bit_exact") is not True:
            errs.append(f"pool_sweep point nodes={n} not bit-exact")
    if not isinstance(sweep.get("scaling_4x_vs_1x"), (int, float)):
        errs.append("pool_sweep.scaling_4x_vs_1x missing")
    elif data.get("tiny") is False and sweep["scaling_4x_vs_1x"] < 2.0:
        errs.append(
            f"4-node aggregate only {sweep['scaling_4x_vs_1x']:.2f}x the "
            f"1-node throughput (claim: >= 2x)"
        )
    ro = data.get("rollout_under_traffic")
    if not isinstance(ro, dict):
        errs.append("missing 'rollout_under_traffic' scenario")
    else:
        if ro.get("completed") is not True:
            errs.append("rollout_under_traffic did not complete")
        if ro.get("dropped") != 0:
            errs.append(
                f"rollout dropped {ro.get('dropped')} requests (must be 0)"
            )
        if ro.get("incorrect") != 0:
            errs.append(
                f"rollout served {ro.get('incorrect')} incorrect replies "
                f"(must be 0)"
            )
        stages = [s.get("stage") for s in ro.get("stages", [])]
        if stages != ["canary", "wave", "fleet"]:
            errs.append(f"rollout stages {stages} != canary/wave/fleet")
    cf = data.get("canary_failure")
    if not isinstance(cf, dict):
        errs.append("missing 'canary_failure' scenario")
    else:
        if cf.get("aborted") is not True:
            errs.append("canary_failure did not abort")
        if cf.get("failed_stage") != "canary":
            errs.append(
                f"bad artifact survived past the canary "
                f"(failed at {cf.get('failed_stage')!r})"
            )
        if cf.get("fleet_consistent_on_old") is not True:
            errs.append("fleet not consistent on the old checksum "
                        "after the aborted rollout")
        if cf.get("rollback_provenance_ok") is not True:
            errs.append("rollback provenance missing on rolled-back nodes")
    ch = data.get("chaos")
    if not isinstance(ch, dict):
        errs.append("missing 'chaos' scenario")
    else:
        if ch.get("critical_lost") != 0:
            errs.append(
                f"chaos lost {ch.get('critical_lost')} critical requests "
                f"(must be 0)"
            )
        if ch.get("critical_incorrect") != 0:
            errs.append(
                f"chaos served {ch.get('critical_incorrect')} incorrect "
                f"critical replies (must be 0 — retried/failed-over "
                f"requests must stay bit-exact)"
            )
        if ch.get("unresolved_handles") != 0:
            errs.append(
                f"chaos left {ch.get('unresolved_handles')} handles "
                f"unresolved (every issued handle must reach a terminal "
                f"state)"
            )
        if ch.get("quarantined") is not True:
            errs.append("chaos never quarantined the killed node")
        if ch.get("quarantine_within_threshold") is not True:
            errs.append(
                "chaos quarantine took more consecutive failures than the "
                "circuit-breaker threshold allows"
            )
        if ch.get("recovered") is not True:
            errs.append("killed node did not recover through a half-open "
                        "probe after revival")
        health = ch.get("health")
        if not isinstance(health, dict) or not health:
            errs.append("chaos.health must be a non-empty object")
        else:
            for node, d in health.items():
                if not isinstance(d, dict):
                    errs.append(f"chaos.health.{node} must be an object")
                    continue
                missing = [k for k in SCHEMA.HEALTH_NODE_KEYS if k not in d]
                if missing:
                    errs.append(f"chaos.health.{node} missing {missing}")
                if d.get("state") not in SCHEMA.HEALTH_STATES:
                    errs.append(
                        f"chaos.health.{node}.state {d.get('state')!r} not "
                        f"in {list(SCHEMA.HEALTH_STATES)}"
                    )
    return errs


def _recal_schema(data: dict):
    """BENCH_tm_recal.json-specific invariants -> error strings.

    The per-TrainEngine comparison must carry the reference and packed
    columns, every column must be bit-identical to the reference (a speed
    number for a diverging trainer is meaningless), and full-mode runs
    additionally gate the fused-kernel claim: packed fit_step/s beats the
    reference host path.  Tiny CI runs skip the throughput ordering — a
    shared runner's relative engine speeds are not the claim."""
    errs = []
    te = data.get("train_engines")
    if not isinstance(te, dict) or not te:
        return ["train_engines must be a non-empty object"]
    for req in ("reference", "packed"):
        if req not in te:
            errs.append(f"train_engines missing the {req!r} column")
    for name, s in te.items():
        if not isinstance(s, dict) or not isinstance(
            s.get("steps_per_s"), (int, float)
        ):
            errs.append(f"train_engines.{name} lacks numeric steps_per_s")
            continue
        if s.get("bit_identical") is not True:
            errs.append(f"train_engines.{name} not bit-identical to reference")
    if errs:
        return errs
    if data.get("tiny") is False:
        ref = te["reference"]["steps_per_s"]
        pk = te["packed"]["steps_per_s"]
        if pk <= ref:
            errs.append(
                f"packed engine {pk:.1f} steps/s did not beat the reference "
                f"{ref:.1f} steps/s (the fused-kernel claim)"
            )
    return errs


def _prune_schema(data: dict):
    """BENCH_tm_prune.json-specific invariants -> error strings.

    The frontier must be a non-empty baseline->exact->merge->ranked walk:
    every lossless rung (prune_exact, exact_merge) must claim bit_exact,
    the ranked rung's holdout accuracy must sit within the declared
    tolerance of the unpruned baseline, and bytes must shrink
    monotonically along the walk (the PrunePolicy size gate's hard
    invariant — a compression pass that grows the artifact is a bug).
    Full-mode runs additionally gate the headline size claim: the ranked
    point is >= 30% smaller than the baseline.  Tiny CI runs skip that —
    an under-trained smoke model carries less redundancy to reclaim."""
    errs = []
    frontier = data.get("frontier")
    if not isinstance(frontier, list) or not frontier:
        return ["frontier must be a non-empty list"]
    names = [p.get("point") for p in frontier]
    for req in ("baseline", "prune_exact", "prune_ranked"):
        if req not in names:
            errs.append(f"frontier lacks the {req!r} point")
    for p in frontier:
        n = p.get("point", "?")
        if not isinstance(p.get("bytes"), int):
            errs.append(f"frontier point {n} lacks integer bytes")
        if not isinstance(p.get("accuracy"), (int, float)):
            errs.append(f"frontier point {n} lacks numeric accuracy")
        if not isinstance(p.get("backends"), dict) or not p["backends"]:
            errs.append(f"frontier point {n} lacks backend timings")
        if n in ("prune_exact", "exact_merge") and p.get("bit_exact") is not True:
            errs.append(f"lossless frontier point {n} not bit-exact")
    if errs:
        return errs
    for prev, cur in zip(frontier, frontier[1:]):
        if cur["bytes"] > prev["bytes"]:
            errs.append(
                f"frontier bytes grew: {prev['point']} {prev['bytes']}B -> "
                f"{cur['point']} {cur['bytes']}B"
            )
    base_acc = data.get("baseline_accuracy")
    tol = data.get("tolerance")
    ranked = frontier[names.index("prune_ranked")]
    if not isinstance(base_acc, (int, float)) or not isinstance(
        tol, (int, float)
    ):
        errs.append("missing numeric baseline_accuracy/tolerance")
    elif ranked["accuracy"] < base_acc - tol:
        errs.append(
            f"ranked accuracy {ranked['accuracy']:.4f} fell below "
            f"baseline {base_acc:.4f} - tolerance {tol}"
        )
    if data.get("tiny") is False:
        shrink = data.get("ranked_bytes_shrink_vs_baseline")
        if not isinstance(shrink, (int, float)) or shrink < 0.30:
            errs.append(
                f"ranked point shrank only {shrink} vs baseline "
                f"(claim: >= 30% smaller bytes within tolerance)"
            )
    return errs


SCHEMA_CHECKS = {
    "BENCH_tm_kernels.json": _kernels_schema,
    "BENCH_tm_serve.json": _serve_schema,
    "BENCH_tm_fleet.json": _fleet_schema,
    "BENCH_tm_recal.json": _recal_schema,
    "BENCH_tm_prune.json": _prune_schema,
}


def validate_schema(name: str, data) -> list:
    """Generic + per-bench schema checks -> list of failure strings."""
    errs = []
    if not isinstance(data, dict):
        return [f"{name}: top level must be a JSON object"]
    if "bench" not in data:
        errs.append("missing 'bench' key")
    if not isinstance(data.get("tiny"), bool):
        errs.append("missing/non-boolean 'tiny' flag")
    if not throughput_paths(data):
        errs.append("no throughput_dps values anywhere")
    extra = SCHEMA_CHECKS.get(name)
    if extra and not errs:
        errs.extend(extra(data))
    return [f"{name}: {e}" for e in errs]


def check_file(name: str, current: dict, baseline: dict, max_drop: float):
    """-> list of failure strings for one bench file."""
    if current.get("tiny") != baseline.get("tiny"):
        print(
            f"  [skip] {name}: tiny={current.get('tiny')} vs baseline "
            f"tiny={baseline.get('tiny')} — not comparable"
        )
        return []
    cur, base = throughput_paths(current), throughput_paths(baseline)
    failures = []
    for path, base_v in sorted(base.items()):
        cur_v = cur.get(path)
        if cur_v is None:
            print(f"  [skip] {name}: {path} absent from current run")
            continue
        if base_v <= 0:
            continue
        drop = 1.0 - cur_v / base_v
        status = "FAIL" if drop > max_drop else "ok"
        print(
            f"  [{status}] {name}: {path} {base_v:.0f} -> {cur_v:.0f} dps "
            f"({-drop:+.1%})"
        )
        if drop > max_drop:
            failures.append(
                f"{name}:{path} dropped {drop:.1%} "
                f"({base_v:.0f} -> {cur_v:.0f} dps, limit {max_drop:.0%})"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", default="origin/main",
                    help="git ref holding the baseline BENCH_*.json files")
    ap.add_argument("--max-drop", type=float, default=0.20,
                    help="maximum allowed fractional throughput drop")
    ap.add_argument("--dir", default=".",
                    help="directory holding the current BENCH_*.json files")
    args = ap.parse_args()

    bench_files = sorted(glob.glob(os.path.join(args.dir, "BENCH_*.json")))
    if not bench_files:
        print(f"no BENCH_*.json in {args.dir!r}; nothing to gate")
        return 0

    failures = []
    for path in bench_files:
        name = os.path.basename(path)
        with open(path) as f:
            current = json.load(f)
        schema_errs = validate_schema(name, current)
        if schema_errs:
            for e in schema_errs:
                print(f"  [FAIL] schema: {e}")
            failures.extend(f"schema: {e}" for e in schema_errs)
            continue
        print(f"  [ok] {name}: schema valid")
        baseline = baseline_json(args.ref, name, args.dir)
        if baseline is None:
            print(f"  [skip] {name}: no baseline on {args.ref} "
                  f"(first run of this bench)")
            continue
        failures.extend(check_file(name, current, baseline, args.max_drop))

    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
