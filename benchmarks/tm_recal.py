"""Recalibration-pipeline benchmark: the Fig-8 loop under the clock.

Measures the costs that bound how fast a deployment can chase drift:

  * trainer throughput  — ``fit_step``s/sec (and samples/sec) of the
    incremental training node, per TrainEngine plugin ('reference' host
    path vs the fused packed-TA 'packed' kernel vs the 'sharded'
    dist-mesh step, all replaying the identical (key, step, batch)
    sequence — the column doubles as a bit-identity check);
  * swap-to-first-correct-prediction latency — wall time from calling
    ``register`` (drain-then-swap) on a live slot to a served, correct
    prediction under the NEW model;
  * accuracy-vs-drift curve — stale-model accuracy vs post-recal accuracy
    at each drift level, recalibrated through the full controller path
    (buffer -> fine-tune -> validated compress -> hot-swap -> post-swap
    validation).

Emits ``BENCH_tm_recal.json`` (CWD) + harness CSV rows.

    PYTHONPATH=src python -m benchmarks.run --only tm_recal

``BENCH_TINY=1`` shrinks everything for the CI smoke step.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig
from repro.data.pipeline import TMDatasetSpec, booleanized_tm_dataset
from repro.recal import (
    DriftMonitor,
    RecalController,
    RecalWorker,
    make_train_engine,
)
from repro.serve_tm import ServeCapacity, TMServer

OUT_PATH = "BENCH_tm_recal.json"


def _tiny() -> bool:
    return os.environ.get("BENCH_TINY", "0") == "1"


def _bench_trainer(worker, x, y, batch: int, steps: int) -> dict:
    """Steady-state fit_step throughput (first call compiles, excluded)."""
    xb, yb = x[:batch], y[:batch]
    worker.fine_tune(xb, yb)  # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(steps):
        worker.fine_tune(xb, yb)
    jax.block_until_ready(worker.state)
    dt = time.perf_counter() - t0
    return {
        "steps_timed": steps,
        "steps_per_s": steps / dt,
        "samples_per_s": steps * batch / dt,
        "us_per_step": dt / steps * 1e6,
    }


def _bench_train_engines(cfg, state0, x, y, batch: int, steps: int) -> dict:
    """Per-TrainEngine steady-state fit_step throughput on identical work.

    Every engine replays the SAME (key, step, batch) sequence from the
    same initial state — the throughput column therefore doubles as a
    bit-identity audit: each engine's final canonical state must equal
    the reference's (``bit_identical``), or the speed number is
    meaningless.  The sharded engine runs on a 1x1 mesh here (the
    single-process bench box); its column measures shard_map overhead at
    trivial scale, not scaling."""
    xb = jnp.asarray(np.asarray(x[:batch], np.uint8))
    yb = jnp.asarray(np.asarray(y[:batch], np.int32))
    key = jax.random.key(0x7E57)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    engines = {
        "reference": make_train_engine("reference", cfg),
        "packed": make_train_engine("packed", cfg),
        "sharded": make_train_engine("sharded", cfg, mesh=mesh, batch=batch),
    }
    out, finals = {}, {}
    for name, eng in engines.items():
        internal = eng.prepare(state0)
        internal = eng.fit_step(internal, key, xb, yb, step=0)  # warm jit
        jax.block_until_ready(internal)
        t0 = time.perf_counter()
        for s in range(1, steps + 1):
            internal = eng.fit_step(internal, key, xb, yb, step=s)
        jax.block_until_ready(internal)
        dt = time.perf_counter() - t0
        finals[name] = np.asarray(eng.canonical(internal))
        out[name] = {
            "steps_timed": steps,
            "steps_per_s": steps / dt,
            "samples_per_s": steps * batch / dt,
            "us_per_step": dt / steps * 1e6,
        }
    for name, stats in out.items():
        stats["bit_identical"] = bool(
            np.array_equal(finals[name], finals["reference"])
        )
        stats["speedup_vs_reference"] = (
            stats["steps_per_s"] / out["reference"]["steps_per_s"]
        )
    return out


def _swap_to_first_correct(server, slot, model, probe_x, probe_y) -> float:
    """Seconds from initiating the hot-swap to a served correct prediction
    under the new program (the paper's runtime-reprogram turnaround)."""
    t0 = time.perf_counter()
    server.register(slot, model, provenance="bench:swap")
    preds = server.infer(slot, probe_x)
    dt = time.perf_counter() - t0
    if not (preds == probe_y).any():
        raise RuntimeError("probe traffic produced no correct prediction")
    return dt


def run():
    tiny = _tiny()
    spec = (
        TMDatasetSpec("recal-bench", 8, 3, 4, 24) if tiny
        else TMDatasetSpec("recal-bench", 16, 4, 4, 40)
    )
    n_train = 600 if tiny else 2000
    batch = 100 if tiny else 200
    timed_steps = 5 if tiny else 30
    drifts = (0.6, 1.2) if tiny else (0.4, 0.8, 1.2)
    epochs_initial = 3 if tiny else 5
    epochs_recal = 6 if tiny else 10

    xb, y, booler = booleanized_tm_dataset(spec, n_train, seed=0, drift=0.0)
    cfg = TMConfig(
        n_classes=spec.n_classes, n_clauses=spec.n_clauses,
        n_features=booler.n_boolean_features,
    )
    worker = RecalWorker(cfg, key=jax.random.key(7))
    worker.fine_tune_epochs(xb, y, epochs=epochs_initial, batch=batch)

    train_stats = _bench_trainer(worker, xb, y, batch, timed_steps)
    train_stats["engine"] = worker.train_engine
    engine_stats = _bench_train_engines(
        cfg, jnp.asarray(worker.snapshot()), xb, y, batch, timed_steps
    )

    server = TMServer(
        ServeCapacity(feature_capacity=128, instruction_capacity=8192),
        backend="plan",
    )
    controller = RecalController(
        server, "edge", worker,
        monitor=DriftMonitor(min_samples=64),
        buffer_batches=8, train_batch_size=batch,
        epochs_per_recal=epochs_recal,
    )
    controller.deploy()
    # warm the engine + measure the clean baseline
    xt, yt, _ = booleanized_tm_dataset(
        spec, 256, seed=1, drift=0.0, booleanizer=booler
    )
    baseline_acc = float((controller.observe(xt, yt) == yt).mean())
    controller.freeze_baseline()

    # swap latency: reinstall the current model into the LIVE slot with
    # traffic queued, then serve a labelled probe under the new version
    probe_x, probe_y, _ = booleanized_tm_dataset(
        spec, 32, seed=2, drift=0.0, booleanizer=booler
    )
    model_now = controller.compressor.compress(cfg, worker.state).model
    swap_lat = []
    for _ in range(3 if tiny else 8):
        server.submit("edge", probe_x)  # queued traffic the swap must drain
        swap_lat.append(
            _swap_to_first_correct(server, "edge", model_now, probe_x, probe_y)
        )
    swap_s = float(np.median(swap_lat))

    # accuracy-vs-drift: stale accuracy, recalibrate, recovered accuracy
    curve = []
    for drift in drifts:
        for i in range(4):
            xd, yd, _ = booleanized_tm_dataset(
                spec, batch, seed=50 + i + int(drift * 100),
                drift=drift, booleanizer=booler,
            )
            controller.observe(xd, yd)
        xe, ye, _ = booleanized_tm_dataset(
            spec, 512, seed=60 + int(drift * 100), drift=drift,
            booleanizer=booler,
        )
        acc_before = float((controller.observe(xe, ye) == ye).mean())
        event = controller.recalibrate(reason=f"bench:drift={drift}")
        acc_after = float((controller.observe(xe, ye) == ye).mean())
        curve.append({
            "drift": drift,
            "acc_before": acc_before,
            "acc_after": acc_after,
            "rolled_back": event.rolled_back,
            "train_s": event.train_s,
            "compress_s": event.compress_s,
            "swap_s": event.swap_s,
        })

    summary = server.metrics.summary()
    report = {
        "bench": "tm_recal",
        "tiny": tiny,
        "model": {
            "n_classes": cfg.n_classes,
            "n_clauses": cfg.n_clauses,
            "n_features": cfg.n_features,
        },
        "baseline_acc": baseline_acc,
        "train": train_stats,
        "train_engines": engine_stats,
        "swap_to_first_correct_us": swap_s * 1e6,
        "curve": curve,
        "recals": summary["recals"],
        "rollbacks": summary["rollbacks"],
        "swaps": summary["swaps"],
        "throughput_dps": summary["throughput_dps"],
        "compile_cache_size": server.compile_cache_size(),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1)

    recovered = ";".join(
        f"d{c['drift']}={c['acc_before']:.2f}->{c['acc_after']:.2f}"
        for c in curve
    )
    return [
        (
            "tm_recal_train",
            f"{train_stats['us_per_step']:.1f}",
            f"steps_per_s={train_stats['steps_per_s']:.1f}"
            f";samples_per_s={train_stats['samples_per_s']:.0f}",
        ),
        (
            "tm_recal_train_engines",
            f"{engine_stats['packed']['speedup_vs_reference']:.2f}",
            ";".join(
                f"{n}={s['steps_per_s']:.1f}steps_per_s"
                f"(bit_identical={s['bit_identical']})"
                for n, s in engine_stats.items()
            ),
        ),
        (
            "tm_recal_swap",
            f"{swap_s * 1e6:.1f}",
            f"swap_to_first_correct;cache={server.compile_cache_size()}",
        ),
        (
            "tm_recal_loop",
            f"{summary['engine_us']['p50']:.1f}",
            recovered,
        ),
    ]
