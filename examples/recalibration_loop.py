"""The paper's Fig-8 system: on-field recalibration without resynthesis,
through the ``repro.accel`` façade.

An edge accelerator answers inference traffic while the data distribution
DRIFTS (sensor aging / environment change — the paper's Gas Sensor Array
Drift scenario).  A co-located training node (Raspberry-Pi-class; here:
the JAX TM trainer on CPU) monitors accuracy, retrains on fresh data,
compiles a portable ``TMProgram`` artifact and ships its BYTES into the
live slot — the Fig-8 reprogram step over the wire.  The engine is never
recompiled: model, class count and input dimensionality are all runtime
state, and the loop asserts ``compile_cache_size() == 1`` throughout.

(For the fully-automated loop — drift monitor, replay buffer, publication
gate, auto-rollback — see examples/online_recal.py and repro.recal.)

Run:  PYTHONPATH=src python examples/recalibration_loop.py
      EXAMPLES_TINY=1 shrinks training/traffic for CI smoke runs.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.accel import Accelerator
from repro.core import TMConfig, fit, include_actions, init_state
from repro.core.compress import encode
from repro.data.pipeline import TM_DATASETS, booleanized_tm_dataset

TINY = os.environ.get("EXAMPLES_TINY", "0") == "1"
SPEC = TM_DATASETS["gas"]
RETRAIN_THRESHOLD = 0.90  # accuracy trigger for the training node
SLOT = "edge"
N_TRAIN = 300 if TINY else 1500
N_TRAFFIC = 96 if TINY else 320
EPOCHS = 2 if TINY else 8
DRIFTS = [0.0, 0.5, 1.2] if TINY else [0.0, 0.15, 0.3, 0.5, 0.8, 1.2]


def train_node(drift: float, booleanizer, seed: int):
    """The Fig-8 Model Training Node: (re)train on the CURRENT distribution."""
    xb, y, booler = booleanized_tm_dataset(
        SPEC, N_TRAIN, seed=seed, drift=drift, booleanizer=booleanizer
    )
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=60,
        n_features=booler.n_boolean_features,
    )
    state = init_state(cfg, jax.random.key(seed))
    state = fit(cfg, state, jax.random.key(seed + 1), jnp.asarray(xb),
                jnp.asarray(y), epochs=EPOCHS, batch=150)
    return encode(cfg, np.asarray(include_actions(cfg, state))), booler


def main():
    # initial deployment: negotiate the envelope from the first trained
    # model (generous headroom — retrained include streams grow), pin the
    # paper-faithful interp engine, ship the artifact
    model, booler = train_node(drift=0.0, booleanizer=None, seed=0)
    acc = Accelerator.for_models(
        [model], headroom=2.0, batch_words=1, engine="interp"
    )
    acc.load(SLOT, acc.compile(model).to_bytes(), provenance="deploy")
    print(f"engine={acc.engine.name}; negotiated plan {acc.plan.as_dict()}")
    print(f"deployed initial model; slot v{acc.registry.get(SLOT).version}")

    for epoch, drift in enumerate(DRIFTS):
        # edge sensor traffic under current drift — the batcher chunks the
        # datapoints into engine words; no manual 32-row slicing
        xb, y, _ = booleanized_tm_dataset(
            SPEC, N_TRAFFIC, seed=100 + epoch, drift=drift, booleanizer=booler
        )
        score = float((acc.infer(SLOT, xb) == y).mean())
        marker = ""
        if score < RETRAIN_THRESHOLD:
            # the training node retrains on the drifted distribution and
            # hot-swaps the live slot AT RUNTIME (no resynthesis): compile
            # -> bytes -> load, the same path a remote node would use
            model, booler = train_node(drift, booler, seed=200 + epoch)
            blob = acc.compile(model).to_bytes()
            acc.load(SLOT, blob, provenance=f"recal:drift={drift}")
            xb2, y2, _ = booleanized_tm_dataset(
                SPEC, N_TRAFFIC, seed=300 + epoch, drift=drift,
                booleanizer=booler,
            )
            score2 = float((acc.infer(SLOT, xb2) == y2).mean())
            marker = (f" -> RECALIBRATED ({len(blob)}B artifact), "
                      f"acc {score2:.3f}")
        print(f"drift {drift:4.2f}: accuracy {score:.3f}{marker}")

    s = acc.metrics.summary()
    print(
        f"\n{s['swaps'] - 1} runtime reprograms over {s['batches']} engine "
        f"batches ({s['throughput_dps']:.0f} datapoints/s), "
        f"{acc.compile_cache_size()} compiled program(s) total "
        f"(the accelerator was never resynthesized)"
    )
    assert acc.compile_cache_size() == 1


if __name__ == "__main__":
    main()
