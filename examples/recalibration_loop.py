"""The paper's Fig-8 system: on-field recalibration without resynthesis.

An edge accelerator serves inference while the data distribution DRIFTS
(sensor aging / environment change — the paper's Gas Sensor Array Drift
scenario).  A co-located training node (Raspberry-Pi-class; here: the JAX
TM trainer on CPU) monitors accuracy, retrains on fresh data, and
reprograms the accelerator over the stream protocol.  The accelerator is
never recompiled — the model, class count and input dimensionality are all
runtime state.

Run:  PYTHONPATH=src python examples/recalibration_loop.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TMConfig, fit, include_actions, init_state
from repro.core.compress import encode
from repro.core.runtime import (
    Accelerator,
    AcceleratorConfig,
    build_feature_stream,
    build_instruction_stream,
)
from repro.data.pipeline import TM_DATASETS, booleanized_tm_dataset

SPEC = TM_DATASETS["gas"]
RETRAIN_THRESHOLD = 0.70  # accuracy trigger for the training node


def train_node(drift: float, booleanizer, seed: int):
    """The Fig-8 Model Training Node: (re)train on the CURRENT distribution."""
    xb, y, booler = booleanized_tm_dataset(
        SPEC, 1500, seed=seed, drift=drift, booleanizer=booleanizer
    )
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=60,
        n_features=booler.n_boolean_features,
    )
    state = init_state(cfg, jax.random.key(seed))
    state = fit(cfg, state, jax.random.key(seed + 1), jnp.asarray(xb),
                jnp.asarray(y), epochs=8, batch=150)
    return cfg, state, booler


def main():
    engine = Accelerator(AcceleratorConfig(
        instruction_capacity=1 << 15, feature_capacity=1 << 11,
        class_capacity=16, batch_words=1,
    ))

    # initial deployment
    cfg, state, booler = train_node(drift=0.0, booleanizer=None, seed=0)
    engine.feed(build_instruction_stream(
        encode(cfg, np.asarray(include_actions(cfg, state)))
    ))
    print("deployed initial model;", engine.programs_loaded, "programs loaded")

    reprograms = 0
    for epoch, drift in enumerate([0.0, 0.15, 0.3, 0.5, 0.8, 1.2]):
        # edge sensor data under current drift
        xb, y, _ = booleanized_tm_dataset(
            SPEC, 320, seed=100 + epoch, drift=drift, booleanizer=booler
        )
        correct = 0
        for i in range(0, 320, 32):
            preds = engine.feed(build_feature_stream(xb[i : i + 32]))
            correct += int((preds[:32] == y[i : i + 32]).sum())
        acc = correct / 320
        marker = ""
        if acc < RETRAIN_THRESHOLD:
            # the training node retrains on the drifted distribution and
            # reprograms the accelerator AT RUNTIME (no resynthesis)
            cfg, state, booler = train_node(drift, booler, seed=200 + epoch)
            engine.feed(build_instruction_stream(
                encode(cfg, np.asarray(include_actions(cfg, state)))
            ))
            reprograms += 1
            xb2, y2, _ = booleanized_tm_dataset(
                SPEC, 320, seed=300 + epoch, drift=drift, booleanizer=booler
            )
            correct = sum(
                int((engine.feed(build_feature_stream(xb2[i : i + 32]))[:32]
                     == y2[i : i + 32]).sum())
                for i in range(0, 320, 32)
            )
            marker = f" -> RECALIBRATED, acc {correct / 320:.3f}"
        print(f"drift {drift:4.2f}: accuracy {acc:.3f}{marker}")

    print(
        f"\n{reprograms} runtime reprograms, "
        f"{engine.compile_cache_size()} compiled program(s) total "
        f"(the accelerator was never resynthesized)"
    )


if __name__ == "__main__":
    main()
