"""The paper's Fig-8 system: on-field recalibration without resynthesis,
on top of the serving subsystem.

An edge server answers inference traffic while the data distribution
DRIFTS (sensor aging / environment change — the paper's Gas Sensor Array
Drift scenario).  A co-located training node (Raspberry-Pi-class; here:
the JAX TM trainer on CPU) monitors accuracy, retrains on fresh data, and
hot-swaps the model into the live slot via ``TMServer.register`` — the
Fig-8 reprogram step as a first-class API.  The engine is never
recompiled: model, class count and input dimensionality are all runtime
state, and the loop asserts ``compile_cache_size() == 1`` throughout.

Run:  PYTHONPATH=src python examples/recalibration_loop.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import TMConfig, fit, include_actions, init_state
from repro.core.compress import encode
from repro.data.pipeline import TM_DATASETS, booleanized_tm_dataset
from repro.serve_tm import ServeCapacity, TMServer

SPEC = TM_DATASETS["gas"]
RETRAIN_THRESHOLD = 0.90  # accuracy trigger for the training node
SLOT = "edge"


def train_node(drift: float, booleanizer, seed: int):
    """The Fig-8 Model Training Node: (re)train on the CURRENT distribution."""
    xb, y, booler = booleanized_tm_dataset(
        SPEC, 1500, seed=seed, drift=drift, booleanizer=booleanizer
    )
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=60,
        n_features=booler.n_boolean_features,
    )
    state = init_state(cfg, jax.random.key(seed))
    state = fit(cfg, state, jax.random.key(seed + 1), jnp.asarray(xb),
                jnp.asarray(y), epochs=8, batch=150)
    return encode(cfg, np.asarray(include_actions(cfg, state))), booler


def main():
    server = TMServer(ServeCapacity(
        instruction_capacity=1 << 15, feature_capacity=1 << 11,
        class_capacity=16, clause_capacity=64, include_capacity=64,
        batch_words=1,
    ), backend="interp")  # the paper-faithful engine

    # initial deployment
    model, booler = train_node(drift=0.0, booleanizer=None, seed=0)
    server.register(SLOT, model)
    print(f"deployed initial model; slot v{server.registry.get(SLOT).version}")

    for epoch, drift in enumerate([0.0, 0.15, 0.3, 0.5, 0.8, 1.2]):
        # edge sensor traffic under current drift — the batcher chunks the
        # 320 datapoints into engine words; no manual 32-row slicing
        xb, y, _ = booleanized_tm_dataset(
            SPEC, 320, seed=100 + epoch, drift=drift, booleanizer=booler
        )
        acc = float((server.infer(SLOT, xb) == y).mean())
        marker = ""
        if acc < RETRAIN_THRESHOLD:
            # the training node retrains on the drifted distribution and
            # hot-swaps the live slot AT RUNTIME (no resynthesis)
            model, booler = train_node(drift, booler, seed=200 + epoch)
            server.register(SLOT, model)
            xb2, y2, _ = booleanized_tm_dataset(
                SPEC, 320, seed=300 + epoch, drift=drift, booleanizer=booler
            )
            acc2 = float((server.infer(SLOT, xb2) == y2).mean())
            marker = f" -> RECALIBRATED, acc {acc2:.3f}"
        print(f"drift {drift:4.2f}: accuracy {acc:.3f}{marker}")

    s = server.metrics.summary()
    print(
        f"\n{s['swaps'] - 1} runtime reprograms over {s['batches']} engine "
        f"batches ({s['throughput_dps']:.0f} datapoints/s), "
        f"{server.compile_cache_size()} compiled program(s) total "
        f"(the accelerator was never resynthesized)"
    )


if __name__ == "__main__":
    main()
