"""Quickstart: the paper's full pipeline in one script.

  1. booleanize a dataset          (Fig 2, Booleanization)
  2. train a Tsetlin Machine       (the Fig-8 training node)
  3. compress to Include instructions  (Fig 3.4, 16-bit encoding)
  4. program the runtime-tunable accelerator via the stream protocol
  5. run batched compressed inference and verify it matches dense TM
  6. swap in a DIFFERENT task at runtime — zero recompilation
  7. the modern deployment path: negotiate capacity, compile a portable
     TMProgram artifact, ship bytes, load (the repro.accel façade)

Run:  PYTHONPATH=src python examples/quickstart.py
      EXAMPLES_TINY=1 shrinks training for CI smoke runs.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.accel import Accelerator
from repro.core import TMConfig, accuracy, fit, init_state, include_actions
from repro.core.compress import encode
from repro.core.runtime import (
    Accelerator as StreamAccelerator,
    AcceleratorConfig,
    build_feature_stream,
    build_instruction_stream,
)
from repro.data.pipeline import TM_DATASETS, booleanized_tm_dataset

TINY = os.environ.get("EXAMPLES_TINY", "0") == "1"


def train_tm(dataset: str, seed: int = 0):
    spec = TM_DATASETS[dataset]
    n_train = 800 if TINY else 2000
    xb, y, booler = booleanized_tm_dataset(spec, n_train, seed=seed)
    xb_t, y_t, _ = booleanized_tm_dataset(spec, 500, seed=seed + 1,
                                          booleanizer=booler)
    cfg = TMConfig(
        n_classes=spec.n_classes, n_clauses=40,
        n_features=booler.n_boolean_features, threshold=15, specificity=3.9,
    )
    state = init_state(cfg, jax.random.key(seed))
    state = fit(cfg, state, jax.random.key(seed + 1), jnp.asarray(xb),
                jnp.asarray(y), epochs=4 if TINY else 10, batch=200)
    acc = accuracy(cfg, state, jnp.asarray(xb_t), jnp.asarray(y_t))
    return cfg, state, (xb_t, y_t), acc


def main():
    # 1-2: train on EMG (the paper's personalization use case)
    cfg, state, (x_test, y_test), acc = train_tm("emg")
    print(f"[train] EMG dense TM accuracy: {acc:.3f}")

    # 3: compress
    acts = np.asarray(include_actions(cfg, state))
    model = encode(cfg, acts)
    density = acts.mean()
    print(
        f"[compress] {model.n_instructions} instructions "
        f"({model.n_bytes} bytes; include density {100 * density:.1f}%). "
        f"Note: EMG is a tiny model — compression pays off at scale; see "
        f"benchmarks/run.py table1 for the paper's MNIST-scale ratio (~99%)."
    )

    # 4: program the accelerator ("synthesized" once, capacities fixed)
    acc_cfg = AcceleratorConfig(
        instruction_capacity=1 << 14, feature_capacity=1 << 11,
        class_capacity=16, batch_words=1,
    )
    engine = StreamAccelerator(acc_cfg)
    engine.feed(build_instruction_stream(model))

    # 5: batched compressed inference (32 datapoints per word, Fig 4.5)
    n_correct = n_total = 0
    for i in range(0, 480, 32):
        preds = engine.feed(build_feature_stream(x_test[i : i + 32]))
        n_correct += int((preds[:32] == y_test[i : i + 32]).sum())
        n_total += 32
    print(f"[infer] compressed-domain accuracy: {n_correct / n_total:.3f} "
          f"(matches dense: {abs(n_correct / n_total - acc) < 0.02})")

    # 6: runtime task swap — new dataset, new class count, new input dim
    cache0 = engine.compile_cache_size()
    cfg2, state2, (x2, y2), acc2 = train_tm("gesture", seed=3)
    model2 = encode(cfg2, np.asarray(include_actions(cfg2, state2)))
    engine.feed(build_instruction_stream(model2))
    preds = engine.feed(build_feature_stream(x2[:32]))
    swap_acc = float((preds[:32] == y2[:32]).mean())
    print(
        f"[swap] gesture task loaded at runtime: acc {swap_acc:.3f}, "
        f"recompiles: {engine.compile_cache_size() - cache0} (must be 0)"
    )
    assert engine.compile_cache_size() == cache0

    # 7: the repro.accel façade — negotiate the envelope from the model
    # population, compile portable artifacts, ship bytes, load, serve
    accel = Accelerator.for_models([model, model2], headroom=0.25)
    blob = accel.compile(model).to_bytes()
    accel.load("emg", blob, provenance="wire:quickstart")
    accel.load("gesture", accel.compile(model2))
    a_pred = accel.infer("emg", x_test[:64])
    a_acc = float((a_pred == y_test[:64]).mean())
    print(
        f"[accel] engine={accel.engine.name} (auto-selected), plan="
        f"{accel.plan.as_dict()}; artifact {len(blob)}B shipped over the "
        f"wire; emg acc {a_acc:.3f}; compiled program(s): "
        f"{accel.compile_cache_size()}"
    )
    assert accel.compile_cache_size() == 1


if __name__ == "__main__":
    main()
