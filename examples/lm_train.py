"""End-to-end distributed-substrate driver: train a ~100M-class LM for a few
hundred steps on the synthetic pipeline with checkpoint/restart.

This exercises the same launcher the production mesh uses (configs ->
sharding rules -> jitted train step -> checkpoint manager), on the CPU
devices available in this container.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 200]
"""

import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm-125m")
    args = ap.parse_args()

    # xlstm-125m IS ~125M params at full config; on CPU we train it with a
    # short sequence so a few hundred steps complete in minutes.
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "128",
        "--mesh", "1x1",
        "--ckpt", "/tmp/repro_lm_train",
        "--save-every", "50",
        "--log-every", "10",
    ]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    raise SystemExit(subprocess.call(cmd, env=env, cwd=REPO))


if __name__ == "__main__":
    main()
