"""The paper's Fig-8 loop, CLOSED: online recalibration under live traffic.

A ``RecalController`` serves drifting sensor traffic from a ``TMServer``
slot while monitoring it.  When synthetic concept drift (a step change in
the class prototypes — sensor aging) collapses the class-sum margins and
the labelled accuracy window, the controller

  * fine-tunes the model on the buffered drifted traffic
    (``RecalWorker``, incremental fold-in-seeded ``fit_step``s),
  * compresses it and PROVES the stream bit-exact against the dense
    oracle (``Compressor`` publication gate),
  * hot-swaps the live slot through the drain-then-swap path, and
  * validates post-swap accuracy on held-out traffic, rolling back
    automatically if it regressed.

Acceptance (asserted below, for every backend):
  * post-swap accuracy recovers above the pre-drift baseline minus 2%
  * the engine is NEVER recompiled: compile_cache_size() == 1 throughout

Run:  PYTHONPATH=src python examples/online_recal.py \
          [interp|plan|sharded|popcount|all]
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TMConfig
from repro.data.pipeline import TMDatasetSpec, booleanized_tm_dataset
from repro.recal import DriftMonitor, RecalController, RecalWorker
from repro.serve_tm import ServeCapacity, TMServer

# A self-contained edge task: 16 raw sensor channels, 4 classes,
# 4-bit thermometer encoding -> 64 Boolean features.
SPEC = TMDatasetSpec("recal-demo", 16, 4, 4, 40)
DRIFT = 1.0          # step change in the class prototypes
SLOT = "edge"
RECOVERY_MARGIN = 0.02


def train_initial():
    """The pre-deployment model + the booleanizer frozen at deploy time."""
    xb, y, booler = booleanized_tm_dataset(SPEC, 2000, seed=0, drift=0.0)
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=SPEC.n_clauses,
        n_features=booler.n_boolean_features,
    )
    worker = RecalWorker(cfg, key=jax.random.key(42))
    worker.fine_tune_epochs(xb, y, epochs=5, batch=200)
    return cfg, worker.snapshot(), booler


def run_backend(backend, cfg, init_state, booler):
    worker = RecalWorker(
        cfg, state=jnp.asarray(init_state), key=jax.random.key(42)
    )
    server = TMServer(
        ServeCapacity(feature_capacity=128, instruction_capacity=8192),
        backend=backend,
    )
    controller = RecalController(
        server, SLOT, worker,
        monitor=DriftMonitor(
            window=512, min_samples=256,
            accuracy_threshold=0.92, margin_fraction=0.6,
        ),
        buffer_batches=8, train_batch_size=256,
        min_buffer_rows=1792, epochs_per_recal=10,
        regression_margin=RECOVERY_MARGIN,
    )
    controller.deploy()

    # healthy traffic: establishes the pre-drift baseline + margin reference
    xt, yt, _ = booleanized_tm_dataset(
        SPEC, 512, seed=1, drift=0.0, booleanizer=booler
    )
    baseline_acc = float((controller.observe(xt, yt) == yt).mean())
    controller.freeze_baseline()
    print(f"[{backend}] deployed v1, pre-drift baseline acc {baseline_acc:.3f}")

    # drift hits: stream labelled edge traffic through the closed loop
    swapped = False
    for i in range(12):
        xd, yd, _ = booleanized_tm_dataset(
            SPEC, 256, seed=100 + i, drift=DRIFT, booleanizer=booler
        )
        preds, event = controller.serve(xd, yd)
        acc = float((preds == yd).mean())
        line = f"[{backend}] batch {i:2d}: acc {acc:.3f}"
        if event is not None:
            line += (
                f"  -> RECAL v{event.version} ({event.reason}): "
                f"holdout {event.holdout_acc_before:.3f} -> "
                f"{event.holdout_acc_after:.3f}"
                f"{', ROLLED BACK' if event.rolled_back else ''}"
                f" [{event.steps_taken} steps, stream/dense "
                f"{1.0 - event.compression_ratio:.2f}x]"
            )
            swapped = swapped or not event.rolled_back
        print(line)

    # fresh drifted traffic scores the recovered deployment
    xf, yf, _ = booleanized_tm_dataset(
        SPEC, 1024, seed=999, drift=DRIFT, booleanizer=booler
    )
    final_acc = float((controller.observe(xf, yf) == yf).mean())
    cache = server.compile_cache_size()
    s = server.metrics.summary()
    print(
        f"[{backend}] post-swap acc {final_acc:.3f} "
        f"(baseline {baseline_acc:.3f}, floor {baseline_acc - RECOVERY_MARGIN:.3f}); "
        f"{s['recals']} recal(s), {s['rollbacks']} rollback(s), "
        f"{s['swaps']} swap(s), compile cache {cache}"
    )

    assert swapped, f"[{backend}] drift never triggered a recalibration"
    assert final_acc >= baseline_acc - RECOVERY_MARGIN, (
        f"[{backend}] post-swap accuracy {final_acc:.3f} did not recover to "
        f"baseline {baseline_acc:.3f} - {RECOVERY_MARGIN}"
    )
    assert cache == 1, (
        f"[{backend}] engine recompiled: {cache} compiled variants"
    )
    return final_acc


def main():
    choice = sys.argv[1] if len(sys.argv) > 1 else "all"
    backends = (
        ("interp", "plan", "sharded", "popcount")
        if choice == "all" else (choice,)
    )
    cfg, init_state, booler = train_initial()
    finals = {b: run_backend(b, cfg, init_state, booler) for b in backends}
    accs = sorted(set(np.round(list(finals.values()), 6)))
    print(
        f"\nall backends recovered through live hot-swaps "
        f"({', '.join(f'{b}={a:.3f}' for b, a in finals.items())}); "
        f"bit-exact across engines: {len(accs) == 1}"
    )


if __name__ == "__main__":
    main()
