"""The paper's Fig-8 loop, CLOSED: online recalibration under live traffic,
now entirely through the ``repro.accel`` façade.

A ``RecalController`` serves drifting sensor traffic from an
``Accelerator`` slot while monitoring it.  The accelerator's capacity
envelope is NEGOTIATED from the deployed model (plus headroom for the
larger models retraining grows); every publication ships as a stamped,
checksummed ``TMProgram`` artifact.  When synthetic concept drift (a step
change in the class prototypes — sensor aging) collapses the class-sum
margins and the labelled accuracy window, the controller

  * fine-tunes the model on the buffered drifted traffic
    (``RecalWorker``, incremental fold-in-seeded ``fit_step``s),
  * compresses it and PROVES the stream bit-exact against the dense
    oracle AND inside the capacity envelope (``Compressor`` publication
    gate -> ``TMProgram``),
  * hot-swaps the live slot through the drain-then-swap path, and
  * validates post-swap accuracy on held-out traffic, rolling back
    automatically if it regressed.

Acceptance (asserted below, for every engine):
  * post-swap accuracy recovers above the pre-drift baseline minus 2%
  * the engine is NEVER recompiled: compile_cache_size() == 1 throughout

Run:  PYTHONPATH=src python examples/online_recal.py \
          [interp|plan|sharded|popcount|all]
      EXAMPLES_TINY=1 shrinks training/traffic for CI smoke runs.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.accel import Accelerator, CapacityPlan
from repro.core import TMConfig
from repro.data.pipeline import TMDatasetSpec, booleanized_tm_dataset
from repro.recal import DriftMonitor, RecalController, RecalWorker

TINY = os.environ.get("EXAMPLES_TINY", "0") == "1"

# A self-contained edge task: 16 raw sensor channels, 4 classes,
# 4-bit thermometer encoding -> 64 Boolean features.
SPEC = TMDatasetSpec("recal-demo", 16, 4, 4, 40)
DRIFT = 1.0          # step change in the class prototypes
SLOT = "edge"
RECOVERY_MARGIN = 0.02


def train_initial():
    """The pre-deployment model + the booleanizer frozen at deploy time."""
    n = 1200 if TINY else 2000
    xb, y, booler = booleanized_tm_dataset(SPEC, n, seed=0, drift=0.0)
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=SPEC.n_clauses,
        n_features=booler.n_boolean_features,
    )
    worker = RecalWorker(cfg, key=jax.random.key(42))
    worker.fine_tune_epochs(xb, y, epochs=4 if TINY else 5, batch=200)
    return cfg, worker.snapshot(), booler


def negotiate_plan(cfg, init_state):
    """Derive the synthesis-time envelope from the deployed model.

    Headroom covers the larger include streams retraining grows; the
    class/feature dims are pinned by the task, so they only pick up the
    word-quantization slack."""
    from repro.recal.compressor import Compressor

    model = Compressor().compress(cfg, jnp.asarray(init_state)).model
    return CapacityPlan.for_models([model], headroom=3.0, batch_words=4)


def run_engine(engine, plan, cfg, init_state, booler):
    worker = RecalWorker(
        cfg, state=jnp.asarray(init_state), key=jax.random.key(42)
    )
    acc = Accelerator(plan, engine=engine)
    n_serve = 192 if TINY else 256
    controller = RecalController(
        acc, SLOT, worker,
        monitor=DriftMonitor(
            window=384 if TINY else 512, min_samples=192 if TINY else 256,
            accuracy_threshold=0.92, margin_fraction=0.6,
        ),
        buffer_batches=8, train_batch_size=192 if TINY else 256,
        min_buffer_rows=(7 * n_serve) if TINY else 1792,
        epochs_per_recal=10,
        regression_margin=RECOVERY_MARGIN,
    )
    controller.deploy()
    entry = acc.registry.get(SLOT)
    assert entry.artifact is not None, "publications must ship artifacts"

    # healthy traffic: establishes the pre-drift baseline + margin reference
    xt, yt, _ = booleanized_tm_dataset(
        SPEC, 512, seed=1, drift=0.0, booleanizer=booler
    )
    baseline_acc = float((controller.observe(xt, yt) == yt).mean())
    controller.freeze_baseline()
    print(f"[{engine}] deployed v1 "
          f"(artifact {entry.artifact.n_bytes}B, "
          f"checksum {entry.artifact.checksum:#010x}), "
          f"pre-drift baseline acc {baseline_acc:.3f}")

    # drift hits: stream labelled edge traffic through the closed loop
    swapped = False
    for i in range(12):
        xd, yd, _ = booleanized_tm_dataset(
            SPEC, n_serve, seed=100 + i, drift=DRIFT, booleanizer=booler
        )
        preds, event = controller.serve(xd, yd)
        acc_i = float((preds == yd).mean())
        line = f"[{engine}] batch {i:2d}: acc {acc_i:.3f}"
        if event is not None:
            line += (
                f"  -> RECAL v{event.version} ({event.reason}): "
                f"holdout {event.holdout_acc_before:.3f} -> "
                f"{event.holdout_acc_after:.3f}"
                f"{', ROLLED BACK' if event.rolled_back else ''}"
                f" [{event.steps_taken} steps, stream/dense "
                f"{1.0 - event.compression_ratio:.2f}x]"
            )
            swapped = swapped or not event.rolled_back
        print(line)

    # fresh drifted traffic scores the recovered deployment
    xf, yf, _ = booleanized_tm_dataset(
        SPEC, 1024, seed=999, drift=DRIFT, booleanizer=booler
    )
    final_acc = float((controller.observe(xf, yf) == yf).mean())
    cache = acc.compile_cache_size()
    s = acc.metrics.summary()
    live = acc.registry.get(SLOT)
    print(
        f"[{engine}] post-swap acc {final_acc:.3f} "
        f"(baseline {baseline_acc:.3f}, floor {baseline_acc - RECOVERY_MARGIN:.3f}); "
        f"{s['recals']} recal(s), {s['rollbacks']} rollback(s), "
        f"{s['swaps']} swap(s), compile cache {cache}; "
        f"live: v{live.version} ({live.provenance})"
    )

    assert swapped, f"[{engine}] drift never triggered a recalibration"
    assert final_acc >= baseline_acc - RECOVERY_MARGIN, (
        f"[{engine}] post-swap accuracy {final_acc:.3f} did not recover to "
        f"baseline {baseline_acc:.3f} - {RECOVERY_MARGIN}"
    )
    assert cache == 1, (
        f"[{engine}] engine recompiled: {cache} compiled variants"
    )
    assert live.artifact is not None and live.provenance.startswith("recal:")
    return final_acc


def main():
    choice = sys.argv[1] if len(sys.argv) > 1 else "all"
    engines = (
        ("interp", "plan", "sharded", "popcount")
        if choice == "all" else (choice,)
    )
    cfg, init_state, booler = train_initial()
    plan = negotiate_plan(cfg, init_state)
    print(f"negotiated plan: {plan.as_dict()}")
    finals = {
        e: run_engine(e, plan, cfg, init_state, booler) for e in engines
    }
    accs = sorted(set(np.round(list(finals.values()), 6)))
    print(
        f"\nall engines recovered through live hot-swaps "
        f"({', '.join(f'{b}={a:.3f}' for b, a in finals.items())}); "
        f"bit-exact across engines: {len(accs) == 1}"
    )


if __name__ == "__main__":
    main()
