"""Multi-tenant batched TM serving through the ``repro.accel`` façade.

The full deployment lifecycle on one accelerator:

  * the capacity envelope is NEGOTIATED from the model population
    (``Accelerator.for_models`` — no hand-built capacities),
  * models ship as portable ``TMProgram`` artifacts: ``compile`` ->
    ``to_bytes`` (the training node) -> ``load`` (the serving node),
  * two tenants share ONE compiled engine; requests are coalesced into
    32-datapoint bit-packed words per slot and demuxed per request,
  * one tenant is hot-swapped mid-traffic to a model with a different
    class count AND feature count — zero recompilation.

Run:  PYTHONPATH=src python examples/serve_batch.py [--engine auto]
      EXAMPLES_TINY=1 shrinks the traffic for CI smoke runs.
"""

import argparse
import os
import time

import numpy as np

from repro.accel import Accelerator
from repro.core import TMConfig
from repro.core.compress import encode

TINY = os.environ.get("EXAMPLES_TINY", "0") == "1"


def random_model(rng, M, C, F, density=0.03):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    return encode(cfg, rng.random((M, C, 2 * F)) < density)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "interp", "plan", "sharded", "popcount"))
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    vision = random_model(rng, 10, 40, 196)
    sensor_v1 = random_model(rng, 6, 24, 64)
    sensor_v2 = random_model(rng, 9, 32, 112)  # the mid-traffic recal

    # capacity negotiation: the minimal word-quantized envelope covering
    # the whole population (25% headroom for whatever ships next)
    acc = Accelerator.for_models(
        [vision, sensor_v1, sensor_v2], headroom=0.25,
        engine=None if args.engine == "auto" else args.engine,
    )
    print(f"engine={acc.engine.name} (auto-selected: {args.engine == 'auto'})")
    print(f"negotiated plan: {acc.plan.as_dict()}")

    # the train node compiles portable artifacts; serving loads BYTES
    blob = acc.compile(vision).to_bytes()
    print(f"vision artifact: {len(blob)} bytes "
          f"(checksummed, capacity-stamped)")
    acc.load("vision", blob, provenance="wire:train-node")
    acc.load("sensor", acc.compile(sensor_v1))

    n_requests = 16 if TINY else 64
    t0 = time.time()
    handles = []
    for i in range(n_requests):  # interleaved traffic, ragged request sizes
        slot, f = (("vision", 196), ("sensor", 64))[i % 2]
        x = rng.integers(0, 2, (int(rng.integers(1, 20)), f)).astype(np.uint8)
        handles.append(acc.submit(slot, x))
    acc.flush()
    assert all(h.done for h in handles)

    # hot-swap "sensor" mid-traffic: different class AND feature count
    for _ in range(2 if TINY else 6):
        acc.submit("sensor", rng.integers(0, 2, (8, 64)).astype(np.uint8))
    acc.load("sensor", acc.compile(sensor_v2).to_bytes(),
             provenance="recal:drift")  # queued traffic drains first
    for _ in range(4 if TINY else 16):
        acc.submit("sensor", rng.integers(0, 2, (8, 112)).astype(np.uint8))
    acc.flush()
    wall = time.time() - t0

    entry = acc.registry.get("sensor")
    print(f"sensor slot: v{entry.version} ({entry.provenance}), artifact "
          f"checksum {entry.artifact.checksum:#010x}")
    s = acc.metrics.summary()
    print(f"wall={wall:.2f}s  batches={s['batches']}  rows={s['rows']}  "
          f"requests={s['requests_completed']}  swaps={s['swaps']}")
    print(f"throughput={s['throughput_dps']:.0f} datapoints/s  "
          f"fill={s['fill_ratio']:.2f}  "
          f"engine p50={s['engine_us']['p50']:.0f}us")
    print(f"compiled program(s): {acc.compile_cache_size()} "
          f"(hot swaps never resynthesize)")
    assert acc.compile_cache_size() == 1


if __name__ == "__main__":
    main()
