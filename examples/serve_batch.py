"""Multi-tenant batched TM serving with hot-swap under traffic.

Two tenants share ONE compiled engine (the paper's runtime-tunability
claim, multi-tenant): requests are coalesced into 32-datapoint bit-packed
words per slot, predictions demuxed back per request, and one tenant is
recalibrated mid-traffic to a model with a different class count AND
feature count — with zero recompilation.

Run:  PYTHONPATH=src python examples/serve_batch.py [--backend plan]
"""

import argparse
import time

import numpy as np

from repro.core import TMConfig
from repro.core.compress import encode
from repro.serve_tm import ServeCapacity, TMServer


def random_model(rng, M, C, F, density=0.03):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    return encode(cfg, rng.random((M, C, 2 * F)) < density)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="plan",
                    choices=("interp", "plan", "sharded"))
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    server = TMServer(ServeCapacity(
        instruction_capacity=8192, feature_capacity=256, class_capacity=16,
        clause_capacity=64, include_capacity=32, batch_words=4,
    ), backend=args.backend)

    # two tenants, one engine
    server.register("vision", random_model(rng, 10, 40, 196))
    server.register("sensor", random_model(rng, 6, 24, 64))

    t0 = time.time()
    handles = []
    for i in range(64):  # interleaved traffic, ragged request sizes
        slot, f = (("vision", 196), ("sensor", 64))[i % 2]
        x = rng.integers(0, 2, (int(rng.integers(1, 20)), f)).astype(np.uint8)
        handles.append(server.submit(slot, x))
    server.flush()
    assert all(h.done for h in handles)

    # hot-swap "sensor" mid-traffic: different class AND feature count
    for _ in range(6):
        server.submit("sensor", rng.integers(0, 2, (8, 64)).astype(np.uint8))
    server.register("sensor", random_model(rng, 9, 32, 112))  # drains first
    for _ in range(16):
        server.submit("sensor", rng.integers(0, 2, (8, 112)).astype(np.uint8))
    server.flush()
    wall = time.time() - t0

    s = server.metrics.summary()
    print(f"backend={args.backend}  wall={wall:.2f}s")
    print(f"batches={s['batches']}  rows={s['rows']}  "
          f"requests={s['requests_completed']}  swaps={s['swaps']}")
    print(f"throughput={s['throughput_dps']:.0f} datapoints/s  "
          f"fill={s['fill_ratio']:.2f}  "
          f"engine p50={s['engine_us']['p50']:.0f}us")
    print(f"compiled program(s): {server.compile_cache_size()} "
          f"(hot swaps never resynthesize)")


if __name__ == "__main__":
    main()
