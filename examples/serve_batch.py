"""Batched LM serving with runtime weight swap (no re-jit) — the paper's
tunability discipline applied to the LM serving substrate.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import get
from repro.launch.serve import Server
from repro.models.api import family_for


def main():
    cfg = get("stablelm-3b-smoke")
    fam = family_for(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, batch=4, prompt_cap=32)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32)

    # model A
    server.load_weights(fam.init_params(cfg, jax.random.key(0)))
    t0 = time.time()
    out_a = server.generate(prompts, 16)
    t_a = time.time() - t0

    # runtime weight swap: same compiled program, new model (e.g. the
    # recalibrated checkpoint from the training node)
    server.load_weights(fam.init_params(cfg, jax.random.key(42)))
    t0 = time.time()
    out_b = server.generate(prompts, 16)
    t_b = time.time() - t0

    swapped = not np.array_equal(out_a, out_b)
    print(f"model A: {out_a.shape} in {t_a:.2f}s; model B in {t_b:.2f}s "
          f"(includes no recompile; outputs differ: {swapped})")
    print("first tokens A:", out_a[0, :8])
    print("first tokens B:", out_b[0, :8])


if __name__ == "__main__":
    main()
