"""Property tests (hypothesis) for the include-instruction compression:
the system's central invariant is that every execution strategy over the
compressed stream reproduces dense TM inference EXACTLY."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TMConfig, batch_class_sums
from repro.core.compress import decode, decode_to_plan, encode
from repro.core.interp import (
    interpret_stream,
    pack_features,
    pad_plan,
    plan_class_sums,
)


def _state_of(cfg, acts):
    return jnp.where(jnp.asarray(acts), cfg.n_states + 1, cfg.n_states)


@st.composite
def tm_case(draw):
    M = draw(st.integers(2, 6))
    C = draw(st.integers(1, 10)) * 2
    F = draw(st.integers(2, 40))
    density = draw(st.floats(0.0, 0.15))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    acts = rng.random((M, C, 2 * F)) < density
    X = rng.integers(0, 2, (32, F)).astype(np.uint8)
    return TMConfig(n_classes=M, n_clauses=C, n_features=F), acts, X


@settings(max_examples=40, deadline=None)
@given(tm_case())
def test_roundtrip_preserves_inference(case):
    cfg, acts, X = case
    acts2 = decode(encode(cfg, acts))
    s1 = batch_class_sums(cfg, _state_of(cfg, acts), jnp.asarray(X))
    s2 = batch_class_sums(cfg, _state_of(cfg, acts2), jnp.asarray(X))
    assert jnp.array_equal(s1, s2)


@settings(max_examples=25, deadline=None)
@given(tm_case())
def test_interpreter_matches_dense(case):
    cfg, acts, X = case
    cm = encode(cfg, acts)
    dense = np.asarray(batch_class_sums(cfg, _state_of(cfg, acts), jnp.asarray(X)))
    i_cap = 1 << int(np.ceil(np.log2(max(cm.n_instructions, 2))))
    imem = np.zeros(i_cap, np.uint16)
    imem[: cm.n_instructions] = cm.instructions
    f_cap = 1 << int(np.ceil(np.log2(max(cfg.n_features, 2))))
    packed = pack_features(jnp.asarray(X), f_cap, 1)
    sums = np.asarray(
        interpret_stream(
            jnp.asarray(imem), jnp.int32(cm.n_instructions), packed,
            jnp.int32(32), m_cap=8,
        )
    )
    assert (sums[: cfg.n_classes, :32].T == dense).all()


@settings(max_examples=25, deadline=None)
@given(tm_case())
def test_decoded_plan_matches_dense(case):
    cfg, acts, X = case
    plan = decode_to_plan(encode(cfg, acts))
    dense = np.asarray(batch_class_sums(cfg, _state_of(cfg, acts), jnp.asarray(X)))
    i_cap = max(64, 1 << int(np.ceil(np.log2(max(plan.n_includes, 2)))))
    ncl_cap = max(16, cfg.n_classes * cfg.n_clauses)
    li, ci, cc, cp = pad_plan(plan, i_cap, ncl_cap)
    lits = np.asarray(
        jax.vmap(lambda r: jnp.stack([r, ~r], -1).reshape(-1))(
            jnp.asarray(X, bool)
        )
    )
    sums = np.asarray(
        plan_class_sums(
            jnp.asarray(li), jnp.asarray(ci), jnp.asarray(cc), jnp.asarray(cp),
            jnp.asarray(lits), n_clause_cap=ncl_cap, m_cap=8,
        )
    )
    assert (sums[:, : cfg.n_classes] == dense).all()


def test_compression_ratio_on_sparse_model():
    """Paper claims ~99% compression at ~1% include density (MNIST-scale)."""
    rng = np.random.default_rng(0)
    cfg = TMConfig(n_classes=10, n_clauses=200, n_features=784)
    acts = rng.random((10, 200, 1568)) < 0.006
    cm = encode(cfg, acts)
    assert cm.compression_ratio(cfg) > 0.85
    assert cm.n_instructions < 0.02 * cfg.n_tas


def test_wide_features_use_extend_escapes():
    rng = np.random.default_rng(1)
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=3000)
    acts = np.zeros((2, 4, 6000), bool)
    acts[0, 0, 5990] = True
    acts[1, 2, 12] = True
    acts[1, 2, 5500] = True
    cm = encode(cfg, acts)
    X = rng.integers(0, 2, (32, 3000)).astype(np.uint8)
    dense = np.asarray(batch_class_sums(cfg, _state_of(cfg, acts), jnp.asarray(X)))
    imem = np.zeros(64, np.uint16)
    imem[: cm.n_instructions] = cm.instructions
    packed = pack_features(jnp.asarray(X), 4096, 1)
    sums = np.asarray(
        interpret_stream(jnp.asarray(imem), jnp.int32(cm.n_instructions),
                         packed, jnp.int32(32), m_cap=4)
    )
    assert (sums[:2, :32].T == dense).all()


def test_empty_class_alignment():
    rng = np.random.default_rng(2)
    cfg = TMConfig(n_classes=5, n_clauses=6, n_features=10)
    acts = rng.random((5, 6, 20)) < 0.2
    acts[1] = False  # empty class in the middle
    acts[4] = False  # empty final class
    cm = encode(cfg, acts)
    X = rng.integers(0, 2, (32, 10)).astype(np.uint8)
    dense = np.asarray(batch_class_sums(cfg, _state_of(cfg, acts), jnp.asarray(X)))
    imem = np.zeros(256, np.uint16)
    imem[: cm.n_instructions] = cm.instructions
    packed = pack_features(jnp.asarray(X), 16, 1)
    sums = np.asarray(
        interpret_stream(jnp.asarray(imem), jnp.int32(cm.n_instructions),
                         packed, jnp.int32(32), m_cap=8)
    )
    assert (sums[:5, :32].T == dense).all()
