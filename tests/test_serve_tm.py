"""Multi-tenant batched serving subsystem: bit-exactness vs the dense
oracle, hot swap under traffic with zero recompilation, batching/demux,
capacity guards and metrics."""

import asyncio
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.serve_tm import (
    Batcher,
    DeadlineExceeded,
    Overloaded,
    PRIORITIES,
    RequestHandle,
    ServeCapacity,
    TMServer,
)

BACKENDS = ("interp", "plan", "sharded", "popcount")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CAP = ServeCapacity(
    instruction_capacity=1024, feature_capacity=128, class_capacity=16,
    clause_capacity=32, include_capacity=24, batch_words=2,
)


def _random_model(rng, M, C, F, density=0.05):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_sums(cfg, acts, X):
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_class_sums_bit_exact(backend):
    rng = np.random.default_rng(0)
    cfg, acts, model = _random_model(rng, 5, 12, 40)
    server = TMServer(CAP, backend=backend)
    server.register("m", model)
    X = rng.integers(0, 2, (50, 40)).astype(np.uint8)
    assert (server.class_sums("m", X) == _oracle_sums(cfg, acts, X)).all()
    assert (
        server.infer("m", X) == _oracle_sums(cfg, acts, X).argmax(1)
    ).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_hot_swap_under_traffic_zero_recompile(backend):
    """The acceptance criterion: swaps change class count AND feature
    count mid-traffic; queued requests drain under the model they were
    submitted against; the engine never recompiles."""
    rng = np.random.default_rng(1)
    cases = [(5, 12, 40), (3, 8, 24), (7, 10, 56)]
    server = TMServer(CAP, backend=backend)
    checks = []  # (handle, expected)
    for i, (M, C, F) in enumerate(cases):
        cfg, acts, model = _random_model(rng, M, C, F)
        server.register("slot", model)  # drains any queued old-F traffic
        for rows in (7, CAP.batch_capacity + 5, 1):
            x = rng.integers(0, 2, (rows, F)).astype(np.uint8)
            checks.append(
                (server.submit("slot", x),
                 _oracle_sums(cfg, acts, x).argmax(1))
            )
        if i == len(cases) - 1:
            server.flush()
    for handle, expected in checks:
        assert handle.done
        assert (handle.result() == expected).all()
    assert server.compile_cache_size() == 1
    assert server.metrics.swaps == len(cases)


@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_tenant_demux(backend):
    rng = np.random.default_rng(2)
    cfg_a, acts_a, model_a = _random_model(rng, 4, 10, 32)
    cfg_b, acts_b, model_b = _random_model(rng, 6, 8, 48)
    server = TMServer(CAP, backend=backend)
    server.register("a", model_a)
    server.register("b", model_b)
    checks = []
    for i in range(12):  # interleave tenants, varied request sizes
        slot, cfg, acts = (("a", cfg_a, acts_a), ("b", cfg_b, acts_b))[i % 2]
        x = rng.integers(0, 2, (1 + i, cfg.n_features)).astype(np.uint8)
        checks.append(
            (server.submit(slot, x), _oracle_sums(cfg, acts, x).argmax(1))
        )
    server.flush()
    for handle, expected in checks:
        assert (handle.result() == expected).all()
    assert server.compile_cache_size() == 1


def test_request_spans_batches():
    rng = np.random.default_rng(3)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, backend="plan")
    server.register("m", model)
    rows = 2 * CAP.batch_capacity + 3  # forces 3 engine batches
    x = rng.integers(0, 2, (rows, 32)).astype(np.uint8)
    preds = server.infer("m", x)
    assert (preds == _oracle_sums(cfg, acts, x).argmax(1)).all()
    assert server.metrics.batches == 3


def test_partial_word_padding():
    """B == 1 and B == 33 exercise partial 32-datapoint-word padding."""
    rng = np.random.default_rng(4)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, backend="interp")
    server.register("m", model)
    for rows in (1, 33):
        x = rng.integers(0, 2, (rows, 32)).astype(np.uint8)
        assert (
            server.infer("m", x) == _oracle_sums(cfg, acts, x).argmax(1)
        ).all()
    # 1-D convenience submit
    x1 = rng.integers(0, 2, 32).astype(np.uint8)
    assert server.infer("m", x1).shape == (1,)


@pytest.mark.parametrize("backend", BACKENDS)
def test_capacity_guards(backend):
    rng = np.random.default_rng(5)
    server = TMServer(CAP, backend=backend)
    _, _, too_many_classes = _random_model(rng, 20, 4, 16)
    with pytest.raises(ValueError, match="class_capacity"):
        server.register("m", too_many_classes)
    _, _, too_many_features = _random_model(rng, 2, 4, 300)
    with pytest.raises(ValueError, match="capacity"):
        server.register("m", too_many_features)


def test_unknown_slot_wrong_features_and_pending_result():
    rng = np.random.default_rng(6)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, backend="plan")
    with pytest.raises(KeyError, match="no model registered"):
        server.submit("ghost", np.zeros((1, 32), np.uint8))
    server.register("m", model)
    with pytest.raises(ValueError, match="features"):
        server.submit("m", np.zeros((1, 16), np.uint8))
    with pytest.raises(ValueError, match="Boolean"):
        server.submit("m", np.full((1, 32), 2, np.uint8))
    h = server.submit("m", np.zeros((4, 32), np.uint8))
    with pytest.raises(RuntimeError, match="flush"):
        h.result()
    server.flush()
    assert h.result().shape == (4,)


def test_metrics_summary():
    rng = np.random.default_rng(7)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, backend="plan")
    server.register("m", model)
    for _ in range(5):
        server.submit("m", rng.integers(0, 2, (10, 32)).astype(np.uint8))
    server.flush()
    s = server.metrics.summary()
    assert s["rows"] == 50 and s["requests_completed"] == 5
    assert s["swaps"] == 1 and 0 < s["fill_ratio"] <= 1
    assert s["throughput_dps"] > 0
    assert {"p50", "p95", "p99"} <= set(s["engine_us"])
    assert s["request_latency_us"]["p50"] > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_private_jit_cache_per_executor(backend):
    """Two live engines of the SAME backend must count compilations
    independently (the compile_cache_size()==1 contract is per instance
    — this is what _private_jit guarantees, now including sharded)."""
    rng = np.random.default_rng(8)
    servers = [TMServer(CAP, backend=backend) for _ in range(2)]
    for i, server in enumerate(servers):
        cfg, acts, model = _random_model(rng, 3 + i, 8, 24 + 8 * i)
        server.register("m", model)
        x = rng.integers(0, 2, (9, cfg.n_features)).astype(np.uint8)
        assert (
            server.infer("m", x) == _oracle_sums(cfg, acts, x).argmax(1)
        ).all()
    for server in servers:
        assert server.compile_cache_size() == 1
    assert servers[0].executor._fn is not servers[1].executor._fn


def test_staging_buffer_is_reused_across_flushes():
    """The flush path packs requests straight into the engine's
    preallocated staging array — no per-batch feature allocation."""
    rng = np.random.default_rng(9)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, backend="popcount")
    server.register("m", model)
    staging = server.executor.staging
    assert staging.shape == (CAP.batch_capacity, CAP.feature_capacity)
    for _ in range(3):
        x = rng.integers(0, 2, (11, 32)).astype(np.uint8)
        assert (
            server.infer("m", x) == _oracle_sums(cfg, acts, x).argmax(1)
        ).all()
        # same preallocated buffer, zero-padded beyond the request rows
        assert server.executor.staging is staging
        assert (staging[11:] == 0).all() and (staging[:11, 32:] == 0).all()
    # an OFFSET view of the staging buffer must not be mistaken for a
    # fully-staged batch (it gets detached and restaged, not aliased)
    staging[:20, :32] = rng.integers(0, 2, (20, 32), dtype=np.uint8)
    view = staging[5:16, :32]
    expected = _oracle_sums(cfg, acts, view.copy())
    assert (server.executor.class_sums(
        server.registry.get("m").program, view) == expected).all()


def test_batcher_packs_into_staging_view():
    b = Batcher(64)
    h = RequestHandle(0, "s", 10)
    b.enqueue(h, np.ones((10, 4), np.uint8))
    out = np.full((64, 8), 7, np.uint8)  # stale garbage must be cleared
    X, spans = b.next_batch("s", out=out)
    assert X.shape == (10, 4) and np.shares_memory(X, out)
    assert (out[:10, :4] == 1).all() and (out[10:] == 0).all()
    assert (out[:10, 4:] == 0).all()
    b.enqueue(RequestHandle(1, "s", 2), np.ones((2, 4), np.uint8))
    with pytest.raises(ValueError, match="too small"):
        b.next_batch("s", out=np.zeros((8, 4), np.uint8))


def test_batcher_coalesces_and_splits():
    b = Batcher(64)
    h1, h2, h3 = (RequestHandle(i, "s", n) for i, n in ((0, 40), (1, 40), (2, 5)))
    b.enqueue(h1, np.zeros((40, 4), np.uint8))
    b.enqueue(h2, np.ones((40, 4), np.uint8))
    b.enqueue(h3, np.zeros((5, 4), np.uint8))
    X, spans = b.next_batch("s")
    assert X.shape[0] == 64  # h1 whole + h2 head
    assert [(s[1], s[2], s[3]) for s in spans] == [(0, 40, 0), (40, 64, 0)]
    X2, spans2 = b.next_batch("s")
    assert X2.shape[0] == 21  # h2 tail + h3
    assert spans2[0][3] == 24  # resumes at row 24 of h2
    assert b.pending_rows("s") == 0
    with pytest.raises(ValueError, match="no pending"):
        b.next_batch("s")
    with pytest.raises(ValueError, match="multiple"):
        Batcher(33)


# ---------------------------------------------------------------------------
# the scheduler-owned continuous-batching runtime (priority lanes, EDF,
# deadlines, admission control, the async front door)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_scheduler_async_path_bit_exact(backend):
    """All four engines stay bit-exact when traffic rides the async front
    door (async_submit -> loop-formed batches -> async_result), with the
    no-recompile invariant held per scheduler-formed batch."""
    rng = np.random.default_rng(7)
    cfg, acts, model = _random_model(rng, 5, 12, 40)
    server = TMServer(CAP, backend=backend, max_wait_ms=0.5)
    server.register("m", model)
    server.start()
    try:
        async def drive():
            handles, blocks = [], []
            for i, pr in enumerate(PRIORITIES * 2):
                x = rng.integers(0, 2, (3 + i, 40)).astype(np.uint8)
                h = await server.async_submit("m", x, priority=pr)
                handles.append(h)
                blocks.append(x)
            return [
                (await h.async_result(timeout=30.0), x)
                for h, x in zip(handles, blocks)
            ]

        for preds, x in asyncio.run(drive()):
            assert (preds == _oracle_sums(cfg, acts, x).argmax(1)).all()
        assert server.compile_cache_size() == 1
        lanes = server.metrics.summary()["lanes"]
        assert all(lanes[p]["completed"] == 2 for p in PRIORITIES)
        assert all(lanes[p]["shed"] == 0 for p in PRIORITIES)
    finally:
        server.stop()


@pytest.mark.parametrize("backend", ("plan", "popcount"))
def test_live_scheduler_hot_swap_and_rollback_drain(backend):
    """Hot-swap (register) and rollback land while the scheduler loop is
    live with a queued backlog: the backlog completes under the OLD
    program (the lock is held across drain + install), and the engine
    never recompiles across either transition."""
    rng = np.random.default_rng(8)
    cfg_a, acts_a, model_a = _random_model(rng, 5, 12, 40)
    cfg_b, acts_b, model_b = _random_model(rng, 3, 8, 24)
    server = TMServer(CAP, backend=backend, max_wait_ms=0.2)
    server.register("slot", model_a)
    server.start()
    try:
        # stall the loop on the scheduler lock so a multi-batch backlog
        # builds, then swap: register must drain it under model A first
        with server.scheduler.lock:
            xs = [
                rng.integers(0, 2, (CAP.batch_capacity + 3, 40)).astype(
                    np.uint8
                )
                for _ in range(2)
            ]
            handles = [server.submit("slot", x) for x in xs]
            server.register("slot", model_b)
        for h, x in zip(handles, xs):
            assert (
                h.wait(timeout=30.0)
                == _oracle_sums(cfg_a, acts_a, x).argmax(1)
            ).all()
        # same discipline for rollback: queued model-B traffic finishes
        # under B, then A's buffers come back
        with server.scheduler.lock:
            xb = rng.integers(0, 2, (CAP.batch_capacity + 1, 24)).astype(
                np.uint8
            )
            hb = server.submit("slot", xb)
            server.rollback("slot")
        assert (
            hb.wait(timeout=30.0) == _oracle_sums(cfg_b, acts_b, xb).argmax(1)
        ).all()
        # post-rollback the loop serves under model A again, no flush()
        xa = rng.integers(0, 2, (9, 40)).astype(np.uint8)
        ha = server.submit("slot", xa)
        assert (
            ha.wait(timeout=30.0) == _oracle_sums(cfg_a, acts_a, xa).argmax(1)
        ).all()
        assert server.compile_cache_size() == 1
    finally:
        server.stop()


def test_batch_formation_property_priority_and_expiry():
    """Property: scheduler batch formation never violates strict priority
    order within a batch and never includes an expired request; every
    past-deadline request ends shed, everything else completes."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.serve_tm.batching import PRIORITY_RANK

    reqs = st.lists(
        st.tuples(
            st.integers(0, 3),                      # priority index
            st.integers(1, 12),                     # rows
            st.sampled_from(("past", "soon", "none")),
        ),
        min_size=1,
        max_size=24,
    )

    @given(reqs)
    @settings(max_examples=60, deadline=None)
    def check(spec):
        now = 1_000.0  # synthetic clock injected into next_batch
        b = Batcher(64)
        handles = []
        for i, (pi, rows, dl) in enumerate(spec):
            deadline = {"past": now - 1.0, "soon": now + 60.0, "none": None}[dl]
            h = RequestHandle(
                i, "s", rows, priority=PRIORITIES[pi], deadline=deadline
            )
            b.enqueue(h, np.zeros((rows, 8), np.uint8))
            handles.append((h, dl))
        while b.pending_rows("s"):
            X, spans = b.next_batch("s", now=now)
            ranks = [PRIORITY_RANK[h.priority] for h, _, _, _ in spans]
            assert ranks == sorted(ranks)
            for h, lo, hi, _ in spans:
                assert not h.expired
                assert h.deadline is None or h.deadline > now
            assert X.shape[0] == sum(hi - lo for _, lo, hi, _ in spans)
        for h, dl in handles:
            assert h.status == ("expired" if dl == "past" else "done")

    check()


def test_async_submit_admission_control_overload():
    """Admission control: the low lane rejects once its queue-depth
    budget fills, with the structured Overloaded fields; critical keeps
    admitting under the exact same backlog."""
    rng = np.random.default_rng(9)
    cfg, acts, model = _random_model(rng, 4, 8, 32)
    server = TMServer(
        CAP,
        backend="plan",
        lane_depth_rows={"low": CAP.batch_capacity},
    )
    server.register("m", model)
    x_full = rng.integers(0, 2, (CAP.batch_capacity, 32)).astype(np.uint8)
    x_one = rng.integers(0, 2, (1, 32)).astype(np.uint8)

    async def drive():
        await server.async_submit("m", x_full, priority="low")
        with pytest.raises(Overloaded) as ei:
            await server.async_submit("m", x_one, priority="low")
        err = ei.value
        assert (err.slot, err.priority) == ("m", "low")
        assert err.pending_rows == CAP.batch_capacity
        assert err.limit_rows == CAP.batch_capacity
        # critical still has headroom under the same backlog
        return await server.async_submit("m", x_one, priority="critical")

    h = asyncio.run(drive())
    server.flush()
    assert (h.result() == _oracle_sums(cfg, acts, x_one).argmax(1)).all()
    s = server.metrics.summary()
    assert s["admission_rejects"] == 1
    assert s["lanes"]["low"]["rejected"] == 1
    assert s["lanes"]["critical"]["rejected"] == 0
    with pytest.raises(KeyError):
        asyncio.run(server.async_submit("nope", x_one))


def test_concurrent_submits_race_live_loop_no_drops():
    """Submit-side heap pushes run on caller threads while the loop
    thread forms batches; without the batcher lock heapq's peek-then-pop
    can pop a freshly-pushed earlier-deadline entry and silently drop it
    (its handle never reaches a terminal state).  Hammer a live loop
    from several threads with interleaved deadline/deadline-less
    requests so lane-heap roots keep re-ordering: every handle must
    complete bit-exactly and every rid must be unique."""
    rng = np.random.default_rng(11)
    cfg, acts, model = _random_model(rng, 4, 8, 32)
    server = TMServer(CAP, backend="plan", max_wait_ms=0.2)
    server.register("m", model)
    server.start()
    results = []
    mu = threading.Lock()
    n_threads = 4
    start = threading.Barrier(n_threads)

    def hammer(seed):
        trng = np.random.default_rng(seed)
        start.wait()
        for i in range(25):
            x = trng.integers(0, 2, (1 + i % 3, 32)).astype(np.uint8)
            # far-future deadlines interleaved with deadline-less so
            # every push contends for the heap root mid-formation
            tmo = None if i % 2 else 30_000.0
            h = server.submit(
                "m", x, priority=PRIORITIES[i % 4], timeout_ms=tmo
            )
            with mu:
                results.append((h, x))

    threads = [
        threading.Thread(target=hammer, args=(100 + t,))
        for t in range(n_threads)
    ]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for h, x in results:
            assert (
                h.wait(timeout=30.0) == _oracle_sums(cfg, acts, x).argmax(1)
            ).all()
        assert server.compile_cache_size() == 1
    finally:
        server.stop()
    rids = [h.rid for h, _ in results]
    assert len(set(rids)) == len(rids)
    lanes = server.metrics.summary()["lanes"]
    assert sum(lanes[p]["shed"] for p in PRIORITIES) == 0


def test_scheduler_loop_survives_batch_exception():
    """One failing loop iteration must not kill the tm-scheduler daemon
    thread (a dead loop strands every pending request): the error is
    logged, the loop keeps running, and the next iteration serves the
    queue."""
    rng = np.random.default_rng(13)
    cfg, acts, model = _random_model(rng, 4, 8, 32)
    server = TMServer(CAP, backend="plan", max_wait_ms=0.2)
    server.register("m", model)
    real = server.scheduler.run_slot_batch
    calls = {"n": 0}

    def flaky(slot):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected batch failure")
        return real(slot)

    server.scheduler.run_slot_batch = flaky
    try:
        server.start()
        x = rng.integers(0, 2, (5, 32)).astype(np.uint8)
        h = server.submit("m", x)
        assert (
            h.wait(timeout=30.0) == _oracle_sums(cfg, acts, x).argmax(1)
        ).all()
        assert server.scheduler.running
        assert calls["n"] >= 2
    finally:
        server.scheduler.run_slot_batch = real
        server.stop()


def test_admission_and_enqueue_atomic_under_contention():
    """The depth check and the enqueue are one atomic section: N racing
    async submitters cannot all pass the same check and collectively
    exceed the lane budget.  With no scheduler draining, exactly
    budget/rows_each submits are admitted, the rest get Overloaded."""
    rng = np.random.default_rng(12)
    _, _, model = _random_model(rng, 4, 8, 32)
    limit = CAP.batch_capacity
    server = TMServer(CAP, backend="plan", lane_depth_rows={"low": limit})
    server.register("m", model)
    rows_each = limit // 4
    n_threads = 8  # 2x oversubscribed: exactly half must be rejected
    start = threading.Barrier(n_threads)
    outcomes = []
    mu = threading.Lock()

    def submitter(seed):
        x = np.random.default_rng(seed).integers(
            0, 2, (rows_each, 32)
        ).astype(np.uint8)
        start.wait()
        try:
            asyncio.run(server.async_submit("m", x, priority="low"))
            ok = True
        except Overloaded:
            ok = False
        with mu:
            outcomes.append(ok)

    threads = [
        threading.Thread(target=submitter, args=(200 + t,))
        for t in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    admitted = sum(outcomes)
    assert admitted == limit // rows_each
    assert server.batcher.pending_rows("m", "low") == limit
    assert server.metrics.summary()["lanes"]["low"]["rejected"] == (
        n_threads - admitted
    )
    server.flush()  # don't strand the admitted backlog


def test_deadline_shed_and_expired_terminal_state():
    """A request whose deadline passes before service is shed, lands in
    the expired terminal state, and raises DeadlineExceeded from both
    result() and wait(); the lane accounting separates it from the
    in-SLO completion sharing its lane."""
    rng = np.random.default_rng(10)
    cfg, acts, model = _random_model(rng, 4, 8, 32)
    server = TMServer(CAP, backend="plan")
    server.register("m", model)
    x = rng.integers(0, 2, (6, 32)).astype(np.uint8)
    h_ok = server.submit("m", x)
    h_dead = server.submit("m", x, timeout_ms=0.0)
    server.flush()
    assert (h_ok.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()
    assert h_dead.status == "expired" and h_dead.expired
    with pytest.raises(DeadlineExceeded) as ei:
        h_dead.result()
    assert (ei.value.rid, ei.value.slot) == (h_dead.rid, "m")
    assert ei.value.priority == "normal"
    with pytest.raises(DeadlineExceeded):
        h_dead.wait(timeout=5.0)
    s = server.metrics.summary()
    assert s["sheds"] == 1
    assert s["lanes"]["normal"]["shed"] == 1
    assert s["lanes"]["normal"]["completed"] == 1
    assert s["lanes"]["normal"]["slo_attainment"] == 0.5


def test_pending_result_error_names_driver_and_slot():
    """Satellite regression: the pending-result error names whichever
    driver owns the request (sync flush vs scheduler loop) and the slot."""
    rng = np.random.default_rng(11)
    _, _, model = _random_model(rng, 4, 8, 32)
    server = TMServer(CAP, backend="plan")
    server.register("m", model)
    h = server.submit("m", rng.integers(0, 2, (4, 32)).astype(np.uint8))
    with pytest.raises(RuntimeError, match=r"slot 'm'.*TMServer\.flush\(\)"):
        h.result()
    server.flush()
    h2 = RequestHandle(99, "edge", 4)
    h2.driver = "scheduler"
    with pytest.raises(RuntimeError, match=r"slot 'edge'.*async_result\(\)"):
        h2.result()


def test_scheduler_lifecycle_idempotent_and_stop_drains():
    rng = np.random.default_rng(12)
    cfg, acts, model = _random_model(rng, 4, 8, 32)
    server = TMServer(CAP, backend="plan", max_wait_ms=0.2)
    server.register("m", model)
    server.start()
    server.start()  # idempotent
    assert server.scheduler_running
    with server.scheduler.lock:  # enqueue while the loop can't serve
        x = rng.integers(0, 2, (5, 32)).astype(np.uint8)
        h = server.submit("m", x)
    server.stop()  # drain=True: nothing admitted is stranded
    assert not server.scheduler_running
    assert (h.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()
    # sync submit after stop reverts to the flush driver
    h2 = server.submit("m", x)
    assert h2.driver == "flush"
    server.flush()
    assert h2.done


def test_executors_shim_deprecation_fires_once():
    """Satellite 1: importing the legacy executors shim (or calling
    make_executor) emits a real DeprecationWarning exactly once per
    process, while importing repro.serve_tm itself stays silent."""
    code = textwrap.dedent(
        """
        import warnings

        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            import repro.serve_tm                 # package import: silent
            import repro.serve_tm.executors       # shim: warns
            import repro.serve_tm.executors       # cached: no second warning
        dep = [
            w for w in rec if issubclass(w.category, DeprecationWarning)
        ]
        assert len(dep) == 1, [str(w.message) for w in rec]
        assert "repro.accel" in str(dep[0].message)

        from repro.serve_tm.executors import ServeCapacity, make_executor

        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            make_executor("interp", ServeCapacity())
        dep = [
            w for w in rec if issubclass(w.category, DeprecationWarning)
        ]
        assert len(dep) == 1, [str(w.message) for w in rec]
        assert "make_engine" in str(dep[0].message)
        print("SHIM-OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert out.returncode == 0, out.stderr
    assert "SHIM-OK" in out.stdout
