"""Sharding/dry-run machinery tests.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax initializes, and the main test process must
keep seeing 1 device), exercising lower+compile of smoke configs on a real
(4 data x 2 model) mesh including the multi-pod axis layout.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_smoke_cells_compile_on_mesh():
    out = _run("""
        import jax
        from repro.configs.registry import get
        from repro.configs.base import ShapeSpec
        from repro.launch.dryrun import lower_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch in ("starcoder2-7b", "moonshot-v1-16b-a3b", "zamba2-2.7b",
                     "whisper-medium", "xlstm-125m"):
            cfg = get(arch + "-smoke")
            for kind in ("train", "prefill", "decode"):
                lower_cell(cfg, ShapeSpec("t", 64, 8, kind), mesh).compile()
        print("COMPILED")
    """)
    assert "COMPILED" in out


@pytest.mark.slow
def test_multipod_axis_shards():
    """The pod axis actually shards the batch (proves the 3-axis layout)."""
    out = _run("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs.registry import get
        from repro.configs.base import ShapeSpec
        from repro.dist import sharding as shd
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get("stablelm-3b-smoke")
        assert shd.batch_axes(mesh, 8) == ("pod", "data")
        from repro.launch.dryrun import lower_cell
        c = lower_cell(cfg, ShapeSpec("t", 64, 8, "train"), mesh).compile()
        print("PODOK", c.cost_analysis()["flops"] > 0)
    """)
    assert "PODOK True" in out


@pytest.mark.slow
def test_tm_sharded_compiles():
    """The paper's multi-core TM on a mesh (classes x batch)."""
    out = _run("""
        import jax, dataclasses
        from repro.dist.tm_sharded import TM_CONFIGS, build_tm_sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(TM_CONFIGS["tm-paper"], n_classes=2, batch=64)
        # adapt: model axis=2 shards 2 classes; data axis=4 shards batch
        fn, specs = build_tm_sharded(cfg, mesh)
        with mesh:
            compiled = jax.jit(fn).lower(*specs).compile()
        print("TMOK")
    """)
    assert "TMOK" in out


def test_collective_parser():
    from repro.analysis.roofline import collective_bytes

    hlo = """
  %p = f32[128,64]{1,0} parameter(0)
  %fusion.1 = f32[128,64]{1,0} fusion(%p), kind=kLoop
  %all-reduce.1 = f32[128,64]{1,0} all-reduce(%fusion.1), channel_id=1
  %ag = f32[512,64]{1,0} all-gather(%fusion.1), dims={0}
  ROOT %all-reduce.2 = f32[] all-reduce(%all-reduce.1), channel_id=2
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 64 * 4 * 2  # both operands resolved
    assert out["all-gather"] == 128 * 64 * 4  # operand, not result


def test_param_sharding_rules():
    import jax
    from repro.configs.registry import get
    from repro.dist import sharding as shd
    from repro.models.api import abstract_params

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("starcoder2-7b", "llama4-maverick-400b-a17b", "zamba2-2.7b",
                 "xlstm-125m", "whisper-medium"):
        cfg = get(arch)
        specs = abstract_params(cfg)
        sh = shd.param_shardings(cfg, mesh, specs)
        # every leaf has a sharding; big matrices are model-sharded
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        assert len(flat) == len(jax.tree.leaves(specs))


def test_cache_sharding_rules_head_dims():
    """Decode caches get batch+HEAD sharding for every cache family —
    attention KV at dim 3, SSM state / mLSTM matrix-memory at their own
    head dims — while headless leaves (SSM conv, sLSTM channel state)
    stay batch-only.  Runs on a degenerate (1, 1) named mesh: axis-name
    assignment is mesh-size-independent, so the PartitionSpecs prove the
    rule without 8 host devices."""
    import jax
    from repro.configs.base import ShapeSpec
    from repro.configs.registry import get
    from repro.dist import sharding as shd
    from repro.models.api import family_for
    from repro.models.ssm import ssm_dims

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def specs_for(arch, batch=8):
        cfg = get(arch)
        shape = ShapeSpec("t", 64, batch, "decode")
        c_specs = family_for(cfg).cache_specs(cfg, shape)
        c_sh = shd.cache_shardings(cfg, mesh, shape, c_specs)
        return cfg, shape, jax.tree.leaves(c_specs), jax.tree.leaves(c_sh)

    def model_dims(sh):
        return [
            d for d, ax in enumerate(sh.spec) if ax == "model"
        ]

    # dense KV [L, B, S, Hkv, hd]: batch dim 1, head dim 3
    cfg, shape, leaves, shardings = specs_for("starcoder2-7b")
    for leaf, sh in zip(leaves, shardings):
        assert sh.spec[1] is not None  # batch sharded
        assert model_dims(sh) == [3]
        assert leaf.shape[3] == cfg.n_kv_heads

    # xLSTM: mLSTM C/n/m [P, B, H, ...] head dim 2; sLSTM [P, B, D]
    # is per-channel fused state — batch-only
    cfg, shape, leaves, shardings = specs_for("xlstm-125m")
    for leaf, sh in zip(leaves, shardings):
        assert sh.spec[1] is not None
        if leaf.ndim >= 3 and leaf.shape[2] == cfg.n_heads:
            assert model_dims(sh) == [2], leaf.shape
        else:
            assert model_dims(sh) == [], leaf.shape

    # head-size collision: with d_model=64, n_heads=8 the mLSTM C cache
    # is [P, B, 8, 8, 8] — its per-head feature dims equal the head
    # count, so rank+size alone matches the KV dim-3 pin.  The square
    # trailing [hd, hd] signature must route it to the generic rule:
    # the TRUE head dim 2 shards, the feature dims stay replicated.
    import dataclasses

    collide = dataclasses.replace(
        get("xlstm-125m"), name="xlstm-collide", d_model=64, n_heads=8,
        n_kv_heads=8,
    )
    shape = ShapeSpec("t", 64, 16, "decode")
    c_specs = family_for(collide).cache_specs(collide, shape)
    c_sh = shd.cache_shardings(collide, mesh, shape, c_specs)
    for leaf, sh in zip(jax.tree.leaves(c_specs), jax.tree.leaves(c_sh)):
        assert sh.spec[1] is not None
        if leaf.ndim >= 3 and leaf.shape[2] == collide.n_heads:
            assert model_dims(sh) == [2], leaf.shape
        else:
            assert model_dims(sh) == [], leaf.shape

    # Zamba2 hybrid: SSM state [G, E, B, H, N, P] head dim 3, conv
    # [G, E, B, K-1, d_conv] batch-only, shared KV [G, B, W, Hkv, hd]
    cfg, shape, leaves, shardings = specs_for("zamba2-2.7b")
    H_ssm = ssm_dims(cfg)[1]
    saw_ssm_state = saw_kv = False
    for leaf, sh in zip(leaves, shardings):
        if leaf.ndim == 6:  # ssm state
            assert sh.spec[2] is not None  # batch at dim 2
            assert model_dims(sh) == [3] and leaf.shape[3] == H_ssm
            saw_ssm_state = True
        elif leaf.ndim == 5 and leaf.shape[3] == cfg.n_kv_heads:  # kv
            assert sh.spec[1] is not None
            assert model_dims(sh) == [3]
            saw_kv = True
        else:  # conv stack: no head dim
            assert model_dims(sh) == [], leaf.shape
    assert saw_ssm_state and saw_kv
