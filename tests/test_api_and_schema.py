"""Golden-schema and harness-CLI contracts.

``src/repro/serve_tm/schema.py`` is the single source of truth for the
``ServeMetrics.summary()`` / ``aggregate()`` key schema; three renderers
must agree with it byte-for-byte: the metrics builder itself, the
``benchmarks/check_regression.py`` gate (which loads the schema by file
path), and the docs/accel.md "Serving metrics" table.  These tests pin
all three, plus the ``benchmarks.run`` CLI contract (``--list`` exits 0
with the suite names; an unknown ``--only`` exits 2).
"""

import importlib.util
import os
import subprocess
import sys

import numpy as np

from repro.core import TMConfig
from repro.core.compress import encode
from repro.serve_tm import PRIORITIES, ServeCapacity, ServeMetrics, TMServer
from repro.serve_tm import schema

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CAP = ServeCapacity(
    instruction_capacity=256, feature_capacity=32, class_capacity=4,
    clause_capacity=8, include_capacity=8, batch_words=1,
)


def _summary_with_traffic():
    rng = np.random.default_rng(0)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=16)
    model = encode(cfg, rng.random((3, 6, 32)) < 0.1)
    server = TMServer(CAP)
    server.register("m", model)
    for _ in range(3):
        server.submit("m", rng.integers(0, 2, (4, 16)).astype(np.uint8))
    server.flush()
    return server.metrics.summary()


# -- the metrics builder -----------------------------------------------------


def test_summary_keys_are_exactly_the_schema():
    for summary in (ServeMetrics().summary(), _summary_with_traffic()):
        assert tuple(summary.keys()) == schema.SUMMARY_KEYS
        assert tuple(summary["lanes"].keys()) == schema.LANES
        for lane, stats in summary["lanes"].items():
            assert tuple(stats.keys()) == schema.LANE_KEYS, lane
            for pct in schema.PCT2_KEYS:
                assert set(stats[pct]) == {"p50", "p99"}
        for pct in schema.PCT3_KEYS:
            assert set(summary[pct]) == {"p50", "p95", "p99"}


def test_aggregate_keys_are_exactly_the_schema():
    snaps = [_summary_with_traffic(), ServeMetrics().summary()]
    agg = ServeMetrics.aggregate(snaps)
    assert tuple(agg.keys()) == schema.AGGREGATE_KEYS
    assert agg["nodes"] == 2
    assert tuple(agg["lanes"].keys()) == schema.LANES
    for stats in agg["lanes"].values():
        assert tuple(stats.keys()) == schema.AGGREGATE_LANE_KEYS
    assert agg["rows"] == sum(s["rows"] for s in snaps)


def test_batching_priorities_are_the_schema_lanes():
    assert PRIORITIES is schema.LANES


# -- the regression gate -----------------------------------------------------


def _load_check_regression():
    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(REPO, "benchmarks", "check_regression.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_loads_the_same_schema():
    cr = _load_check_regression()
    assert cr.SCHEMA.SUMMARY_KEYS == schema.SUMMARY_KEYS
    assert cr.SCHEMA.LANE_KEYS == schema.LANE_KEYS
    assert cr.SCHEMA.LANES == schema.LANES


def test_check_regression_rejects_summary_missing_schema_keys():
    """A backend summary that drops ANY schema key must fail the gate."""
    cr = _load_check_regression()
    full = _summary_with_traffic()
    full["bit_exact"] = True
    full["compile_cache_size"] = 1
    for key in schema.SUMMARY_KEYS:
        broken = {k: v for k, v in full.items() if k != key}
        broken["bit_exact"] = True
        broken["compile_cache_size"] = 1
        errs = cr._serve_schema({"backends": {"plan": broken}})
        assert any(key in e for e in errs), f"dropping {key!r} not caught"


# -- the docs table ----------------------------------------------------------


def test_docs_metrics_table_documents_every_schema_key():
    with open(os.path.join(REPO, "docs", "accel.md")) as f:
        doc = f.read()
    start = doc.index("### Serving metrics")
    end = doc.index("## ", start + 4)
    table = doc[start:end]
    for key in schema.SUMMARY_KEYS + schema.LANE_KEYS:
        assert key in table, f"docs/accel.md metrics table lacks {key!r}"


# -- the benchmarks.run CLI --------------------------------------------------


def _run_harness(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *argv],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=120,
    )


def test_run_list_prints_suites_and_exits_zero():
    out = _run_harness("--list")
    assert out.returncode == 0, out.stderr
    names = out.stdout.split()
    assert names == list(dict.fromkeys(names))  # no duplicates
    for expected in ("table1", "tm_serve", "tm_recal", "tm_kernels",
                     "tm_fleet"):
        assert expected in names


def test_run_unknown_only_exits_two():
    out = _run_harness("--only", "definitely_not_a_suite")
    assert out.returncode == 2
    assert "unknown" in out.stderr
    assert "definitely_not_a_suite" in out.stderr