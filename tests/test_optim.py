"""Optimizer + gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.compress import GradCompressor


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8,)), jnp.float32)
    params = {"w": jnp.zeros(8)}
    state = adamw.init(cfg, params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_bf16_moments():
    cfg = adamw.AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw.init(cfg, params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, s2, gn = adamw.apply(cfg, params, g, state)
    assert p2["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(gn))


def test_grad_clip():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(cfg, params)
    g = {"w": jnp.full(4, 100.0)}
    p2, _, gnorm = adamw.apply(cfg, params, g, state)
    assert float(gnorm) > 100.0
    assert bool(jnp.all(jnp.abs(p2["w"]) < 10.0))


def test_compression_roundtrip_bounded_error():
    rng = np.random.default_rng(1)
    grads = {"a": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    comp = GradCompressor.init(grads)
    cg, comp = comp.compress(grads)
    assert cg.q["a"].dtype == jnp.int8
    deq = GradCompressor.decompress(cg)
    err = float(jnp.max(jnp.abs(deq["a"] - grads["a"])))
    scale = float(cg.scale["a"])
    assert err <= scale * 0.51  # rounding bound


def test_error_feedback_accumulates():
    """With error feedback, the BIAS of repeated compression vanishes:
    sum of k compressed steps ~= sum of the raw gradients."""
    rng = np.random.default_rng(2)
    g = {"a": jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)}
    comp = GradCompressor.init(g)
    total = jnp.zeros(256)
    k = 50
    for _ in range(k):
        cg, comp = comp.compress(g)
        total = total + GradCompressor.decompress(cg)["a"]
    raw_total = g["a"] * k
    # error feedback keeps the accumulated residual bounded by one quantum
    resid = float(jnp.max(jnp.abs(total - raw_total)))
    assert resid <= float(jnp.max(cg.scale["a"])) * 1.01


def test_compressed_training_converges():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(3).normal(size=(8,)), jnp.float32)
    params = {"w": jnp.zeros(8)}
    state = adamw.init(cfg, params)
    comp = GradCompressor.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        cg, comp = comp.compress(g)
        g = GradCompressor.decompress(cg)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss_fn(params)) < 5e-2
