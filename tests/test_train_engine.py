"""TrainEngine plugin API + fused packed-TA kernel tests.

The load-bearing guarantee: every registered train engine ('reference'
host path, 'packed' fused int8 kernel, 'sharded' dist-mesh step) produces
the BIT-IDENTICAL canonical TA state for the same (key, step, batch) —
backend choice is a speed knob, never a semantics knob.  Checked both
directly (fixed seeds, adversarial shapes) and as a hypothesis property
(random shapes/keys/step offsets), plus checkpoint-resume across
backends, the structured capacity envelope, registry/selection behavior,
and the legacy RecalWorker construction shim.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.accel.capacity import CapacityExceeded, CapacityPlan
from repro.core.tm import TMConfig, init_state
from repro.core.train import fit_step
from repro.kernels.tm_train import (
    MAX_PACKED_STATES,
    check_packable,
    fused_fit_step,
    fused_train_batch,
    fused_train_batch_ref,
    pack_ta_state,
    supports_packed_states,
    unpack_ta_state,
)
from repro.recal import (
    TRAIN_ENGINES,
    RecalWorker,
    TrainEngine,
    TrainEngineBase,
    make_train_engine,
    register_train_engine,
    select_train_engine,
    train_engine_names,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batch(rng, B, F, M):
    x = rng.integers(0, 2, (B, F)).astype(np.uint8)
    y = rng.integers(0, M, B).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def _all_engines(cfg, *, plan=None):
    """One instance of every registered engine for cfg (sharded on a 1x1
    mesh so it runs in a single-device test process)."""
    return {
        "reference": make_train_engine("reference", cfg, plan=plan),
        "packed": make_train_engine("packed", cfg, plan=plan),
        "sharded": make_train_engine("sharded", cfg, mesh=_mesh11(), plan=plan),
    }


def _run_engine(engine, cfg, state0, key, batches, *, step0=0):
    """Drive `engine` through `batches` starting at step0; return the
    canonical final state."""
    internal = engine.prepare(state0)
    for j, (xb, yb) in enumerate(batches):
        internal = engine.fit_step(internal, key, xb, yb, step=step0 + j)
    return np.asarray(engine.canonical(internal))


# ---------------------------------------------------------------------------
# packed representation
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_and_action_boundary():
    cfg = TMConfig(n_classes=3, n_clauses=10, n_features=8)
    key = jax.random.key(0)
    state = init_state(cfg, key)
    packed = pack_ta_state(cfg, state)
    assert packed.dtype == jnp.int8
    assert packed.shape == (3, 10, 8, 2)
    back = unpack_ta_state(cfg, packed)
    assert back.dtype == jnp.int32
    assert jnp.array_equal(back, state)
    # include action (state > N) survives the centered remap exactly
    from repro.kernels.tm_train import packed_include_actions

    acts = packed_include_actions(packed.reshape(3, 10, 16))
    assert jnp.array_equal(acts, state > cfg.n_states)
    # extremes of the legal state range fit int8 exactly
    lo = jnp.full_like(state, 1)
    hi = jnp.full_like(state, 2 * cfg.n_states)
    assert jnp.array_equal(unpack_ta_state(cfg, pack_ta_state(cfg, lo)), lo)
    assert jnp.array_equal(unpack_ta_state(cfg, pack_ta_state(cfg, hi)), hi)


def test_packable_gate():
    ok = TMConfig(n_classes=2, n_clauses=4, n_features=4,
                  n_states=MAX_PACKED_STATES)
    too_big = TMConfig(n_classes=2, n_clauses=4, n_features=4,
                       n_states=MAX_PACKED_STATES + 1)
    assert supports_packed_states(ok)
    assert not supports_packed_states(too_big)
    check_packable(ok)
    with pytest.raises(ValueError, match="reference"):
        check_packable(too_big)
    with pytest.raises(ValueError, match="reference"):
        make_train_engine("packed", too_big)


# ---------------------------------------------------------------------------
# bit-identity: packed == reference == sharded (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "M,C,F,B",
    [
        (2, 6, 5, 16),    # tiny
        (3, 40, 11, 33),  # C > 32 (bitplane chunking), ragged batch
        (5, 10, 16, 7),   # ragged sub-word batch
    ],
)
def test_fused_kernel_bit_identical_to_fit_step(M, C, F, B):
    """fused_fit_step == core.train.fit_step(parallel=True), bit for bit,
    including across multiple steps (state feeds back through int8)."""
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    key = jax.random.key(17)
    rng = np.random.default_rng(23)
    state = init_state(cfg, jax.random.key(1))
    packed = pack_ta_state(cfg, state)
    for step in (0, 1, 7):
        xb, yb = _batch(rng, B, F, M)
        state = fit_step(cfg, state, key, xb, yb, step=step, parallel=True)
        packed = fused_fit_step(cfg, packed, key, xb, yb, step=step)
    assert jnp.array_equal(unpack_ta_state(cfg, packed), state)


def test_fused_kernel_all_excluded_clauses():
    """All-TA-states-at-minimum => every clause all-excluded => training
    clause output 1 everywhere; the packed AND-identity path must agree
    with the dense oracle from the first update."""
    cfg = TMConfig(n_classes=3, n_clauses=12, n_features=9)
    state = jnp.ones((3, 12, 18), jnp.int32)  # everything excluded
    key = jax.random.key(3)
    rng = np.random.default_rng(5)
    xb, yb = _batch(rng, 20, 9, 3)
    packed = pack_ta_state(cfg, state)
    ref = fit_step(cfg, state, key, xb, yb, step=0, parallel=True)
    out = fused_fit_step(cfg, packed, key, xb, yb, step=0)
    assert jnp.array_equal(unpack_ta_state(cfg, out), ref)


def test_fused_kernel_matches_independent_oracle():
    """fused_train_batch vs the deliberately-naive unpack->reference->
    repack oracle (two independently-structured computations)."""
    cfg = TMConfig(n_classes=4, n_clauses=24, n_features=12)
    key = jax.random.fold_in(jax.random.key(9), 4)
    rng = np.random.default_rng(11)
    xb, yb = _batch(rng, 40, 12, 4)
    packed = pack_ta_state(cfg, init_state(cfg, jax.random.key(2)))
    out = fused_train_batch(cfg, packed.copy(), key, xb, yb)
    ref = fused_train_batch_ref(cfg, packed.copy(), key, xb, yb)
    assert jnp.array_equal(out, ref)


def test_all_engines_bit_identical_multi_step():
    """The tentpole guarantee at the engine level: reference, packed and
    sharded produce the same canonical state over a multi-step run with
    a ragged tail batch and a nonzero step offset."""
    cfg = TMConfig(n_classes=3, n_clauses=34, n_features=10)
    key = jax.random.key(29)
    rng = np.random.default_rng(31)
    state0 = init_state(cfg, jax.random.key(4))
    batches = [_batch(rng, b, 10, 3) for b in (32, 32, 13)]
    finals = {
        name: _run_engine(e, cfg, state0, key, batches, step0=5)
        for name, e in _all_engines(cfg).items()
    }
    assert np.array_equal(finals["reference"], finals["packed"])
    assert np.array_equal(finals["reference"], finals["sharded"])


def test_checkpoint_resume_across_engines():
    """A (key, step, state) checkpoint taken mid-run on one engine resumes
    bit-exactly on ANY other engine: 2 steps on packed + 2 on sharded ==
    4 straight reference steps."""
    cfg = TMConfig(n_classes=4, n_clauses=20, n_features=8)
    key = jax.random.key(41)
    rng = np.random.default_rng(43)
    state0 = init_state(cfg, jax.random.key(6))
    batches = [_batch(rng, 24, 8, 4) for _ in range(4)]
    eng = _all_engines(cfg)

    straight = _run_engine(eng["reference"], cfg, state0, key, batches)
    mid = _run_engine(eng["packed"], cfg, state0, key, batches[:2])
    hopped = _run_engine(eng["sharded"], cfg, mid, key, batches[2:], step0=2)
    assert np.array_equal(straight, hopped)


def test_engine_equivalence_property():
    """Hypothesis property: over random shapes, keys, step offsets and
    batch sizes (incl. sub-word ragged), packed == reference == sharded
    final canonical states bit-exactly."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    shapes = st.tuples(
        st.integers(2, 5),     # classes
        st.integers(2, 40),    # clauses (crosses the 32 bitplane boundary)
        st.integers(2, 12),    # raw features
        st.integers(1, 40),    # batch rows (crosses the 32 word boundary)
        st.integers(0, 2**16), # seed
        st.integers(0, 2**20), # step offset
        st.booleans(),         # start from all-excluded state
    )

    @given(shapes)
    @settings(max_examples=25, deadline=None)
    def check(spec):
        M, C, F, B, seed, step0, all_excl = spec
        cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
        key = jax.random.key(seed)
        rng = np.random.default_rng(seed)
        if all_excl:
            state0 = jnp.ones((M, C, 2 * F), jnp.int32)
        else:
            state0 = init_state(cfg, jax.random.key(seed + 1))
        batches = [_batch(rng, B, F, M), _batch(rng, max(1, B - 3), F, M)]
        finals = {
            name: _run_engine(e, cfg, state0, key, batches, step0=step0)
            for name, e in _all_engines(cfg).items()
        }
        assert np.array_equal(finals["reference"], finals["packed"])
        assert np.array_equal(finals["reference"], finals["sharded"])

    check()


# ---------------------------------------------------------------------------
# registry / selection / construction
# ---------------------------------------------------------------------------


def test_registry_contents_and_protocol():
    assert train_engine_names() == ["packed", "reference", "sharded"]
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    for name in ("reference", "packed"):
        e = make_train_engine(name, cfg)
        assert isinstance(e, TrainEngine)  # runtime-checkable protocol
        assert e.name == name
    assert TRAIN_ENGINES["sharded"].needs_mesh
    assert not TRAIN_ENGINES["packed"].needs_mesh


def test_register_conflict_raises():
    with pytest.raises(ValueError, match="already registered"):

        @register_train_engine("packed")
        class Impostor(TrainEngineBase):
            pass

    assert TRAIN_ENGINES["packed"].__name__ == "PackedTrainEngine"


def test_select_train_engine_rules():
    small = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    big = TMConfig(n_classes=2, n_clauses=4, n_features=4,
                   n_states=MAX_PACKED_STATES + 8)
    # fastest mesh-free engine wins; packed bows out past its state range
    assert select_train_engine(small) == "packed"
    assert select_train_engine(big) == "reference"
    assert select_train_engine() == "packed"  # no cfg: no supports() veto
    # a mesh selects the mesh-consuming engine
    assert select_train_engine(small, mesh=_mesh11()) == "sharded"


def test_make_train_engine_errors_and_passthrough():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    with pytest.raises(ValueError, match="unknown train engine"):
        make_train_engine("warp", cfg)
    ref = make_train_engine("reference", cfg)
    assert make_train_engine(ref, cfg) is ref
    # mesh is only forwarded to engines that declare needs_mesh
    assert make_train_engine("reference", cfg, mesh=_mesh11()).name == "reference"


# ---------------------------------------------------------------------------
# capacity envelope (structured errors, not bare asserts)
# ---------------------------------------------------------------------------


def test_fit_step_capacity_exceeded():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    plan = CapacityPlan(batch_words=1)  # 32-row envelope
    state = init_state(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    xb, yb = _batch(rng, 33, 4, 2)
    with pytest.raises(CapacityExceeded) as ei:
        fit_step(cfg, state, jax.random.key(1), xb, yb, step=0,
                 parallel=True, plan=plan)
    err = ei.value
    assert isinstance(err, ValueError)
    assert err.knob == "batch_words"
    assert err.required == 2 and err.capacity == 1
    # within the envelope: fine
    fit_step(cfg, state, jax.random.key(1), xb[:32], yb[:32], step=0,
             parallel=True, plan=plan)


def test_fused_and_engine_capacity_exceeded():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    plan = CapacityPlan(batch_words=1)
    rng = np.random.default_rng(1)
    xb, yb = _batch(rng, 40, 4, 2)
    packed = pack_ta_state(cfg, init_state(cfg, jax.random.key(0)))
    with pytest.raises(CapacityExceeded):
        fused_fit_step(cfg, packed, jax.random.key(1), xb, yb, step=0,
                       plan=plan)
    for name, e in _all_engines(cfg, plan=plan).items():
        internal = e.prepare(init_state(cfg, jax.random.key(0)))
        with pytest.raises(CapacityExceeded):
            e.fit_step(internal, jax.random.key(1), xb, yb, step=0)


def test_worker_respects_plan():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=4)
    worker = RecalWorker(cfg, key=jax.random.key(0),
                         plan=CapacityPlan(batch_words=1))
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2, (64, 4)).astype(np.uint8)
    y = rng.integers(0, 2, 64).astype(np.int32)
    with pytest.raises(CapacityExceeded):
        worker.fine_tune(x, y)
    assert worker.step_count == 0  # failed batches consume no step ids
    worker.fine_tune(x[:32], y[:32])
    assert worker.step_count == 1


# ---------------------------------------------------------------------------
# RecalWorker over the engine API
# ---------------------------------------------------------------------------


def test_worker_engine_parity_and_state_boundary():
    """Workers on different engines stay bit-identical through the epoch
    loop (shared shuffle stream), and the canonical-state boundary
    (state property / snapshot / restore) hides the int8 representation."""
    cfg = TMConfig(n_classes=3, n_clauses=18, n_features=8)
    rng = np.random.default_rng(7)
    x = rng.integers(0, 2, (300, 8)).astype(np.uint8)
    y = rng.integers(0, 3, 300).astype(np.int32)
    wp = RecalWorker(cfg, key=jax.random.key(1))  # auto -> packed
    wr = RecalWorker(cfg, key=jax.random.key(1), train_engine="reference")
    assert wp.train_engine == "packed" and wr.train_engine == "reference"
    assert wp._internal.dtype == jnp.int8  # fused representation persists
    assert wp.state.dtype == jnp.int32    # ...but the boundary is canonical
    wp.fine_tune_epochs(x, y, epochs=2, batch=64)
    wr.fine_tune_epochs(x, y, epochs=2, batch=64)
    assert np.array_equal(wp.snapshot(), wr.snapshot())
    # restore() round-trips through prepare(); subclasses may assign state
    snap = wr.snapshot()
    wp.fine_tune(x[:64], y[:64])
    wp.restore(snap)
    assert np.array_equal(wp.snapshot(), snap)
    wp.state = init_state(cfg, jax.random.key(9))
    assert np.array_equal(wp.snapshot(), np.asarray(init_state(cfg, jax.random.key(9))))


def test_worker_legacy_sharded_shim():
    """Satellite: the pre-engine RecalWorker(mesh=, sharded_batch=)
    construction still works (maps to the 'sharded' engine) but warns
    exactly once per process — checked in a subprocess so this test is
    immune to warning state from the rest of the suite."""
    code = textwrap.dedent(
        """
        import warnings
        import jax
        import numpy as np
        from repro.core.tm import TMConfig
        from repro.recal import RecalWorker

        cfg = TMConfig(n_classes=2, n_clauses=6, n_features=4)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            w1 = RecalWorker(cfg, key=jax.random.key(0), mesh=mesh,
                             sharded_batch=16)
            w2 = RecalWorker(cfg, key=jax.random.key(0), mesh=mesh,
                             sharded_batch=16)
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1, [str(w.message) for w in rec]
        assert "train_engine='sharded'" in str(dep[0].message)
        assert w1.train_engine == "sharded"

        # the shimmed worker still trains bit-identically to reference
        wr = RecalWorker(cfg, key=jax.random.key(0),
                         train_engine="reference")
        rng = np.random.default_rng(0)
        x = rng.integers(0, 2, (16, 4)).astype(np.uint8)
        y = rng.integers(0, 2, 16).astype(np.int32)
        w1.fine_tune(x, y)
        wr.fine_tune(x, y)
        assert np.array_equal(w1.snapshot(), wr.snapshot())

        # new-style construction is silent
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            RecalWorker(cfg, key=jax.random.key(0))
        assert not [
            w for w in rec if issubclass(w.category, DeprecationWarning)
        ]
        print("WORKER-SHIM-OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert out.returncode == 0, out.stderr
    assert "WORKER-SHIM-OK" in out.stdout
