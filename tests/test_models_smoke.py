"""Per-assigned-architecture smoke tests: reduced config of the same family
runs one forward/train step + prefill + decode on CPU, asserting output
shapes and finiteness (the FULL configs are exercised by the dry-run only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import all_arch_names, get
from repro.dist.steps import make_train_step, opt_config_for
from repro.models.api import active_params, count_params, family_for
from repro.optim import adamw

rng = np.random.default_rng(0)


def _batch_for(cfg, fam, shape):
    out = {}
    for k, s in fam.input_specs(cfg, shape).items():
        if k in ("tokens", "token"):
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, s.shape), jnp.int32)
        elif k == "pos":
            out[k] = jnp.int32(0)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", all_arch_names())
def test_train_step_smoke(arch):
    cfg = get(arch + "-smoke")
    fam = family_for(cfg)
    params = fam.init_params(cfg, jax.random.key(0))
    opt_cfg = opt_config_for(cfg)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch_for(cfg, fam, ShapeSpec("t", 64, 2, "train"))
    params2, opt2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", all_arch_names())
def test_prefill_decode_smoke(arch):
    cfg = get(arch + "-smoke")
    fam = family_for(cfg)
    params = fam.init_params(cfg, jax.random.key(1))
    B, S = 2, 64
    batch = _batch_for(cfg, fam, ShapeSpec("p", S, B, "prefill"))
    logits, cache = jax.jit(lambda p, b: fam.prefill(cfg, p, b))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    dec = {
        "token": jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32),
        "pos": jnp.int32(S - 1),
    }
    logits2, cache2 = jax.jit(lambda p, c, b: fam.decode(cfg, p, c, b))(
        params, cache, dec
    )
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", all_arch_names())
def test_full_config_dims(arch):
    """Exact assigned dims are wired through (no allocation: specs only)."""
    cfg = get(arch)
    fam = family_for(cfg)
    fam.param_specs(cfg)
    n = count_params(cfg)
    assert n > 0
    if cfg.is_moe:
        assert active_params(cfg) < n
    # vocab padding never shrinks
    assert cfg.padded_vocab >= cfg.vocab


def test_loss_decreases_on_tiny_training():
    """End-to-end: 30 steps of the real train step reduce loss on the
    structured synthetic stream."""
    from repro.data.pipeline import TokenStream, TokenStreamConfig

    cfg = get("stablelm-3b-smoke")
    fam = family_for(cfg)
    params = fam.init_params(cfg, jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    stream = TokenStream(TokenStreamConfig(cfg.vocab, 64, 16, seed=1))
    losses = []
    for _ in range(60):
        batch = {"tokens": jnp.asarray(stream.next_batch()["tokens"])}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    # measured headroom ~1.8 nats over 60 steps; assert half of it
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.9, losses
