"""repro.fleet: pool membership behind the ServingNode boundary, routed
replica traffic (least-depth, failover, replication), and canary → wave
→ fleet rollouts with gated fleet-wide rollback."""

import asyncio

import numpy as np
import pytest

import jax.numpy as jnp

from repro.accel import Accelerator, CapacityPlan, TMProgram
from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.fleet import (
    FleetPool,
    NoEligibleNode,
    RolloutAborted,
    RolloutManager,
    Router,
    plan_stages,
)
from repro.serve_tm import CapacityExceeded, ServingNode, TMServer
from repro.serve_tm.scheduler import Overloaded

CAP = CapacityPlan(
    instruction_capacity=1024, feature_capacity=128, class_capacity=16,
    clause_capacity=32, include_capacity=24, batch_words=2,
)

ENGINES = ("interp", "plan", "popcount", "sharded")


def _random_model(rng, M, C, F, density=0.05):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_sums(cfg, acts, X):
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    )


def _program(model, cap=CAP):
    return TMProgram(capacity=cap, model=model)


def _pool(n, slot=None, artifact=None, engines=ENGINES):
    """A pool of n TMServer nodes over heterogeneous engines."""
    pool = FleetPool()
    for i in range(n):
        node = TMServer(CAP, engine=engines[i % len(engines)])
        if slot is not None:
            node.register(slot, artifact)
        pool.add(f"n{i}", node)
    return pool


# -- membership / protocol ---------------------------------------------------


def test_pool_membership_and_protocol_conformance():
    pool = FleetPool()
    server = TMServer(CAP)
    accel = Accelerator(plan=CAP)
    # both node flavors satisfy the structural boundary
    assert isinstance(server, ServingNode)
    assert isinstance(accel, ServingNode)
    pool.add("a", server)
    pool.add("b", accel)
    assert pool.names() == ["a", "b"]  # join order
    assert "a" in pool and len(pool) == 2
    with pytest.raises(ValueError, match="already in the pool"):
        pool.add("a", TMServer(CAP))
    with pytest.raises(TypeError, match="ServingNode"):
        pool.add("c", object())
    assert pool.remove("a") is server
    assert pool.names() == ["b"]
    with pytest.raises(KeyError):
        pool.node("a")


def test_pool_install_validates_every_target_before_any_register():
    """A heterogeneous fleet must never end up half-programmed: if ONE
    node can't fit the artifact, NO node gets it."""
    rng = np.random.default_rng(0)
    _, _, model = _random_model(rng, 5, 12, 40)
    small = CapacityPlan(
        instruction_capacity=64, feature_capacity=32, class_capacity=4,
        clause_capacity=8, include_capacity=8, batch_words=1,
    )
    pool = FleetPool({"big": TMServer(CAP), "small": TMServer(small)})
    with pytest.raises(CapacityExceeded, match="small"):
        pool.install("m", _program(model))
    assert pool.nodes_with_slot("m") == []
    # restricting to fitting nodes works
    pool.install("m", _program(model), nodes=["big"])
    assert [n for n, _ in pool.nodes_with_slot("m")] == ["big"]


# -- routing -----------------------------------------------------------------


def test_router_least_depth_routing_and_bit_exactness():
    """Requests spread by pending rows across heterogeneous engines and
    every prediction matches the dense oracle."""
    rng = np.random.default_rng(1)
    cfg, acts, model = _random_model(rng, 5, 12, 40)
    art = _program(model)
    pool = _pool(3, slot="m", artifact=art)
    router = Router(pool)
    with pytest.raises(NoEligibleNode, match="no node hosts"):
        router.route("ghost")
    handles = []
    for _ in range(6):  # loops not running -> queues accumulate
        x = rng.integers(0, 2, (10, 40)).astype(np.uint8)
        handles.append((router.submit("m", x), x))
    # least-depth + join-order tie-break round-robins a uniform load
    assert [h.routed_to for h, _ in handles] == ["n0", "n1", "n2"] * 2
    for _, node in pool.items():
        node.flush()
    for h, x in handles:
        assert (h.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()
        assert (h.class_sums == _oracle_sums(cfg, acts, x)).all()


class _AlwaysOverloaded(TMServer):
    async def async_submit(self, slot, x, **kw):
        raise Overloaded(slot, kw.get("priority", "normal"), 99, 1)


def test_router_async_failover_on_overloaded():
    """A node's Overloaded moves the request to the next candidate; it
    propagates only when every candidate rejects."""
    rng = np.random.default_rng(2)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    full = _AlwaysOverloaded(CAP, engine="interp")
    full.register("m", art)
    ok = TMServer(CAP, engine="plan")
    ok.register("m", art)
    pool = FleetPool({"full": full, "ok": ok})
    router = Router(pool)
    x = rng.integers(0, 2, (8, 32)).astype(np.uint8)

    async def run():
        h = await router.async_submit("m", x)
        return h

    h = asyncio.run(run())
    assert h.routed_to == "ok"
    ok.flush()
    assert (h.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()

    full2 = _AlwaysOverloaded(CAP, engine="interp")
    full2.register("m", art)
    all_full = FleetPool({"a": full2})

    async def run_full():
        await Router(all_full).async_submit("m", x)

    with pytest.raises(Overloaded):
        asyncio.run(run_full())


def test_router_replicate_reships_artifact_capacity_fit():
    rng = np.random.default_rng(3)
    _, _, model = _random_model(rng, 5, 12, 40)
    art = _program(model)
    small = CapacityPlan(
        instruction_capacity=64, feature_capacity=32, class_capacity=4,
        clause_capacity=8, include_capacity=8, batch_words=1,
    )
    pool = FleetPool({
        "src": TMServer(CAP, engine="interp"),
        "fit": TMServer(CAP, engine="popcount"),
        "tiny": TMServer(small),
    })
    pool.install("m", art, nodes=["src"])
    router = Router(pool)
    # asks for 2 replicas; only one node fits -> capacity-fit filtering
    assert router.replicate("m", n=2) == ["fit"]
    assert pool.node("fit").installed_checksum("m") == art.checksum
    assert "rollout" not in pool.node("fit").registry.get("m").provenance
    assert pool.node("fit").registry.get("m").provenance == "replicate:src"
    assert "m" not in pool.node("tiny").slots()
    # a slot programmed from a bare model has no wire artifact to re-ship
    bare = TMServer(CAP)
    bare.register("bare", model)
    p2 = FleetPool({"a": bare, "b": TMServer(CAP)})
    with pytest.raises(ValueError, match="bare model"):
        Router(p2).replicate("bare")


# -- rollouts ----------------------------------------------------------------


def test_plan_stages_shapes():
    assert plan_stages(["a"]) == [("canary", ["a"])]
    assert plan_stages(["a", "b"]) == [("canary", ["a"]), ("wave", ["b"])]
    assert plan_stages(["a", "b", "c", "d"]) == [
        ("canary", ["a"]), ("wave", ["b", "c"]), ("fleet", ["d"]),
    ]
    assert plan_stages(list("abcde")) == [
        ("canary", ["a"]), ("wave", ["b", "c"]), ("fleet", ["d", "e"]),
    ]


def test_rollout_success_canary_wave_fleet():
    """A good artifact ships in three gated stages; every node ends on
    the shipped checksum with rollout provenance, bit-exact across
    heterogeneous engines."""
    rng = np.random.default_rng(4)
    cfg1, acts1, m1 = _random_model(rng, 5, 12, 40)
    cfg2, acts2, m2 = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(m2)
    pool = _pool(4, slot="m", artifact=v1)
    X = rng.integers(0, 2, (64, 40)).astype(np.uint8)
    y2 = _oracle_sums(cfg2, acts2, X).argmax(1)  # the NEW program's truth
    report = RolloutManager(pool).rollout(
        "m", v2, holdout_x=X, holdout_y=y2,
    )
    assert report.completed and report.failed_stage is None
    assert [s.stage for s in report.stages] == ["canary", "wave", "fleet"]
    assert [len(s.nodes) for s in report.stages] == [1, 2, 1]
    assert all(s.passed and s.bit_exact and s.checksum_ok
               for s in report.stages)
    # the new program aces its own holdout on every node
    assert all(s.accuracy == 1.0 for s in report.stages)
    for name, node in pool.items():
        assert node.installed_checksum("m") == v2.checksum
        assert "rollout:" in node.registry.get("m").provenance
        assert f"{v2.checksum:08x}" in report.provenance[name]


def test_rollout_canary_accuracy_failure_rolls_back():
    """A bad artifact dies at the canary: the fleet never sees it, the
    canary is rolled back with nested provenance, and the structured
    RolloutAborted carries the full report."""
    rng = np.random.default_rng(5)
    cfg1, acts1, m1 = _random_model(rng, 5, 12, 40)
    _, _, bad = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(bad)
    pool = _pool(4, slot="m", artifact=v1)
    X = rng.integers(0, 2, (64, 40)).astype(np.uint8)
    y1 = _oracle_sums(cfg1, acts1, X).argmax(1)  # CURRENT program's truth
    with pytest.raises(RolloutAborted) as ei:
        RolloutManager(pool).rollout("m", v2, holdout_x=X, holdout_y=y1)
    err = ei.value
    assert err.stage == "canary" and "accuracy" in err.reason
    assert err.report.baseline_accuracy == 1.0
    assert err.report.rolled_back == ("n0",)
    for name, node in pool.items():
        # every node serves the OLD program again (or still)
        assert node.installed_checksum("m") == v1.checksum
        prov = node.registry.get("m").provenance
        if name == "n0":
            # the retreat heads the chain; the attempt is in history
            assert prov.startswith("rollback:")
            assert any("rollout:canary" in h.provenance
                       for h in node.registry.history("m"))
        else:
            assert "rollout" not in prov


class _LyingChecksum(TMServer):
    """A node that programs the artifact but reports the wrong installed
    checksum — the integrity gate's target."""

    def installed_checksum(self, slot):
        return 0xDEADBEEF


def test_rollout_midwave_integrity_failure_rolls_back_everything():
    """A wave-stage gate failure retreats the WHOLE rollout: nodes
    installed in earlier passing stages roll back too."""
    rng = np.random.default_rng(6)
    _, _, m1 = _random_model(rng, 5, 12, 40)
    _, _, m2 = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(m2)
    good = TMServer(CAP, engine="interp")
    liar = _LyingChecksum(CAP, engine="plan")
    for node in (good, liar):
        node.register("m", v1)
    pool = FleetPool({"good": good, "liar": liar})
    X = rng.integers(0, 2, (32, 40)).astype(np.uint8)
    with pytest.raises(RolloutAborted) as ei:
        RolloutManager(pool).rollout("m", v2, holdout_x=X)
    assert ei.value.stage == "wave" and "checksum" in ei.value.reason
    assert ei.value.report.rolled_back == ("good", "liar")
    for node in (good, liar):
        # back on v1's artifact (version advances monotonically)
        assert node.registry.get("m").artifact.checksum == v1.checksum
        assert node.registry.get("m").provenance.startswith("rollback:")


def test_rollout_refuses_misfitting_fleet_up_front():
    rng = np.random.default_rng(7)
    _, _, m1 = _random_model(rng, 5, 12, 40)
    v1 = _program(m1)
    small = CapacityPlan(
        instruction_capacity=64, feature_capacity=32, class_capacity=4,
        clause_capacity=8, include_capacity=8, batch_words=1,
    )
    big = TMServer(CAP)
    big.register("m", v1)
    pool = FleetPool({"big": big, "tiny": TMServer(small)})
    X = rng.integers(0, 2, (8, 40)).astype(np.uint8)
    with pytest.raises(CapacityExceeded, match="tiny"):
        # explicit targets include the misfit -> refused before any install
        RolloutManager(pool).rollout(
            "m", v1, holdout_x=X, nodes=["big", "tiny"]
        )
    assert big.installed_checksum("m") == v1.checksum
    with pytest.raises(TypeError, match="TMProgram"):
        RolloutManager(pool).rollout("m", m1, holdout_x=X)


def test_rollout_under_live_traffic_drops_nothing():
    """A mid-traffic rollout: requests keep flowing through the router
    while the fleet reprograms; every reply matches the old OR the new
    program's oracle and nothing is dropped."""
    rng = np.random.default_rng(8)
    cfg1, acts1, m1 = _random_model(rng, 5, 12, 40)
    cfg2, acts2, m2 = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(m2)
    pool = _pool(2, slot="m", artifact=v1)
    router = Router(pool)
    pool.start_all()
    try:
        handles = []
        X = rng.integers(0, 2, (40, 6, 40)).astype(np.uint8)
        for i in range(10):
            handles.append((router.submit("m", X[i]), X[i]))
        report = RolloutManager(pool).rollout("m", v2, holdout_x=X[0])
        assert report.completed
        for i in range(10, 20):
            handles.append((router.submit("m", X[i]), X[i]))
        ok1 = ok2 = 0
        for h, x in handles:
            preds = h.wait(timeout=60.0)
            e1 = _oracle_sums(cfg1, acts1, x).argmax(1)
            e2 = _oracle_sums(cfg2, acts2, x).argmax(1)
            if (preds == e1).all():
                ok1 += 1
            elif (preds == e2).all():
                ok2 += 1
            else:  # pragma: no cover - the assertion message we want
                raise AssertionError("reply matches neither program")
        assert ok1 + ok2 == 20 and ok2 >= 10  # post-rollout -> new program
    finally:
        pool.stop_all()


# -- fleet metrics rollup ----------------------------------------------------


def test_pool_metrics_aggregate_sums_nodes():
    rng = np.random.default_rng(9)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    pool = _pool(2, slot="m", artifact=art, engines=("interp", "plan"))
    router = Router(pool)
    for _ in range(4):
        router.submit("m", rng.integers(0, 2, (5, 32)).astype(np.uint8))
    for _, node in pool.items():
        node.flush()
    summary = pool.metrics_summary()
    agg, nodes = summary["aggregate"], summary["nodes"]
    assert agg["nodes"] == 2 and set(nodes) == {"n0", "n1"}
    assert agg["rows"] == sum(s["rows"] for s in nodes.values()) == 20
    assert agg["requests_completed"] == 4
    assert agg["throughput_dps"] == pytest.approx(
        sum(s["throughput_dps"] for s in nodes.values())
    )


# -- stable exception exports (satellite) ------------------------------------


def test_structured_exceptions_exported_from_both_packages():
    """Overloaded / DeadlineExceeded / CapacityExceeded (and the
    ServingNode boundary) are the SAME objects importable from
    repro.accel and repro.serve_tm."""
    import repro.accel as accel
    import repro.serve_tm as serve

    for name in ("Overloaded", "DeadlineExceeded", "CapacityExceeded",
                 "ServingNode"):
        a, s = getattr(accel, name), getattr(serve, name)
        assert a is s, name
        assert name in accel.__all__ and name in serve.__all__


def test_failure_exceptions_exported_from_all_three_packages():
    """NodeDown and EngineFault are stable, identical exports of
    repro.fleet, repro.serve_tm AND repro.accel — deployment code
    catches fleet failures from whichever package it already imports."""
    import repro.accel as accel
    import repro.fleet as fleet
    import repro.serve_tm as serve

    for name in ("NodeDown", "EngineFault"):
        a = getattr(accel, name)
        f = getattr(fleet, name)
        s = getattr(serve, name)
        assert a is s and f is s, name
        for pkg in (accel, fleet, serve):
            assert name in pkg.__all__, (name, pkg.__name__)
    assert fleet.ServingNode is serve.ServingNode
