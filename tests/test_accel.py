"""The repro.accel façade: capacity negotiation, the Engine plugin
protocol, and the serializable TMProgram artifact.

Covers the ISSUE-5 acceptance surface: TMProgram bytes round-trip with
bit-exact class sums on every engine, CapacityPlan.for_models minimality
and word-quantization, CapacityExceeded knob reporting, deterministic
engine auto-selection, and compile_cache_size()==1 across hot-swaps of
differently-sized models within one negotiated plan.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.accel import (
    ENGINES,
    Accelerator,
    CapacityExceeded,
    CapacityPlan,
    EngineBase,
    QUANTA,
    TMProgram,
    make_engine,
    model_requirements,
    register_engine,
    select_engine,
)
from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.serve_tm import ModelRegistry, TMServer

ENGINE_NAMES = ("interp", "plan", "sharded", "popcount")


def _random_model(rng, M, C, F, density=0.05):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_sums(cfg, acts, X):
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    )


# ---------------------------------------------------------------------------
# CapacityPlan negotiation
# ---------------------------------------------------------------------------

def test_for_models_fits_population_and_is_quantized():
    rng = np.random.default_rng(0)
    models = [
        _random_model(rng, 5, 12, 40)[2],
        _random_model(rng, 9, 8, 72)[2],
        _random_model(rng, 3, 20, 24, density=0.15)[2],
    ]
    plan = CapacityPlan.for_models(models)
    for m in models:
        assert plan.fits(m), plan.violations(m)
    for knob, q in QUANTA.items():
        assert getattr(plan, knob) % q == 0, (knob, getattr(plan, knob))
    # the envelope is driven by the population maxima
    assert plan.class_capacity == 9
    assert plan.feature_capacity == 80  # 72 -> quantized to 16


def test_for_models_minimality_per_quantum():
    """Shrinking any model-derived knob by ONE quantum must evict some
    model from the envelope — the plan is minimal at the word grain."""
    rng = np.random.default_rng(1)
    models = [_random_model(rng, 6, 10, 48, density=0.1)[2],
              _random_model(rng, 4, 14, 64)[2]]
    plan = CapacityPlan.for_models(models)  # headroom=0
    for knob in CapacityPlan.KNOBS:
        if knob == "batch_words":  # traffic-shaped, not model-derived
            continue
        if getattr(plan, knob) - QUANTA[knob] < 1:
            continue  # already at the floor (e.g. weight_planes=1)
        shrunk = dataclasses.replace(
            plan, **{knob: getattr(plan, knob) - QUANTA[knob]}
        )
        assert any(not shrunk.fits(m) for m in models), knob


def test_for_models_headroom_and_errors():
    rng = np.random.default_rng(2)
    model = _random_model(rng, 4, 10, 32)[2]
    base = CapacityPlan.for_models([model])
    roomy = CapacityPlan.for_models([model], headroom=1.0)
    assert roomy.instruction_capacity >= 2 * model.n_instructions
    assert roomy.clause_capacity >= base.clause_capacity
    # task-pinned dims never inflate: classes/features are what they are
    assert roomy.class_capacity == base.class_capacity == 4
    assert roomy.feature_capacity == base.feature_capacity == 32
    assert roomy.batch_words == base.batch_words
    with pytest.raises(ValueError, match="at least one model"):
        CapacityPlan.for_models([])
    with pytest.raises(ValueError, match="headroom"):
        CapacityPlan.for_models([model], headroom=-0.5)
    with pytest.raises(ValueError, match="positive integer"):
        CapacityPlan(class_capacity=0)


def test_capacity_exceeded_reports_knob_and_required_value():
    rng = np.random.default_rng(3)
    _, _, small = _random_model(rng, 3, 6, 24)
    # generous everywhere except the knob under test, so the report is
    # unambiguous (validate reports violations in KNOBS order)
    plan = dataclasses.replace(
        CapacityPlan.for_models([small]),
        instruction_capacity=8192, clause_capacity=64, include_capacity=64,
    )
    _, _, wide = _random_model(rng, 3, 6, 120)
    with pytest.raises(CapacityExceeded) as ei:
        plan.validate(wide)
    err = ei.value
    assert isinstance(err, ValueError)  # legacy guards keep working
    assert err.knob == "feature_capacity"
    assert err.required == 120
    assert err.capacity == plan.feature_capacity
    assert "feature_capacity" in str(err)
    # widen_to is the advertised remedy
    widened = plan.widen_to(wide)
    assert widened.fits(wide) and widened.fits(small)
    assert widened.feature_capacity == 128  # 120 quantized up to 16s

    _, _, classy = _random_model(rng, 14, 6, 24)
    with pytest.raises(CapacityExceeded) as ei:
        plan.validate(classy)
    assert ei.value.knob == "class_capacity"
    assert ei.value.required == 14
    # knob subsets: an engine that has no class bank wouldn't trip it
    assert plan.fits(classy, knobs=("feature_capacity",))


def test_model_requirements_extents():
    rng = np.random.default_rng(4)
    cfg, acts, model = _random_model(rng, 5, 12, 40, density=0.1)
    req = model_requirements(model)
    assert req["instruction_capacity"] == model.n_instructions
    assert req["class_capacity"] == 5
    assert req["feature_capacity"] == 40
    # clause/include extents match the dense action mask
    per_class = (acts.any(axis=2)).sum(axis=1).max()
    assert req["clause_capacity"] == per_class
    assert req["include_capacity"] == acts.sum(axis=2).max()


# ---------------------------------------------------------------------------
# TMProgram artifact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_tmprogram_bytes_roundtrip_bit_exact(engine):
    """compile -> to_bytes -> from_bytes -> load must reproduce class
    sums bit-exactly on every engine (the acceptance criterion)."""
    rng = np.random.default_rng(5)
    cfg, acts, model = _random_model(rng, 5, 12, 40)
    acc = Accelerator.for_models([model], engine=engine, batch_words=2)
    art = acc.compile(model)
    blob = art.to_bytes()
    art2 = TMProgram.from_bytes(blob)
    assert art2 == art
    assert art2.checksum == art.checksum
    assert art2.capacity == acc.plan
    acc.load("m", blob, provenance="wire")
    X = rng.integers(0, 2, (33, 40)).astype(np.uint8)
    assert (acc.class_sums("m", X) == _oracle_sums(cfg, acts, X)).all()
    assert acc.compile_cache_size() == 1
    entry = acc.registry.get("m")
    assert entry.provenance == "wire"
    assert entry.artifact is not None
    assert entry.artifact.checksum == art.checksum


def test_tmprogram_rejects_corruption():
    rng = np.random.default_rng(6)
    _, _, model = _random_model(rng, 4, 8, 32)
    art = TMProgram(CapacityPlan.for_models([model]), model)
    blob = bytearray(art.to_bytes())
    with pytest.raises(ValueError, match="checksum"):
        TMProgram.from_bytes(bytes(blob[:-2] + bytes([blob[-2] ^ 0xFF, blob[-1]])))
    with pytest.raises(ValueError, match="truncated"):
        TMProgram.from_bytes(bytes(blob[:10]))
    with pytest.raises(ValueError, match="truncated"):
        TMProgram.from_bytes(bytes(blob[:-4]))
    with pytest.raises(ValueError, match="not a TMProgram"):
        TMProgram.from_bytes(b"NOPE" + bytes(blob[4:]))
    newer = bytearray(blob)
    newer[4:6] = (99).to_bytes(2, "little")
    with pytest.raises(ValueError, match="version"):
        TMProgram.from_bytes(bytes(newer))


def test_compile_gate_covers_the_load_path():
    """Anything compile() accepts must install on the same accelerator:
    the serving node's load path never discovers a capacity violation
    the training node's gate missed (the plan engine's clause-table
    bound is part of its validated knobs)."""
    rng = np.random.default_rng(12)
    plan = CapacityPlan(
        instruction_capacity=4096, feature_capacity=32, class_capacity=16,
        clause_capacity=8, include_capacity=8, batch_words=1,
    )
    acc = Accelerator(plan, engine="plan")
    # 16 classes x ~18 non-empty clauses blows the 16*8 segment table —
    # compile must say so; it must NOT surface only at load time
    cfg, acts, clausey = _random_model(rng, 16, 20, 16, density=0.08)
    with pytest.raises(CapacityExceeded) as ei:
        acc.compile(clausey)
    assert ei.value.knob == "clause_capacity"
    # and a compile-accepted model always loads
    cfg2, acts2, ok = _random_model(rng, 8, 6, 16, density=0.08)
    acc.load("m", acc.compile(ok).to_bytes())
    X = rng.integers(0, 2, (9, 16)).astype(np.uint8)
    assert (acc.class_sums("m", X) == _oracle_sums(cfg2, acts2, X)).all()


def test_instruction_metric_extend_heavy_stream():
    """plan/popcount operand vectors hold only the INCLUDES; boundary
    EXTEND words never materialize there.  An EXTEND-heavy stream (high
    literal slots) must load on those engines with instruction_capacity
    sized for the includes, while the interp engine (whose instruction
    memory holds the raw stream) reports the full stream depth."""
    cfg = TMConfig(n_classes=2, n_clauses=2, n_features=4096)
    acts = np.zeros((2, 2, 8192), bool)
    acts[:, :, 8190] = True  # offset 8190 needs two EXTENDs per include
    model = encode(cfg, acts)
    assert model.n_instructions == 12  # 4 includes + 8 EXTENDs
    plan = CapacityPlan(
        instruction_capacity=8, feature_capacity=4096, class_capacity=2,
        clause_capacity=2, include_capacity=1, batch_words=1,
    )
    rng = np.random.default_rng(13)
    X = rng.integers(0, 2, (5, 4096)).astype(np.uint8)
    oracle = _oracle_sums(cfg, acts, X)
    for name in ("plan", "popcount"):
        acc = Accelerator(plan, engine=name)
        acc.load("m", acc.compile(model))  # 4 includes <= 8: fits
        assert (acc.class_sums("m", X) == oracle).all()
    with pytest.raises(CapacityExceeded) as ei:
        Accelerator(plan, engine="interp").compile(model)
    assert ei.value.knob == "instruction_capacity"
    assert ei.value.required == 12  # the full stream depth


def test_tmprogram_rejects_inconsistent_dims():
    """A CRC-consistent blob whose dims lie about the stream length must
    be rejected, not silently truncated to a wrong model."""
    import struct
    import zlib

    rng = np.random.default_rng(14)
    _, _, model = _random_model(rng, 4, 8, 32)
    blob = TMProgram(CapacityPlan.for_models([model]), model).to_bytes()
    payload = bytearray(blob[16:])
    # dims claim FEWER instructions than the payload carries, with the
    # CRC recomputed so only the length cross-check can catch the lie
    payload[36:40] = struct.pack("<I", model.n_instructions - 100)
    rebuilt = struct.pack(
        "<4sHHII", b"TMPG", 1, 0, len(payload), zlib.crc32(bytes(payload))
    ) + bytes(payload)
    with pytest.raises(ValueError, match="inconsistent"):
        TMProgram.from_bytes(rebuilt)


def test_failed_publication_restores_worker_state():
    """When the publication gate refuses a recal (capacity exhausted),
    the live slot is untouched AND the worker reverts to its pre-recal
    state — the unpublished fine-tune must not seed the next attempt."""
    import jax

    from repro.recal import RecalController, RecalWorker
    from repro.recal.compressor import Compressor

    cfg = TMConfig(n_classes=3, n_clauses=4, n_features=16)
    worker = RecalWorker(cfg, key=jax.random.key(3))
    plan = CapacityPlan(
        instruction_capacity=1024, feature_capacity=16, class_capacity=4,
        clause_capacity=4, include_capacity=16, batch_words=1,
    )
    acc = Accelerator(plan, engine="plan")
    controller = RecalController(
        acc, "s", worker, min_buffer_rows=1, epochs_per_recal=1,
        train_batch_size=8,
    )
    controller.deploy()
    rng = np.random.default_rng(15)
    x = rng.integers(0, 2, (16, 16)).astype(np.uint8)
    y = rng.integers(0, 3, 16).astype(np.int32)
    controller.observe(x, y)
    pre_state = worker.snapshot()
    pre_version = acc.registry.get("s").version
    # cripple the gate: an envelope no 3-class model can fit
    controller.compressor = Compressor(plan=dataclasses.replace(
        plan, class_capacity=1,
    ))
    with pytest.raises(CapacityExceeded):
        controller.recalibrate(reason="test")
    assert np.array_equal(worker.snapshot(), pre_state)
    assert acc.registry.get("s").version == pre_version


def test_compile_refuses_oversized_model():
    rng = np.random.default_rng(7)
    _, _, small = _random_model(rng, 3, 6, 24)
    _, _, big = _random_model(rng, 12, 6, 24)
    plan = dataclasses.replace(
        CapacityPlan.for_models([small]), instruction_capacity=8192
    )
    acc = Accelerator(plan, engine="plan")
    with pytest.raises(CapacityExceeded) as ei:
        acc.compile(big)
    assert ei.value.knob == "class_capacity"
    assert ei.value.required == 12


# ---------------------------------------------------------------------------
# engine plugin protocol
# ---------------------------------------------------------------------------

def test_engine_auto_selection_is_deterministic():
    plan = CapacityPlan(
        instruction_capacity=512, feature_capacity=64, class_capacity=8,
        clause_capacity=16, include_capacity=16, batch_words=1,
    )
    # no mesh: the fastest mesh-free engine, stable across calls
    assert select_engine(plan) == "popcount"
    assert all(select_engine(plan) == "popcount" for _ in range(5))
    # a mesh makes the mesh-consuming plugin the eligible set
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert select_engine(plan, mesh=mesh) == "sharded"
    acc = Accelerator(plan)
    assert acc.engine.name == "popcount"
    assert acc.engine.supports_donation
    assert Accelerator(plan, engine="interp").engine.name == "interp"


def test_register_engine_rejects_name_collisions():
    with pytest.raises(ValueError, match="already registered"):
        @register_engine("popcount")
        class Impostor(EngineBase):
            pass
    assert ENGINES["popcount"].__name__ == "PopcountEngine"


def test_make_engine_uniform_construction_and_options():
    plan = CapacityPlan(
        instruction_capacity=256, feature_capacity=32, class_capacity=4,
        clause_capacity=8, include_capacity=8, batch_words=1,
    )
    eng = make_engine("popcount", plan, implementation="xla")
    assert eng.implementation == "xla"
    # instance passthrough
    assert make_engine(eng, plan) is eng
    with pytest.raises(ValueError, match="unknown engine"):
        make_engine("fpga", plan)
    # capability flags live on the classes
    assert ENGINES["sharded"].needs_mesh
    assert not ENGINES["plan"].needs_mesh
    assert ENGINES["popcount"].supports_donation


def test_donation_warning_suppression_is_scoped_to_dispatch():
    """The donating engine must not leave donation-warning suppression
    in the process-global filter list after a call (the old module-level
    filterwarnings bug): the filter set is bit-identical before and
    after an engine dispatch."""
    rng = np.random.default_rng(11)
    cfg, acts, model = _random_model(rng, 3, 6, 24)
    acc = Accelerator.for_models([model], engine="popcount", batch_words=1)
    acc.load("m", acc.compile(model))
    before = list(warnings.filters)
    X = rng.integers(0, 2, (5, 24)).astype(np.uint8)
    assert (acc.class_sums("m", X) == _oracle_sums(cfg, acts, X)).all()
    assert warnings.filters == before


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_hot_swap_differently_sized_models_one_plan(engine):
    """Acceptance: differently-sized models hot-swap within ONE
    negotiated plan with compile_cache_size() == 1 throughout."""
    rng = np.random.default_rng(8)
    shapes = [(5, 12, 40), (3, 8, 24), (7, 10, 56)]
    trained = [_random_model(rng, *s) for s in shapes]
    acc = Accelerator.for_models(
        [m for _, _, m in trained], engine=engine, batch_words=2
    )
    for cfg, acts, model in trained:
        acc.load("slot", acc.compile(model))
        X = rng.integers(0, 2, (21, cfg.n_features)).astype(np.uint8)
        assert (
            acc.infer("slot", X) == _oracle_sums(cfg, acts, X).argmax(1)
        ).all()
    assert acc.compile_cache_size() == 1
    assert acc.registry.get("slot").version == len(shapes)


# ---------------------------------------------------------------------------
# registry satellites: history depth + rollback provenance chain
# ---------------------------------------------------------------------------

def _tiny_models(n, seed=9):
    rng = np.random.default_rng(seed)
    return [_random_model(rng, 3, 4, 8, density=0.2)[2] for _ in range(n)]


def test_registry_history_depth_is_constructor_argument():
    plan = CapacityPlan(
        instruction_capacity=64, feature_capacity=16, class_capacity=4,
        clause_capacity=4, include_capacity=4, batch_words=1,
    )
    models = _tiny_models(5)
    for depth in (1, 3):
        reg = ModelRegistry(make_engine("plan", plan), history_depth=depth)
        for m in models:
            reg.install("s", m)
        assert len(reg.history("s")) == depth
    with pytest.raises(ValueError, match="history_depth"):
        ModelRegistry(make_engine("plan", plan), history_depth=0)
    server = TMServer(plan, backend="plan", history_depth=2)
    for m in models:
        server.register("s", m)
    assert len(server.registry.history("s")) == 2


def test_rollback_of_rollback_records_full_chain():
    plan = CapacityPlan(
        instruction_capacity=64, feature_capacity=16, class_capacity=4,
        clause_capacity=4, include_capacity=4, batch_words=1,
    )
    server = TMServer(plan, backend="plan")
    m1, m2, m3 = _tiny_models(3, seed=10)
    server.register("s", m1, provenance="deploy")          # v1
    server.register("s", m2, provenance="recal:drift")     # v2
    e3 = server.rollback("s")                              # v3 = m1
    assert e3.provenance == "rollback:v2->v1(deploy)"
    server.register("s", m3, provenance="recal:retry")     # v4
    e5 = server.rollback("s")                              # v5 = v3 entry
    # the chain survives: rolling back to a rollback shows BOTH hops
    assert e5.provenance == "rollback:v4->v3(rollback:v2->v1(deploy))"
    assert e5.model is m1
    assert e5.version == 5
