"""Roofline-analysis machinery tests."""


from repro.analysis.corrections import scan_correction_flops
from repro.analysis.roofline import (
    HBM_BW,
    PEAK_FLOPS,
    build_roofline,
    collective_bytes,
    model_flops,
)
from repro.configs.base import DECODE_32K, PREFILL_32K, TRAIN_4K
from repro.configs.registry import get
from repro.models.api import active_params, count_params


def test_collective_parser_async_pairs_counted_once():
    hlo = """
  %p0 = bf16[256,512]{1,0} parameter(0)
  %ar-start = bf16[256,512]{1,0} all-reduce-start(%p0), channel_id=1
  %ar-done = bf16[256,512]{1,0} all-reduce-done(%ar-start)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 512 * 2  # start counted, done not


def test_collective_parser_tuple_allreduce():
    hlo = """
  %a = f32[16,16]{1,0} parameter(0)
  %b = f32[8]{0} parameter(1)
  %ar = (f32[16,16]{1,0}, f32[8]{0}) all-reduce(%a, %b), channel_id=3
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == (16 * 16 + 8) * 4


def test_model_flops_shapes():
    cfg = get("stablelm-3b")
    n = active_params(cfg)
    t = model_flops(cfg, TRAIN_4K, n)
    p = model_flops(cfg, PREFILL_32K, n)
    d = model_flops(cfg, DECODE_32K, n)
    assert t == 6.0 * n * 256 * 4096
    assert p == 2.0 * n * 32 * 32768
    assert d == 2.0 * n * 128


def test_moe_active_params_smaller():
    cfg = get("llama4-maverick-400b-a17b")
    total = count_params(cfg)
    act = active_params(cfg)
    assert total > 300e9  # ~400B-class
    assert act < 0.1 * total  # top-1 of 128 experts


def test_corrections_zero_for_decode_and_short_seq():
    cfg = get("starcoder2-7b")
    assert scan_correction_flops(cfg, DECODE_32K) == 0.0
    assert scan_correction_flops(cfg, TRAIN_4K) > 0.0


def test_build_roofline_terms():
    rl = build_roofline(
        arch="x", shape="train_4k", mesh_name="m", chips=256,
        cost={"flops": 1e12, "bytes accessed": 1e9},
        hlo_text="%ar = f32[1000]{0} all-reduce(%ar)",
        model_flops_global=2.56e14,
    )
    assert abs(rl.t_compute - 1e12 / PEAK_FLOPS) < 1e-12
    assert abs(rl.t_memory - 1e9 / HBM_BW) < 1e-12
    assert rl.t_collective > 0
    assert rl.bottleneck in ("compute", "memory", "collective")
    assert 0 < rl.useful_flops_ratio <= 1.1
