"""Sharded TM executor variants: all must reproduce dense TM inference
exactly (single-shard semantics tested here; mesh partitioning is covered
by test_sharding_dryrun.py)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.dist.tm_sharded as tms
from repro.core import TMConfig, batch_class_sums, pack_literals
from repro.core.compress import decode_to_plan, encode


@pytest.fixture(scope="module")
def case():
    rng = np.random.default_rng(5)
    cfg = TMConfig(n_classes=4, n_clauses=10, n_features=30)
    acts = rng.random((4, 10, 60)) < 0.25  # dense enough to span chunks
    X = rng.integers(0, 2, (64, 30)).astype(np.uint8)
    state = jnp.where(jnp.asarray(acts), cfg.n_states + 1, cfg.n_states)
    oracle = np.asarray(batch_class_sums(cfg, state, jnp.asarray(X)))
    plan = decode_to_plan(encode(cfg, np.asarray(acts)))
    return cfg, acts, X, oracle, plan


def _operands(plan, chunk):
    n_inc = plan.n_includes
    I_cap = -(-n_inc // chunk) * chunk
    lit_idx = np.zeros(I_cap, np.int32)
    lit_idx[:n_inc] = plan.lit_idx
    seg_last = np.zeros(I_cap, np.int32)
    seg_last[:n_inc][
        np.concatenate([plan.clause_id[1:] != plan.clause_id[:-1], [True]])
    ] = 1
    cid = np.full(I_cap, plan.n_clauses_total, np.int32)
    cid[:n_inc] = plan.clause_id
    return lit_idx, seg_last, cid


def test_unpacked_executor(case, monkeypatch):
    monkeypatch.setattr(tms, "CHUNK", 16)  # force chunk-spanning clauses
    cfg, acts, X, oracle, plan = case
    lit_idx, _, cid = _operands(plan, 16)
    lits = np.asarray(
        jax.vmap(lambda r: jnp.stack([r, ~r], -1).reshape(-1))(
            jnp.asarray(X, bool)
        )
    ).astype(np.int8)
    sums = np.asarray(
        tms._local_plan_executor(
            jnp.asarray(lit_idx), jnp.asarray(cid),
            jnp.asarray(plan.clause_class), jnp.asarray(plan.clause_pol),
            jnp.asarray(lits),
        )
    )
    assert (sums[: cfg.n_classes, :64].T == oracle).all()


def test_packed_executor(case, monkeypatch):
    monkeypatch.setattr(tms, "CHUNK", 16)
    cfg, acts, X, oracle, plan = case
    lit_idx, seg_last, _ = _operands(plan, 16)
    packed = pack_literals(jnp.asarray(X))
    sums = np.asarray(
        tms._local_plan_executor_packed(
            jnp.asarray(lit_idx), jnp.asarray(seg_last),
            jnp.asarray(plan.clause_class), jnp.asarray(plan.clause_pol),
            packed,
        )
    )
    assert (sums[: cfg.n_classes, :64].T == oracle).all()


def test_clausemajor_executor(case):
    cfg, acts, X, oracle, plan = case
    NCL = plan.n_clauses_total
    Lc = int(max((plan.clause_id == c).sum() for c in range(NCL)))
    pad_idx = np.full((NCL, Lc), 2 * cfg.n_features, np.int32)  # ones row
    for c in range(NCL):
        ks = plan.lit_idx[plan.clause_id == c]
        pad_idx[c, : len(ks)] = ks
    packed = np.asarray(pack_literals(jnp.asarray(X)))
    packed1 = np.concatenate(
        [packed, np.full((1, packed.shape[1]), 0xFFFFFFFF, np.uint32)]
    )
    sums = np.asarray(
        tms._local_plan_executor_clausemajor(
            jnp.asarray(pad_idx), jnp.asarray(plan.clause_class),
            jnp.asarray(plan.clause_pol), jnp.asarray(packed1),
        )
    )
    assert (sums[: cfg.n_classes, :64].T == oracle).all()


def test_moe_ep_matches_plain():
    """shard_map EP MoE == plain MoE (single-device degenerate mesh)."""
    import dataclasses

    from repro.configs.registry import get
    from repro.dist import sharding as shd
    from repro.models import moe

    cfg = dataclasses.replace(
        get("moonshot-v1-16b-a3b-smoke"), n_experts=4, top_k=2
    )
    rng = np.random.default_rng(0)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": jnp.asarray(rng.normal(size=(D, E)), jnp.float32),
        "w_gate": jnp.asarray(rng.normal(size=(E, D, F)) * 0.05, jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(E, D, F)) * 0.05, jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(E, F, D)) * 0.05, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(2, 16, D)), jnp.float32)
    shd.set_activation_mesh(None)
    y_plain = moe.moe_ffn(p, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shd.set_activation_mesh(mesh)
    try:
        with mesh:
            y_ep = jax.jit(lambda pp, xx: moe.moe_ffn(pp, xx, cfg))(p, x)
    finally:
        shd.set_activation_mesh(None)
    assert float(jnp.max(jnp.abs(y_plain - y_ep))) < 1e-5
