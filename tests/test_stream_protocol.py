"""uint16 stream-protocol hardening: header field validation at the wire
boundaries, parse_header round-trips for both packet types, and the
capacity guards of pack_features / MultiCoreAccelerator."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import CompressedModel, encode
from repro.core.interp import pack_features
from repro.core.runtime import (
    PAYLOAD_MASK,
    Accelerator,
    AcceleratorConfig,
    MultiCoreAccelerator,
    build_feature_stream,
    build_instruction_stream,
    parse_header,
)


def _model(n_instructions=8, n_classes=4, n_clauses=10, n_features=50):
    return CompressedModel(
        instructions=np.zeros(n_instructions, np.uint16),
        n_classes=n_classes, n_clauses=n_clauses, n_features=n_features,
    )


def _dense_argmax(cfg, acts, X):
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    ).argmax(1)


# ---------------------------------------------------------------------------
# header round-trips (both packet types)
# ---------------------------------------------------------------------------

def test_instruction_header_roundtrip():
    stream = build_instruction_stream(_model(n_classes=9, n_clauses=33))
    reset, is_instr, payload, w1, count = parse_header(stream)
    assert reset and is_instr and payload == 9 and w1 == 33 and count == 8


def test_feature_header_roundtrip():
    X = np.zeros((5, 40), np.uint8)
    reset, is_instr, payload, w1, count = parse_header(build_feature_stream(X))
    assert reset and not is_instr
    assert payload == 40 and w1 == 5 and count == 5 * 3  # ceil(40/16) words


def test_instruction_count_crosses_word_split():
    """count > 65535 spans header words 2 and 3."""
    stream = build_instruction_stream(_model(n_instructions=70000))
    _, is_instr, _, _, count = parse_header(stream)
    assert is_instr and count == 70000
    assert int(stream[2]) == 70000 & 0xFFFF and int(stream[3]) == 70000 >> 16


def test_feature_count_crosses_word_split():
    # 4100 datapoints x 17 features -> 2 words each -> 8200 words > 65535? no;
    # use 40000 x 2 words = 80000 words, crossing the 16-bit split
    X = np.zeros((40000, 17), np.uint8)
    _, is_instr, payload, w1, count = parse_header(build_feature_stream(X))
    assert not is_instr and payload == 17 and w1 == 40000 and count == 80000


# ---------------------------------------------------------------------------
# wire-width validation (no silent wraparound)
# ---------------------------------------------------------------------------

def test_instruction_stream_boundary_values():
    # at the boundary: fits exactly, round-trips exactly
    stream = build_instruction_stream(
        _model(n_classes=PAYLOAD_MASK, n_clauses=0xFFFF)
    )
    _, _, payload, w1, _ = parse_header(stream)
    assert payload == PAYLOAD_MASK == 16383 and w1 == 0xFFFF == 65535


def test_instruction_stream_overflow_raises():
    with pytest.raises(ValueError, match="n_classes"):
        build_instruction_stream(_model(n_classes=PAYLOAD_MASK + 1))
    with pytest.raises(ValueError, match="n_clauses"):
        build_instruction_stream(_model(n_clauses=0x10000))


def test_feature_stream_boundary_values():
    X = np.zeros((0xFFFF, 4), np.uint8)  # 65535 datapoints round-trip
    _, _, payload, w1, count = parse_header(build_feature_stream(X))
    assert payload == 4 and w1 == 0xFFFF and count == 0xFFFF


def test_feature_stream_overflow_raises():
    with pytest.raises(ValueError, match="n_datapoints"):
        build_feature_stream(np.zeros((0x10000, 4), np.uint8))
    with pytest.raises(ValueError, match="n_features"):
        build_feature_stream(np.zeros((1, PAYLOAD_MASK + 1), np.uint8))


# ---------------------------------------------------------------------------
# payload edge cases through the full accelerator
# ---------------------------------------------------------------------------

@pytest.fixture
def acc():
    return Accelerator(AcceleratorConfig(
        instruction_capacity=2048, feature_capacity=64, class_capacity=8,
        batch_words=1,
    ))


def test_feature_payload_f_multiple_of_16(acc):
    """F % 16 == 0: the packed payload has no slack bits."""
    rng = np.random.default_rng(0)
    F = 32
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=F)
    acts = rng.random((3, 8, 2 * F)) < 0.1
    X = rng.integers(0, 2, (20, F)).astype(np.uint8)
    acc.feed(build_instruction_stream(encode(cfg, acts)))
    preds = acc.feed(build_feature_stream(X))
    assert (preds[:20] == _dense_argmax(cfg, acts, X)).all()


def test_feature_payload_single_datapoint(acc):
    """B == 1: one partial word, 31 padded lanes."""
    rng = np.random.default_rng(1)
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=20)
    acts = rng.random((3, 8, 40)) < 0.1
    X = rng.integers(0, 2, (1, 20)).astype(np.uint8)
    acc.feed(build_instruction_stream(encode(cfg, acts)))
    preds = acc.feed(build_feature_stream(X))
    assert preds[0] == _dense_argmax(cfg, acts, X)[0]


# ---------------------------------------------------------------------------
# capacity guards with actionable messages
# ---------------------------------------------------------------------------

def test_pack_features_capacity_errors():
    X = jnp.zeros((8, 100), jnp.uint8)
    with pytest.raises(ValueError, match="feature_capacity"):
        pack_features(X, 64, 1)
    with pytest.raises(ValueError, match="batch_words"):
        pack_features(jnp.zeros((40, 16), jnp.uint8), 64, 1)


def test_multicore_infer_without_model():
    mc = MultiCoreAccelerator(2, AcceleratorConfig(
        instruction_capacity=256, feature_capacity=32, class_capacity=8,
        batch_words=1,
    ))
    with pytest.raises(RuntimeError, match="no model loaded"):
        mc.infer(np.zeros((4, 16), np.uint8))
