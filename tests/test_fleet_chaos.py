"""The fleet's failure model: deterministic chaos injection, the
per-node circuit breaker (healthy → degraded → quarantined → half-open
probe → healthy), retry/backoff under a hard deadline budget, structured
engine faults, and failure-aware rollouts/teardown — all under injected
clocks, never wall-clock sleeps."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import TMConfig, batch_class_sums, state_from_actions
from repro.core.compress import encode
from repro.accel import CapacityPlan, TMProgram
from repro.fleet import (
    ChaosNode,
    FleetHealth,
    FleetPool,
    NodeDown,
    NoEligibleNode,
    RetryPolicy,
    RolloutAborted,
    RolloutManager,
    Router,
)
from repro.serve_tm import EngineFault, TMServer
from repro.serve_tm.schema import HEALTH_NODE_KEYS, HEALTH_STATES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CAP = CapacityPlan(
    instruction_capacity=1024, feature_capacity=128, class_capacity=16,
    clause_capacity=32, include_capacity=24, batch_words=2,
)


def _random_model(rng, M, C, F, density=0.05):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def _oracle_sums(cfg, acts, X):
    return np.asarray(
        batch_class_sums(cfg, state_from_actions(cfg, acts), jnp.asarray(X))
    )


def _program(model, cap=CAP):
    return TMProgram(capacity=cap, model=model)


class _FakeTime:
    """One injectable clock for the breaker, the policy and its sleeps."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []  # (clock at sleep, requested duration)

    def clock(self):
        return self.t

    def sleep(self, d):
        self.sleeps.append((self.t, d))
        self.t += d


class _StubNode:
    """Minimal structural ServingNode whose submit always fails —
    drives the retry loop without touching an engine."""

    def __init__(self, advance=None):
        self.calls = 0
        self.scheduler_running = False
        self.capacity = CAP
        self._advance = advance  # simulated per-call service cost

    def submit(self, slot, x, *, priority="normal", timeout_ms=None):
        self.calls += 1
        if self._advance is not None:
            self._advance()
        raise RuntimeError("stub node always fails")

    async def async_submit(self, slot, x, *, priority="normal",
                           timeout_ms=None):
        return self.submit(slot, x, priority=priority, timeout_ms=timeout_ms)

    def flush(self):
        pass

    def infer(self, slot, x):
        return self.submit(slot, x)

    def class_sums(self, slot, x):
        raise RuntimeError("stub")

    def start(self):
        pass

    def stop(self, drain=True):
        pass

    def register(self, slot, model, provenance="install"):
        pass

    def rollback(self, slot):
        pass

    def validate_model(self, model):
        pass

    def queue_depth(self, slot=None, priority=None):
        return 0

    def metrics_snapshot(self):
        return {}

    def slots(self):
        return ["m"]

    def installed_checksum(self, slot):
        return 0

    def installed_artifact(self, slot):
        return None

    def compile_cache_size(self):
        return 1


# -- RetryPolicy: the deadline budget rule -----------------------------------


def test_retry_policy_validation_and_backoff_shape():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="multiplier"):
        RetryPolicy(backoff_multiplier=0.5)
    p = RetryPolicy(backoff_base_s=0.01, backoff_multiplier=2.0,
                    backoff_max_s=0.05)
    assert [p.backoff_s(i) for i in range(5)] == [
        0.01, 0.02, 0.04, 0.05, 0.05,  # exponential, capped
    ]


def test_retry_policy_deadline_budget_property():
    """Property: against an always-failing node, the router never tries
    more than max_attempts, every backoff sleep fits inside the
    remaining deadline budget, and the backoff sequence is exactly the
    policy's capped exponential — all under simulated time."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    x = np.zeros((1, 4), np.uint8)

    @given(
        max_attempts=st.integers(1, 6),
        base_ms=st.floats(0.1, 50.0),
        mult=st.floats(1.0, 4.0),
        cap_ms=st.floats(0.1, 100.0),
        timeout_ms=st.one_of(st.none(), st.floats(0.1, 300.0)),
        call_cost_ms=st.floats(0.0, 30.0),
    )
    @settings(max_examples=60, deadline=None)
    def check(max_attempts, base_ms, mult, cap_ms, timeout_ms, call_cost_ms):
        ft = _FakeTime()

        def advance():
            ft.t += call_cost_ms / 1e3

        node = _StubNode(advance=advance)
        pool = FleetPool({"a": node})
        # thresholds pushed out of reach: this property is about the
        # policy arithmetic, not the breaker
        health = FleetHealth(
            pool=pool, clock=ft.clock, consecutive_failures=10 ** 9,
            min_window=10 ** 9, probe_after_s=1e9,
        )
        retry = RetryPolicy(
            max_attempts=max_attempts, backoff_base_s=base_ms / 1e3,
            backoff_multiplier=mult, backoff_max_s=cap_ms / 1e3,
            sleep=ft.sleep, clock=ft.clock,
        )
        router = Router(pool, health=health, retry=retry)
        with pytest.raises(RuntimeError, match="stub node always fails"):
            router.submit("m", x, timeout_ms=timeout_ms)
        assert 1 <= node.calls <= max_attempts
        if timeout_ms is None:
            # no deadline: the full attempt budget is spent, with one
            # backoff between each single-candidate sweep
            assert node.calls == max_attempts
            assert len(ft.sleeps) == max_attempts - 1
        else:
            deadline = timeout_ms / 1e3  # stamped at t=0
            for at, d in ft.sleeps:
                assert at + d < deadline  # never sleeps past the budget
        for i, (_, d) in enumerate(ft.sleeps):
            assert d == pytest.approx(retry.backoff_s(i))

    check()


# -- the circuit breaker ------------------------------------------------------


class _FlakySubmit(TMServer):
    """A real node whose submit fails on demand (the engine is fine)."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.failing = False
        self.calls = 0

    def submit(self, slot, x, **kw):
        self.calls += 1
        if self.failing:
            raise RuntimeError("transient engine fault")
        return super().submit(slot, x, **kw)


def test_breaker_full_cycle_quarantine_probe_recover_under_fake_clock():
    """healthy → degraded → quarantined → (cooldown) → half-open probe →
    healthy, and the probe-failure edge back to quarantined — all
    transitions driven through the ROUTER, no wall-clock."""
    rng = np.random.default_rng(30)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    bad = _FlakySubmit(CAP, engine="interp")
    ok = TMServer(CAP, engine="plan")
    for node in (bad, ok):
        node.register("m", art)
    pool = FleetPool({"bad": bad, "ok": ok})
    ft = _FakeTime()
    health = FleetHealth(
        pool=pool, consecutive_failures=2, probe_after_s=5.0,
        heartbeat_timeout_s=1e9, clock=ft.clock,
    )
    router = Router(pool, health=health,
                    retry=RetryPolicy(sleep=ft.sleep, clock=ft.clock))
    x = rng.integers(0, 2, (4, 32)).astype(np.uint8)

    bad.failing = True
    assert router.submit("m", x).routed_to == "ok"
    assert health.state("bad") == "degraded"
    assert router.submit("m", x).routed_to == "ok"
    assert health.state("bad") == "quarantined"  # consecutive threshold

    # quarantined + cooldown not elapsed: the node is not even tried
    calls = bad.calls
    assert router.submit("m", x).routed_to == "ok"
    assert bad.calls == calls

    # cooldown elapses, the node healed: ONE half-open probe closes the
    # breaker and the probe request itself is served there
    ft.t += 5.0
    bad.failing = False
    h = router.submit("m", x)
    assert h.routed_to == "bad"
    assert health.state("bad") == "healthy"
    assert health.summary()["bad"]["probes"] == 1

    # the probe-failure edge: re-quarantined, cooldown restamped
    bad.failing = True
    router.submit("m", x)
    router.submit("m", x)
    assert health.state("bad") == "quarantined"
    ft.t += 5.0
    assert health.probe_due("bad")
    assert router.submit("m", x).routed_to == "ok"  # probe fails over
    assert health.state("bad") == "quarantined"
    assert not health.probe_due("bad")  # cooldown restarted
    assert health.summary()["bad"]["probes"] == 2
    assert health.summary()["bad"]["quarantines"] == 3
    # the router mirrored failovers into the serving node's own metrics
    assert ok.metrics.failovers > 0


def test_router_all_quarantined_raises_structured_no_eligible_node():
    node = _StubNode()
    pool = FleetPool({"a": node})
    health = FleetHealth(pool=pool, probe_after_s=1e9)
    health.quarantine("a", reason="manual")
    router = Router(pool, health=health,
                    retry=RetryPolicy(sleep=lambda d: None))
    with pytest.raises(NoEligibleNode, match="quarantined or unreachable"):
        router.submit("m", np.zeros((1, 4), np.uint8))
    assert node.calls == 0


def test_heartbeat_sweep_quarantines_silent_nodes():
    ft = _FakeTime()
    health = FleetHealth(heartbeat_timeout_s=10.0, clock=ft.clock)
    health.record_success("a")
    health.record_success("b")
    ft.t = 5.0
    health.record_success("a")  # a keeps beating, b goes silent
    ft.t = 12.0
    assert health.sweep() == ["b"]
    assert health.state("b") == "quarantined"
    assert health.state("a") == "healthy"
    assert health.sweep() == []  # already quarantined: not re-flagged


def test_straggler_evict_quarantines_slow_node():
    """A node that still answers but far slower than its own history is
    routed around like a dead one (supervisor's StragglerMonitor)."""
    health = FleetHealth(consecutive_failures=10 ** 9)
    for _ in range(8):
        health.record_success("slow", latency_s=0.01)
    assert health.state("slow") == "healthy"
    n = 0
    while health.state("slow") != "quarantined" and n < 30:
        health.record_success("slow", latency_s=5.0)
        n += 1
    assert health.state("slow") == "quarantined"
    assert health.summary()["slow"]["quarantines"] == 1


def test_health_summary_matches_schema():
    health = FleetHealth()
    health.record_success("a", latency_s=0.01)
    health.record_failure("b", RuntimeError("x"))
    health.record_overload("a")
    summary = health.summary()
    assert list(summary) == ["a", "b"]
    for d in summary.values():
        assert tuple(d.keys()) == HEALTH_NODE_KEYS
        assert d["state"] in HEALTH_STATES
    assert summary["a"]["overloads"] == 1
    assert summary["b"]["consecutive_failures"] == 1


# -- ChaosNode ----------------------------------------------------------------


def _chaos_server(art, engine="interp", **chaos_kw):
    inner = TMServer(CAP, engine=engine)
    inner.register("m", art)
    chaos_kw.setdefault("sleep", lambda d: None)
    return inner, ChaosNode(inner, **chaos_kw)


def _drive(chaos, x, n_ops):
    """A fixed op script; faults are swallowed, the schedule advances."""
    for i in range(n_ops):
        op = ("submit", "infer", "flush")[i % 3]
        try:
            if op == "submit":
                chaos.submit("m", x)
            elif op == "infer":
                chaos.infer("m", x)
            else:
                chaos.flush()
        except Exception:
            pass


def test_chaos_same_seed_replays_identical_fault_schedule():
    rng = np.random.default_rng(40)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    x = rng.integers(0, 2, (3, 32)).astype(np.uint8)
    rates = dict(error_rate=0.2, latency_rate=0.15, latency_s=0.0,
                 overload_rate=0.15, hang_rate=0.1)
    logs = []
    for seed in (7, 7, 8):
        _, chaos = _chaos_server(art, seed=seed, **rates)
        _drive(chaos, x, 40)
        logs.append(list(chaos.fault_log))
    assert logs[0] == logs[1]        # same seed -> identical schedule
    assert logs[0] != logs[2]        # different seed -> different storm
    faults = {f for _, _, f in logs[0]}
    assert faults - {"ok"}           # the storm actually injected faults


def test_chaos_hung_handle_resolved_by_kill_then_revive():
    rng = np.random.default_rng(41)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    inner, chaos = _chaos_server(art, name="h", seed=3, hang_rate=1.0)
    x = rng.integers(0, 2, (4, 32)).astype(np.uint8)
    h = chaos.submit("m", x)
    with pytest.raises(TimeoutError):
        h.wait(timeout=0.05)  # hung: the node accepted, then went silent
    assert h.status == "pending"
    chaos.kill()
    assert h.failed and h.status == "failed"
    with pytest.raises(NodeDown):
        h.result()
    with pytest.raises(NodeDown):
        chaos.submit("m", x)
    with pytest.raises(NodeDown):
        chaos.queue_depth()
    assert chaos.down and not chaos.scheduler_running
    chaos.revive()
    chaos.rates["hang"] = 0.0
    h2 = chaos.submit("m", x)
    chaos.flush()
    assert (h2.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()


def test_chaos_corrupted_artifact_rejected_by_crc():
    """A bit-flipped TMProgram on the wire NEVER reaches a live
    accelerator: the CRC-32 integrity check rejects it on install."""
    rng = np.random.default_rng(42)
    _, _, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    inner = TMServer(CAP)
    chaos = ChaosNode(inner, seed=0, corrupt_rate=1.0)
    with pytest.raises(ValueError, match="checksum mismatch"):
        chaos.register("m", art)
    assert "m" not in inner.slots()  # the registry was never touched


def test_chaos_down_after_ops_is_deterministic():
    rng = np.random.default_rng(43)
    _, _, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    x = rng.integers(0, 2, (2, 32)).astype(np.uint8)
    _, chaos = _chaos_server(art, seed=0, down_after_ops=3)
    chaos.submit("m", x)
    chaos.submit("m", x)
    chaos.flush()  # op 3: the last one served
    with pytest.raises(NodeDown):
        chaos.submit("m", x)
    assert chaos.fault_log[-1] == (4, "submit", "down")


# -- routing under faults -----------------------------------------------------


def test_router_failover_bit_exact_across_heterogeneous_engines():
    """A failed-over request returns predictions AND class sums
    identical to the dense oracle even when the healthy replica runs a
    different engine than the one that failed."""
    rng = np.random.default_rng(50)
    cfg, acts, model = _random_model(rng, 5, 12, 40)
    art = _program(model)
    flaky_inner, flaky = _chaos_server(art, engine="interp",
                                       name="flaky", seed=5, error_rate=1.0)
    ok = TMServer(CAP, engine="popcount")
    ok.register("m", art)
    pool = FleetPool({"flaky": flaky, "ok": ok})
    health = FleetHealth(pool=pool, consecutive_failures=3,
                         probe_after_s=1e6)
    router = Router(pool, health=health,
                    retry=RetryPolicy(sleep=lambda d: None))
    handles = []
    for _ in range(3):
        x = rng.integers(0, 2, (6, 40)).astype(np.uint8)
        h = router.submit("m", x)
        assert h.routed_to == "ok"
        handles.append((h, x))
    assert health.state("flaky") == "quarantined"
    # the breaker event was mirrored into the node's own metrics
    assert flaky_inner.metrics.quarantines == 1
    assert ok.metrics.failovers == 3
    ok.flush()
    for h, x in handles:
        want = _oracle_sums(cfg, acts, x)
        assert (h.result() == want.argmax(1)).all()
        assert np.array_equal(np.asarray(h.class_sums), want)


class _FailsOnce(TMServer):
    """First submit (sync or async) raises; every later one serves."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.failures_left = 1

    def _maybe_fail(self):
        if self.failures_left > 0:
            self.failures_left -= 1
            raise RuntimeError("transient")

    def submit(self, slot, x, **kw):
        self._maybe_fail()
        return super().submit(slot, x, **kw)

    async def async_submit(self, slot, x, **kw):
        self._maybe_fail()
        return await super().async_submit(slot, x, **kw)


def test_router_retry_after_backoff_serves_bit_exact():
    """A single-node fleet whose node fails once: the router backs off,
    re-sweeps, and the RETRIED request is served bit-exact; the node's
    metrics record the retry."""
    rng = np.random.default_rng(51)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    node = _FailsOnce(CAP, engine="plan")
    node.register("m", _program(model))
    pool = FleetPool({"only": node})
    ft = _FakeTime()
    health = FleetHealth(pool=pool, consecutive_failures=5, clock=ft.clock)
    retry = RetryPolicy(max_attempts=3, backoff_base_s=0.01,
                        sleep=ft.sleep, clock=ft.clock)
    router = Router(pool, health=health, retry=retry)
    x = rng.integers(0, 2, (5, 32)).astype(np.uint8)
    h = router.submit("m", x)
    assert h.routed_to == "only"
    assert ft.sleeps == [(0.0, 0.01)]  # exactly one backoff sweep
    assert node.metrics.retries == 1
    node.flush()
    want = _oracle_sums(cfg, acts, x)
    assert (h.result() == want.argmax(1)).all()
    assert np.array_equal(np.asarray(h.class_sums), want)


def test_router_async_retry_with_injected_sleep():
    import asyncio

    rng = np.random.default_rng(52)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    node = _FailsOnce(CAP, engine="interp")
    node.register("m", _program(model))
    pool = FleetPool({"only": node})
    ft = _FakeTime()
    health = FleetHealth(pool=pool, consecutive_failures=5, clock=ft.clock)
    retry = RetryPolicy(max_attempts=3, backoff_base_s=0.02,
                        sleep=ft.sleep, clock=ft.clock)
    router = Router(pool, health=health, retry=retry)
    x = rng.integers(0, 2, (5, 32)).astype(np.uint8)
    h = asyncio.run(router.async_submit("m", x))
    assert h.routed_to == "only"
    assert ft.sleeps == [(0.0, 0.02)]  # injected sleep, not asyncio's
    node.flush()
    assert (h.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()


def test_router_routes_around_dead_node_and_quarantines_it():
    """A node that dies outright (introspection raises NodeDown) is
    skipped by candidates, recorded as failing, and quarantined."""
    rng = np.random.default_rng(53)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    _, dead = _chaos_server(art, engine="interp", name="d", seed=0)
    ok = TMServer(CAP, engine="plan")
    ok.register("m", art)
    pool = FleetPool({"d": dead, "ok": ok})
    health = FleetHealth(pool=pool, consecutive_failures=3,
                         probe_after_s=1e6)
    router = Router(pool, health=health,
                    retry=RetryPolicy(sleep=lambda d: None))
    dead.kill()
    x = rng.integers(0, 2, (4, 32)).astype(np.uint8)
    for _ in range(3):
        assert router.submit("m", x).routed_to == "ok"
    assert health.state("d") == "quarantined"


# -- structured engine faults -------------------------------------------------


def test_scheduler_engine_fault_fails_handles_and_loop_survives():
    """A raising batch body fails its requests with EngineFault (slot +
    cause) instead of stranding them; the slot serves again once the
    engine recovers."""
    rng = np.random.default_rng(60)
    cfg, acts, model = _random_model(rng, 4, 10, 32)
    server = TMServer(CAP, engine="plan")
    server.register("m", model)
    x = rng.integers(0, 2, (6, 32)).astype(np.uint8)
    h = server.submit("m", x)
    real = server.executor

    class _Boom:
        def __getattr__(self, name):
            return getattr(real, name)  # staging etc. still work

        def class_sums(self, prog, xx):
            raise RuntimeError("device fell off the bus")

    server.executor = _Boom()
    server.flush()  # must not raise: the batch body absorbs the fault
    assert h.failed and h.status == "failed"
    with pytest.raises(EngineFault) as ei:
        h.result()
    assert ei.value.slot == "m"
    assert isinstance(ei.value.cause, RuntimeError)
    assert "device fell off the bus" in str(ei.value)
    # recovery: the same server keeps serving after the engine heals
    server.executor = real
    h2 = server.submit("m", x)
    server.flush()
    assert (h2.result() == _oracle_sums(cfg, acts, x).argmax(1)).all()


# -- failure-aware rollouts ---------------------------------------------------


def _three_node_pool(v1, victim_kw):
    """n0/n2 plain, n1 chaos-wrapped (the wave stage's only member)."""
    inners = {}
    for i, eng in enumerate(("interp", "plan", "popcount")):
        inner = TMServer(CAP, engine=eng)
        inner.register("m", v1)
        inners[f"n{i}"] = inner
    victim = ChaosNode(inners["n1"], name="n1", sleep=lambda d: None,
                       **victim_kw)
    pool = FleetPool({"n0": inners["n0"], "n1": victim, "n2": inners["n2"]})
    return inners, victim, pool


def test_rollout_midwave_node_death_quarantines_and_rolls_back_reachable():
    """A node dying mid-wave is a gate failure: the rollback completes
    on every reachable node, the corpse is quarantined and recorded
    unreachable (it keeps the attempted artifact until it returns)."""
    rng = np.random.default_rng(70)
    _, _, m1 = _random_model(rng, 5, 12, 40)
    _, _, m2 = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(m2)
    # op 1 = the wave install (survives), op 2 = the gate submit (dies)
    inners, victim, pool = _three_node_pool(v1, dict(seed=0,
                                                     down_after_ops=1))
    health = FleetHealth(pool=pool)
    X = rng.integers(0, 2, (24, 40)).astype(np.uint8)
    with pytest.raises(RolloutAborted) as ei:
        RolloutManager(pool, health=health).rollout("m", v2, holdout_x=X)
    err = ei.value
    assert err.stage == "wave" and "died during the gate" in err.reason
    assert err.report.rolled_back == ("n0",)
    assert err.report.unreachable == ("n1",)
    # reachable nodes are back on (or never left) the OLD checksum
    assert inners["n0"].installed_checksum("m") == v1.checksum
    assert inners["n0"].registry.get("m").provenance.startswith("rollback:")
    assert inners["n2"].installed_checksum("m") == v1.checksum
    assert "rollout" not in inners["n2"].registry.get("m").provenance
    # the corpse kept the attempted artifact and is quarantined
    assert inners["n1"].installed_checksum("m") == v2.checksum
    assert health.state("n1") == "quarantined"


def test_rollout_corrupt_install_aborts_cleanly_and_quarantines():
    """Corrupted wire bytes die at the node's CRC check BEFORE its
    registry is touched: the stage aborts, the victim still runs the
    old program, the canary is rolled back."""
    rng = np.random.default_rng(71)
    _, _, m1 = _random_model(rng, 5, 12, 40)
    _, _, m2 = _random_model(rng, 5, 12, 40)
    v1, v2 = _program(m1), _program(m2)
    inners, victim, pool = _three_node_pool(v1, dict(seed=0,
                                                     corrupt_rate=1.0))
    health = FleetHealth(pool=pool)
    X = rng.integers(0, 2, (24, 40)).astype(np.uint8)
    with pytest.raises(RolloutAborted) as ei:
        RolloutManager(pool, health=health).rollout("m", v2, holdout_x=X)
    err = ei.value
    assert err.stage == "wave" and "failed install" in err.reason
    assert "checksum mismatch" in err.reason
    assert err.report.rolled_back == ("n0",)
    assert err.report.unreachable == ()  # alive, just fed garbage
    for name in ("n0", "n1", "n2"):
        assert inners[name].installed_checksum("m") == v1.checksum
    assert health.state("n1") == "quarantined"


# -- dead-node-tolerant pool lifecycle ----------------------------------------


def test_pool_remove_and_stop_all_tolerate_dead_nodes():
    rng = np.random.default_rng(80)
    _, _, model = _random_model(rng, 4, 10, 32)
    art = _program(model)
    inner, dead = _chaos_server(art, name="dead", seed=0)
    ok = TMServer(CAP, engine="plan")
    ok.register("m", art)
    pool = FleetPool({"dead": dead, "ok": ok})
    pool.start_all()
    try:
        dead.kill()
        # rollups flag the corpse instead of raising
        ms = pool.metrics_summary()
        assert ms["unreachable"] == ["dead"] and "ok" in ms["nodes"]
        assert pool.queue_depths() == {"ok": 0}
        assert [n for n, _ in pool.nodes_with_slot("m")] == ["ok"]
        # teardown completes; the failure is a recorded warning
        pool.stop_all()
        assert any("dead" in w for w in pool.warnings)
        n_warnings = len(pool.warnings)
        assert pool.remove("dead") is dead
        assert "dead" not in pool
        assert len(pool.warnings) == n_warnings + 1
    finally:
        pool.stop_all()


# -- deprecations -------------------------------------------------------------


def test_gate_timeout_constant_deprecation_fires_once():
    """Reading the deprecated fleet.rollout.GATE_TIMEOUT_S constant
    warns exactly once per process; importing the module stays silent."""
    code = textwrap.dedent(
        """
        import warnings

        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            import repro.fleet.rollout as ro      # import: silent
            v1 = ro.GATE_TIMEOUT_S                # first access: warns
            v2 = ro.GATE_TIMEOUT_S                # cached: silent
        assert v1 == v2 == 120.0
        dep = [
            w for w in rec
            if issubclass(w.category, DeprecationWarning)
            and "GATE_TIMEOUT_S" in str(w.message)
        ]
        assert len(dep) == 1, [str(w.message) for w in rec]
        assert "gate_timeout_s" in str(dep[0].message)
        print("GATE-OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert out.returncode == 0, out.stderr
    assert "GATE-OK" in out.stdout
