"""Stream protocol + Accelerator: the paper's runtime tunability claims."""

import numpy as np
import pytest

from repro.core import TMConfig, batch_class_sums
from repro.core.compress import encode
from repro.core.runtime import (
    Accelerator,
    AcceleratorConfig,
    MultiCoreAccelerator,
    build_feature_stream,
    build_instruction_stream,
    parse_header,
)

import jax.numpy as jnp


def _random_model(rng, M, C, F, density=0.05):
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts


def _dense_sums(cfg, acts, X):
    state = jnp.where(jnp.asarray(acts), cfg.n_states + 1, cfg.n_states)
    return np.asarray(batch_class_sums(cfg, state, jnp.asarray(X)))


@pytest.fixture
def acc():
    return Accelerator(AcceleratorConfig(
        instruction_capacity=4096, feature_capacity=256, class_capacity=16,
        batch_words=1,
    ))


def test_header_roundtrip():
    rng = np.random.default_rng(0)
    cfg, acts = _random_model(rng, 4, 10, 50)
    stream = build_instruction_stream(encode(cfg, acts))
    reset, is_instr, payload, w1, count = parse_header(stream)
    assert reset and is_instr and payload == 4 and w1 == 10


def test_program_and_infer(acc):
    rng = np.random.default_rng(1)
    cfg, acts = _random_model(rng, 4, 10, 50)
    X = rng.integers(0, 2, (32, 50)).astype(np.uint8)
    acc.feed(build_instruction_stream(encode(cfg, acts)))
    preds = acc.feed(build_feature_stream(X))
    assert (preds[:32] == _dense_sums(cfg, acts, X).argmax(1)).all()


def test_zero_recompile_model_swap(acc):
    """THE paper claim: model size, task (classes) and input dimensionality
    all change at runtime with no recompilation (no 'resynthesis')."""
    rng = np.random.default_rng(2)
    cases = [(4, 10, 50), (2, 6, 120), (7, 14, 33), (3, 20, 200)]
    baseline = None
    for (M, C, F) in cases:
        cfg, acts = _random_model(rng, M, C, F)
        X = rng.integers(0, 2, (20, F)).astype(np.uint8)
        acc.feed(build_instruction_stream(encode(cfg, acts)))
        preds = acc.feed(build_feature_stream(X))
        assert (preds[:20] == _dense_sums(cfg, acts, X).argmax(1)).all(), (M, C, F)
        if baseline is None:
            baseline = acc.compile_cache_size()
        else:
            assert acc.compile_cache_size() == baseline, "re-jit on model swap!"
    assert acc.programs_loaded == len(cases)


def test_capacity_guard(acc):
    rng = np.random.default_rng(3)
    cfg, acts = _random_model(rng, 4, 10, 50, density=0.9)  # too many includes
    with pytest.raises(ValueError, match="capacity"):
        big_cfg, big_acts = _random_model(rng, 8, 200, 500, density=0.5)
        acc.feed(build_instruction_stream(encode(big_cfg, big_acts)))


def test_feature_capacity_guard(acc):
    rng = np.random.default_rng(4)
    X = rng.integers(0, 2, (8, 1000)).astype(np.uint8)
    with pytest.raises(ValueError, match="dimensionality"):
        acc.feed(build_feature_stream(X))


def test_multicore_matches_single():
    rng = np.random.default_rng(5)
    cfg, acts = _random_model(rng, 9, 12, 40)
    X = rng.integers(0, 2, (32, 40)).astype(np.uint8)
    mc = MultiCoreAccelerator(4, AcceleratorConfig(
        instruction_capacity=4096, feature_capacity=64, class_capacity=16,
        batch_words=1,
    ))
    mc.load_model(encode(cfg, acts))
    assert (mc.infer(X) == _dense_sums(cfg, acts, X).argmax(1)).all()
