"""Flash-attention (custom VJP) and RoPE properties."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.models.common as cm


@pytest.fixture(autouse=True)
def small_chunks(monkeypatch):
    monkeypatch.setattr(cm, "ATTN_CHUNK", 16)


def _qkv(rng, B=2, Sq=48, Skv=48, Hq=8, Hkv=4, hd=16):
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,Skv", [
    (True, 0, 48), (True, 24, 48), (False, 0, 50), (True, 0, 70),
])
def test_flash_forward_matches_plain(causal, window, Skv):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, Skv=Skv)
    out_f = cm._flash_attention(q, k, v, causal, 0, window)
    out_p = cm._plain_attention(
        q, k, v, causal=causal, q_offset=0, window=window, kv_len=None
    )
    assert float(jnp.max(jnp.abs(out_f - out_p))) < 2e-5


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_backward_matches_plain(causal, window):
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(cm._flash_attention(q, k, v, causal, 0, window)))

    def loss_plain(q, k, v):
        return jnp.sum(jnp.sin(cm._plain_attention(
            q, k, v, causal=causal, q_offset=0, window=window, kv_len=None
        )))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_plain, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 5e-5


def test_decode_path_uses_kv_len_mask():
    """Garbage beyond kv_len must not affect the output."""
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, Sq=1, Skv=32)
    k2 = k.at[:, 20:].set(999.0)
    v2 = v.at[:, 20:].set(-999.0)
    out1 = cm.gqa_attention(q, k, v, causal=False, kv_len=jnp.int32(20))
    out2 = cm.gqa_attention(q, k2, v2, causal=False, kv_len=jnp.int32(20))
    assert float(jnp.max(jnp.abs(out1 - out2))) < 1e-6


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

    def dot_at(p_q, p_k):
        xq = cm.rope(x, jnp.array([[p_q]]), 10000.0)
        yk = cm.rope(y, jnp.array([[p_k]]), 10000.0)
        return float(jnp.sum(xq * yk))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4  # sanity: not constant


def test_causal_lm_loss_masks_padded_vocab():
    from repro.models.common import causal_lm_loss

    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 10, (2, 8)), jnp.int32)
    l1 = causal_lm_loss(logits, tokens, true_vocab=10)
    # huge logits on padded rows must not change the loss
    logits2 = logits.at[:, :, 10:].set(1e4)
    l2 = causal_lm_loss(logits2, tokens, true_vocab=10)
    assert abs(float(l1) - float(l2)) < 1e-4
