"""Data pipeline + booleanizer tests (incl. hypothesis properties)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.booleanize import Booleanizer, booleanize_images
from repro.data.pipeline import (
    TM_DATASETS,
    TokenStream,
    TokenStreamConfig,
    booleanized_tm_dataset,
    make_tm_dataset,
)


def test_stream_deterministic():
    cfg = TokenStreamConfig(vocab=100, seq_len=8, global_batch=2, seed=9)
    a = TokenStream(cfg).next_batch()["tokens"]
    b = TokenStream(cfg).next_batch()["tokens"]
    assert np.array_equal(a, b)
    assert a.max() < 100 and a.min() >= 0


@settings(max_examples=20, deadline=None)
@given(
    st.integers(2, 6),
    st.integers(10, 200),
    st.integers(1, 8),
)
def test_booleanizer_properties(n_feat, n, bits):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, n_feat)).astype(np.float32)
    b = Booleanizer.fit(x, bits=bits)
    out = b.transform(x)
    assert out.shape == (n, n_feat * bits)
    assert set(np.unique(out)).issubset({0, 1})
    # thermometer monotonicity: higher bit set => all lower bits set
    th = out.reshape(n, n_feat, bits)
    for k in range(1, bits):
        assert np.all(th[:, :, k] <= th[:, :, k - 1])


def test_booleanize_images():
    img = np.linspace(0, 1, 16).reshape(4, 4)
    out = booleanize_images(img[None], threshold=0.5)
    assert out.sum() == (img > 0.5).sum()


def test_tm_datasets_shapes():
    for name, spec in TM_DATASETS.items():
        x, y = make_tm_dataset(spec, 50, seed=1)
        assert x.shape == (50, spec.n_raw_features)
        assert y.max() < spec.n_classes
        xb, yb, booler = booleanized_tm_dataset(spec, 50, seed=1)
        assert xb.shape == (50, spec.n_raw_features * spec.thermometer_bits)


def test_drift_changes_distribution():
    spec = TM_DATASETS["gas"]
    x0, _ = make_tm_dataset(spec, 500, seed=2, drift=0.0)
    x1, _ = make_tm_dataset(spec, 500, seed=2, drift=1.0)
    assert not np.allclose(x0, x1)
