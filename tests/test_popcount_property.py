"""Property test (hypothesis): the popcount bitplane path is bit-exact.

For random capacities, odd word counts, ragged batches and all-excluded
clause banks, the four compressed execution strategies must agree on the
class sums EXACTLY:

    kernels.tm_popcount (Pallas, interpret=True on CPU — tier-1 covers it)
 == kernels.tm_popcount_xla (the portable serving formulation)
 == kernels.tm_interp (Pallas interpreter kernel, interpret=True)
 == core.interp.plan_class_sums (gather/segmented-reduce engine)

and all must match the dense ``batch_class_sums`` oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TMConfig, batch_class_sums
from repro.core.compress import decode_to_plan, encode
from repro.core.interp import pad_plan, plan_class_sums
from repro.core.tm import literals
from repro.kernels.tm_interp.kernel import tm_interp
from repro.kernels.tm_interp.ops import (
    pack_interleaved_literals,
    plan_to_operands,
)
from repro.kernels.tm_popcount.kernel import tm_popcount, tm_popcount_xla
from repro.kernels.tm_popcount.ops import plan_to_popcount_operands


@st.composite
def popcount_case(draw):
    M = draw(st.integers(1, 5))
    C = draw(st.integers(1, 8))
    F = draw(st.integers(2, 40))
    # odd word counts and ragged (non-multiple-of-32) batches both matter:
    # the packers pad the trailing word, the kernels pad the word grid
    B = draw(st.integers(1, 100))
    density = draw(st.sampled_from([0.0, 0.03, 0.1, 0.3]))  # 0.0: all-excl
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    acts = rng.random((M, C, 2 * F)) < density
    X = rng.integers(0, 2, (B, F)).astype(np.uint8)
    return TMConfig(n_classes=M, n_clauses=C, n_features=F), acts, X


@settings(max_examples=15, deadline=None)
@given(popcount_case())
def test_popcount_matches_interp_and_plan(case):
    cfg, acts, X = case
    M, B = cfg.n_classes, X.shape[0]
    state = jnp.where(jnp.asarray(acts), cfg.n_states + 1, cfg.n_states)
    oracle = np.asarray(batch_class_sums(cfg, state, jnp.asarray(X)))

    plan = decode_to_plan(encode(cfg, np.asarray(acts)))
    m_cap = M + 2
    i_cap = max(64, -(-max(plan.n_includes, 1) // 64) * 64)
    packed = pack_interleaved_literals(jnp.asarray(X))  # pads B to words

    pc_ops = plan_to_popcount_operands(
        plan, i_cap, m_cap, l2_cap=int(packed.shape[0])
    )
    pc_args = tuple(jnp.asarray(a) for a in pc_ops) + (packed,)
    out_pallas = np.asarray(
        tm_popcount(*pc_args, block_instructions=64, block_words=1,
                    interpret=True)
    )
    out_xla = np.asarray(tm_popcount_xla(*pc_args))

    it_args = tuple(
        jnp.asarray(a) for a in plan_to_operands(plan, i_cap, m_cap=m_cap)
    ) + (packed,)
    out_interp = np.asarray(tm_interp(
        *it_args, m_cap=m_cap, block_instructions=64, block_words=1,
        interpret=True,
    ))

    ncl_cap = max(8, plan.n_clauses_total)
    li, ci, cc, cp = pad_plan(plan, i_cap, ncl_cap)
    out_plan = np.asarray(plan_class_sums(
        jnp.asarray(li), jnp.asarray(ci), jnp.asarray(cc), jnp.asarray(cp),
        literals(jnp.asarray(X)), n_clause_cap=ncl_cap, m_cap=m_cap,
    ))  # [B, m_cap]

    assert (out_pallas == out_xla).all()
    assert (out_pallas == out_interp).all()
    assert (out_pallas[:, :B].T[:, :m_cap] == out_plan[:B]).all()
    assert (out_pallas[:M, :B].T == oracle).all()
