"""Behaviour tests for the repro.recal online-recalibration subsystem
(the closed Fig-8 loop) and its supporting serve_tm/train/dist changes."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig,
    fit_step,
    init_state,
    train_batch,
    train_batch_parallel,
)
from repro.core.compress import encode, validate_roundtrip
from repro.data.pipeline import TMDatasetSpec, booleanized_tm_dataset
from repro.dist.steps import make_tm_train_step
from repro.recal import (
    Compressor,
    DriftMonitor,
    RecalController,
    RecalWorker,
)
from repro.serve_tm import ServeCapacity, TMServer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_batch(rng, B, F, M):
    x = rng.integers(0, 2, (B, F)).astype(np.uint8)
    y = rng.integers(0, M, B).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


# ---------------------------------------------------------------------------
# seeding contract (fold-in keys, resumable fit_step)
# ---------------------------------------------------------------------------

def test_train_batch_reproducible_for_same_key():
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=8)
    rng = np.random.default_rng(0)
    xb, yb = _random_batch(rng, 16, 8, 3)
    key = jax.random.key(9)
    s1 = train_batch(cfg, init_state(cfg, key), key, xb, yb)
    s2 = train_batch(cfg, init_state(cfg, key), key, xb, yb)
    assert jnp.array_equal(s1, s2)


def test_fit_step_is_resumable():
    """Step s yields the same update no matter how many steps ran before —
    the contract the RecalWorker's snapshot/restore relies on."""
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=8)
    rng = np.random.default_rng(1)
    key = jax.random.key(3)
    b0 = _random_batch(rng, 16, 8, 3)
    b1 = _random_batch(rng, 16, 8, 3)

    # path A: steps 0 then 1
    sA = fit_step(cfg, init_state(cfg, key), key, *b0, step=0, parallel=True)
    sA = fit_step(cfg, sA, key, *b1, step=1, parallel=True)
    # path B: step 1 applied to a checkpoint of step 0's result
    sB = fit_step(cfg, init_state(cfg, key), key, *b0, step=0, parallel=True)
    ckpt = np.asarray(sB)  # host checkpoint (train steps donate buffers)
    sB = fit_step(cfg, jnp.asarray(ckpt), key, *b1, step=1, parallel=True)
    assert jnp.array_equal(sA, sB)


def test_sharded_tm_train_step_matches_parallel_trainer():
    """make_tm_train_step on a 1x1 mesh is bit-identical to
    train_batch_parallel (same fold-in sample keys, same deltas)."""
    cfg = TMConfig(n_classes=4, n_clauses=8, n_features=6)
    rng = np.random.default_rng(2)
    xb, yb = _random_batch(rng, 32, 6, 4)
    key = jax.random.key(5)
    ref = train_batch_parallel(cfg, init_state(cfg, key), key, xb, yb)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_tm_train_step(cfg, mesh, batch=32)
    out = step(init_state(cfg, key), key, xb, yb)
    assert jnp.array_equal(ref, out)


@pytest.mark.slow
def test_sharded_tm_train_step_multidevice():
    """Bit-equality on a real (2 data x 2 model) mesh: classes sharded over
    model, batch over data, global sample keys derived per shard."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import TMConfig, init_state, train_batch_parallel
            from repro.dist.steps import make_tm_train_step
            cfg = TMConfig(n_classes=4, n_clauses=8, n_features=6)
            rng = np.random.default_rng(0)
            xb = jnp.asarray(rng.integers(0, 2, (32, 6)).astype(np.uint8))
            yb = jnp.asarray(rng.integers(0, 4, 32).astype(np.int32))
            key = jax.random.key(5)
            ref = train_batch_parallel(
                cfg, init_state(cfg, key), key, xb, yb)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            step = make_tm_train_step(cfg, mesh, batch=32)
            out = step(init_state(cfg, key), key, xb, yb)
            assert jnp.array_equal(ref, out), "mesh step diverged"
            print("SHARDED_OK")
        """)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# DriftMonitor
# ---------------------------------------------------------------------------

def _sums(margin, n, M=4):
    """Class-sum rows with an exact top1-top2 gap of ``margin``."""
    s = np.zeros((n, M), np.int32)
    s[:, 0] = margin
    return s


def test_monitor_warmup_then_margin_trigger():
    mon = DriftMonitor(window=64, min_samples=32, margin_fraction=0.5)
    preds = np.zeros(16, np.int32)
    mon.observe(_sums(10, 16), preds)
    assert not mon.decision().trigger  # warmup: below min_samples
    mon.observe(_sums(10, 32), np.zeros(32, np.int32))
    mon.freeze_baseline()
    assert mon.decision().reason == "healthy"
    # margin collapses below 0.5 x baseline -> trigger without any labels
    mon.observe(_sums(1, 64), np.zeros(64, np.int32))
    d = mon.decision()
    assert d.trigger and "margin" in d.reason and d.accuracy is None


def test_monitor_accuracy_trigger_beats_margin():
    mon = DriftMonitor(window=64, min_samples=16, accuracy_threshold=0.9)
    preds = np.zeros(32, np.int32)
    labels = np.ones(32, np.int32)  # everything wrong
    mon.observe(_sums(10, 32), preds, labels)
    d = mon.decision()
    assert d.trigger and "accuracy" in d.reason and d.accuracy == 0.0


def test_monitor_reset_clears_windows():
    mon = DriftMonitor(window=64, min_samples=16)
    mon.observe(_sums(10, 32), np.zeros(32, np.int32), np.zeros(32, np.int32))
    mon.reset()
    assert mon.n_samples == 0 and mon.accuracy is None
    assert mon.decision().reason == "warmup"


# ---------------------------------------------------------------------------
# Compressor / publication gate
# ---------------------------------------------------------------------------

def test_compressor_emits_validated_model():
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=10)
    rng = np.random.default_rng(3)
    key = jax.random.key(1)
    state = train_batch_parallel(
        cfg, init_state(cfg, key), key, *_random_batch(rng, 64, 10, 3)
    )
    report = Compressor(probe_rows=32).compress(cfg, state)
    assert report.model.n_classes == 3
    assert report.probe_rows == 32
    assert report.n_includes == int(
        np.asarray(state > cfg.n_states).sum()
    )


def test_compressor_rejects_bad_traffic_sample_shape():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=6)
    state = init_state(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="traffic_sample"):
        Compressor().compress(
            cfg, state, traffic_sample=np.zeros((4, 5), np.uint8)
        )


def test_validate_roundtrip_catches_tampered_stream():
    """A corrupted instruction stream must never pass the publication gate."""
    cfg = TMConfig(n_classes=2, n_clauses=2, n_features=4)
    acts = np.zeros((2, 2, 8), bool)
    acts[0, 0, 0] = True  # class 0, + clause, literal f0
    acts[1, 0, 2] = True  # class 1, + clause, literal f1
    model = encode(cfg, acts)
    X = np.eye(4, dtype=np.uint8)
    validate_roundtrip(cfg, acts, model, X)  # intact stream passes
    tampered = np.array(model.instructions)
    tampered[0] += 1  # corrupt the offset: include lands on the wrong slot
    import dataclasses
    bad = dataclasses.replace(model, instructions=tampered)
    with pytest.raises(ValueError, match="not bit-exact"):
        validate_roundtrip(cfg, acts, bad, X)


# ---------------------------------------------------------------------------
# registry / server rollback hooks
# ---------------------------------------------------------------------------

def _tiny_model(seed, M=3, C=4, F=8, density=0.2):
    rng = np.random.default_rng(seed)
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < density
    return cfg, acts, encode(cfg, acts)


def test_registry_rollback_and_provenance():
    server = TMServer(ServeCapacity(), backend="plan")
    _, _, m1 = _tiny_model(1)
    _, _, m2 = _tiny_model(2)
    server.register("s", m1, provenance="deploy")
    server.register("s", m2, provenance="recal:test")
    assert server.registry.get("s").version == 2
    assert server.registry.get("s").provenance == "recal:test"
    assert server.registry.previous("s").model is m1

    entry = server.rollback("s")
    assert entry.version == 3  # versions stay monotonic
    assert entry.model is m1
    # provenance nests the restored entry's own provenance (full chain)
    assert entry.provenance == "rollback:v2->v1(deploy)"
    assert server.metrics.rollbacks == 1
    assert server.metrics.summary()["rollbacks"] == 1


def test_registry_rollback_without_history_raises():
    server = TMServer(ServeCapacity(), backend="plan")
    _, _, m1 = _tiny_model(1)
    server.register("s", m1)
    with pytest.raises(KeyError, match="no previous version"):
        server.rollback("s")


def test_server_rollback_drains_queued_traffic_under_current_model():
    """Rows queued before a rollback are answered by the model they were
    submitted against (same drain discipline as register)."""
    server = TMServer(ServeCapacity(), backend="plan")
    cfg1, acts1, m1 = _tiny_model(4)
    cfg2, acts2, m2 = _tiny_model(5)
    server.register("s", m1)
    server.register("s", m2)

    rng = np.random.default_rng(6)
    x = rng.integers(0, 2, (8, cfg2.n_features)).astype(np.uint8)
    expected_v2 = np.asarray(server.class_sums("s", x)).argmax(1)
    h = server.submit("s", x)
    server.rollback("s")  # must flush the queue under m2 first
    assert np.array_equal(h.result(), expected_v2)
    assert server.compile_cache_size() == 1


# ---------------------------------------------------------------------------
# controller: the closed loop
# ---------------------------------------------------------------------------

SPEC = TMDatasetSpec("recal-test", 12, 3, 4, 24)


def _trained_setup(backend="plan"):
    xb, y, booler = booleanized_tm_dataset(SPEC, 900, seed=0, drift=0.0)
    cfg = TMConfig(
        n_classes=SPEC.n_classes, n_clauses=SPEC.n_clauses,
        n_features=booler.n_boolean_features,
    )
    worker = RecalWorker(cfg, key=jax.random.key(11))
    worker.fine_tune_epochs(xb, y, epochs=4, batch=150)
    server = TMServer(
        ServeCapacity(feature_capacity=64, instruction_capacity=8192),
        backend=backend,
    )
    return cfg, worker, server, booler


def test_controller_closes_the_loop_under_drift():
    cfg, worker, server, booler = _trained_setup()
    controller = RecalController(
        server, "edge", worker,
        monitor=DriftMonitor(window=256, min_samples=128,
                             accuracy_threshold=0.9),
        buffer_batches=6, train_batch_size=128, min_buffer_rows=512,
        epochs_per_recal=6,
    )
    controller.deploy()
    assert server.registry.get("edge").provenance == "deploy"

    xt, yt, _ = booleanized_tm_dataset(
        SPEC, 256, seed=1, drift=0.0, booleanizer=booler
    )
    base_acc = float((controller.observe(xt, yt) == yt).mean())
    controller.freeze_baseline()
    assert base_acc > 0.8

    events = []
    for i in range(14):
        xd, yd, _ = booleanized_tm_dataset(
            SPEC, 128, seed=100 + i, drift=1.2, booleanizer=booler
        )
        _, event = controller.serve(xd, yd)
        if event:
            events.append(event)
    assert events, "drift never triggered a recalibration"
    assert any(not e.rolled_back for e in events)
    swap = next(e for e in events if not e.rolled_back)
    assert swap.holdout_acc_after >= swap.holdout_acc_before
    assert server.registry.get("edge").provenance.startswith(
        ("recal:", "rollback:")
    )

    xf, yf, _ = booleanized_tm_dataset(
        SPEC, 512, seed=999, drift=1.2, booleanizer=booler
    )
    final_acc = float((controller.observe(xf, yf) == yf).mean())
    # the tight recovery bound (baseline - 2%) is the example's acceptance
    # criterion at full scale; this miniature loop just has to get close
    assert final_acc >= base_acc - 0.08
    assert server.compile_cache_size() == 1
    assert server.metrics.summary()["recals"] == len(events)


def test_controller_rolls_back_a_bad_recalibration():
    cfg, worker, server, booler = _trained_setup()

    class SabotagedWorker(RecalWorker):
        """Training node gone wrong: unlearns everything."""

        def fine_tune_epochs(self, x, y, *, epochs, batch):
            self.state = init_state(self.cfg, self.key)  # all-Exclude
            return 1

    bad = SabotagedWorker(cfg, state=jnp.asarray(worker.snapshot()),
                          key=jax.random.key(11))
    controller = RecalController(
        server, "edge", bad, buffer_batches=4, train_batch_size=128,
        regression_margin=0.02,
    )
    controller.deploy()
    good_state = bad.snapshot()
    xt, yt, _ = booleanized_tm_dataset(
        SPEC, 256, seed=1, drift=0.0, booleanizer=booler
    )
    expected = controller.observe(xt, yt)

    event = controller.recalibrate(reason="test")
    assert event.rolled_back
    assert server.metrics.rollbacks == 1
    # the served model is the pre-recal one again, the worker restored
    assert np.array_equal(controller.server.infer("edge", xt), expected)
    assert np.array_equal(bad.snapshot(), good_state)
    assert server.compile_cache_size() == 1


def test_controller_requires_labelled_buffer():
    cfg, worker, server, _ = _trained_setup()
    controller = RecalController(server, "edge", worker)
    controller.deploy()
    with pytest.raises(RuntimeError, match="no labelled traffic"):
        controller.recalibrate()
