"""repro.prune: ETHEREAL-style clause pruning + weighted clauses.

Covers the ISSUE-10 acceptance surface: prune_exact bit-exact on all four
engines (property-tested, including all-excluded and duplicate-clause
cases), merge_weighted's lossless weighted collapse, the tolerance-gated
ranked drop, weighted execution end-to-end (encode -> wire -> every
engine vs the ``batch_class_sums_weighted`` oracle, popcount staying
multiply-free via bitplane decomposition), the TMProgram v2 wire format
with the v1 golden-fixture byte-stability guarantee, the
``weight_planes`` capacity knob + shrink diagnostics, the
zero-clause-class ``validate_roundtrip`` gate, and the
``RecalController(prune=...)`` integration.
"""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.accel import (
    CapacityPlan,
    HEADROOM_KNOBS,
    QUANTA,
    TMProgram,
    make_engine,
    model_requirements,
)
from repro.core import (
    TMConfig,
    batch_class_sums,
    batch_class_sums_weighted,
    state_from_actions,
)
from repro.core.compress import (
    CompressedModel,
    decode,
    decode_to_plan,
    decode_weights,
    encode,
    validate_roundtrip,
)
from repro.core.tm import clause_outputs, literals
from repro.kernels.tm_popcount.ops import (
    pack_class_masks,
    pack_class_masks_weighted,
)
from repro.prune import (
    PrunePolicy,
    clause_fire_counts,
    contradictory_clauses,
    dead_clause_mask,
    duplicate_groups,
    merge_weighted,
    prune_exact,
    prune_ranked,
    vote_contribution,
)

ENGINE_NAMES = ("interp", "plan", "sharded", "popcount")
DATA_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")


def _oracle(cfg, acts, X, weights=None):
    w = None if weights is None else jnp.asarray(weights, jnp.int32)
    return np.asarray(batch_class_sums_weighted(
        cfg, state_from_actions(cfg, acts), jnp.asarray(X), w
    ))


def _engine_sums(name, model, X):
    plan = CapacityPlan.for_models([model], batch_words=2)
    opts = {"implementation": "xla"} if name == "popcount" else {}
    eng = make_engine(name, plan, **opts)
    prog = eng.program(model)
    return eng.class_sums(prog, X)


def _messy_actions(rng, cfg, density=0.2):
    """Random mask seeded with every dead-clause species: all-excluded
    rows, contradictory rows, duplicate groups (cancelling and not)."""
    M, C, L = cfg.n_classes, cfg.n_clauses, cfg.n_literals
    acts = rng.random((M, C, L)) < density
    acts[:, C - 1, :] = False  # all-excluded everywhere
    if C >= 4:
        acts[0, 1] = False  # contradictory clause
        acts[0, 1, 0] = acts[0, 1, 1] = True
        # a cancelling duplicate pair (even + odd slot, same litset) ...
        acts[1, 0] = False
        acts[1, 1] = False
        acts[1, 0, 2] = acts[1, 1, 2] = True
        # ... and a same-parity duplicate pair that must NOT cancel
        acts[2, 0] = False
        acts[2, 2] = False
        acts[2, 0, 4] = acts[2, 2, 4] = True
    return acts


# ---------------------------------------------------------------------------
# ranking + dead-clause detection
# ---------------------------------------------------------------------------

def test_fire_counts_match_dense_clause_outputs():
    rng = np.random.default_rng(0)
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=7)
    acts = _messy_actions(rng, cfg)
    X = rng.integers(0, 2, (40, cfg.n_features)).astype(np.uint8)
    counts = clause_fire_counts(cfg, acts, X)
    ref = np.zeros((cfg.n_classes, cfg.n_clauses), np.int64)
    for row in np.asarray(literals(jnp.asarray(X, bool))):
        ref += np.asarray(clause_outputs(
            cfg, jnp.asarray(acts), jnp.asarray(row), training=False
        )).astype(np.int64)
    assert np.array_equal(counts, ref)


def test_vote_contribution_is_weight_times_fires():
    rng = np.random.default_rng(1)
    cfg = TMConfig(n_classes=2, n_clauses=6, n_features=5)
    acts = rng.random((2, 6, 10)) < 0.3
    w = rng.integers(1, 9, (2, 6))
    X = rng.integers(0, 2, (24, 5)).astype(np.uint8)
    assert np.array_equal(
        vote_contribution(cfg, acts, X, w),
        clause_fire_counts(cfg, acts, X) * w,
    )


def test_dead_clause_mask_species():
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=4)
    acts = np.zeros((3, 6, 8), bool)
    acts[0, 0, 0] = True  # live
    acts[0, 2, 2] = acts[0, 2, 3] = True  # contradictory (feature 1 + f̄1)
    acts[1, 0, 4] = acts[1, 1, 4] = True  # cancelling +/- duplicate pair
    acts[2, 0, 6] = acts[2, 2, 6] = True  # same-parity duplicates: live
    dead = dead_clause_mask(cfg, acts)
    assert not dead[0, 0]
    assert dead[0, 1]  # empty
    assert dead[0, 2]  # contradictory
    assert dead[1, 0] and dead[1, 1]  # cancelled group
    assert not dead[2, 0] and not dead[2, 2]
    # weights break the cancellation: +2 vs -1 nets +1, so the pair lives
    w = np.ones((3, 6), np.int64)
    w[1, 0] = 2
    dead_w = dead_clause_mask(cfg, acts, w)
    assert not dead_w[1, 0] and not dead_w[1, 1]


def test_duplicate_groups_keys_on_class_and_litset():
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=3)
    acts = np.zeros((2, 4, 6), bool)
    acts[0, 0, 0] = acts[0, 1, 0] = acts[0, 3, 0] = True  # one group of 3
    acts[1, 0, 0] = True  # same litset, OTHER class: not grouped
    groups = duplicate_groups(cfg, acts)
    assert len(groups) == 1
    ((m, _), slots), = groups.items()
    assert m == 0 and slots == [0, 1, 3]


# ---------------------------------------------------------------------------
# prune_exact / merge_weighted: bit-exact on every engine (property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINE_NAMES)
@pytest.mark.parametrize("seed", range(3))
def test_prune_exact_bit_exact_on_every_engine(engine, seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(2, 5))
    C = int(rng.integers(4, 9))
    F = int(rng.integers(4, 12))
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = _messy_actions(rng, cfg)
    X = rng.integers(0, 2, (32, F)).astype(np.uint8)

    r = prune_exact(cfg, acts)
    assert r.report.n_dead >= 1  # the seeded all-excluded rows at least
    model = encode(cfg, r.actions, clause_weights=r.weights)
    assert np.array_equal(_engine_sums(engine, model, X),
                          _oracle(cfg, acts, X))


@pytest.mark.parametrize("engine", ENGINE_NAMES)
@pytest.mark.parametrize("seed", range(3))
def test_merge_weighted_bit_exact_on_every_engine(engine, seed):
    rng = np.random.default_rng(100 + seed)
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=6)
    acts = _messy_actions(rng, cfg)
    X = rng.integers(0, 2, (32, cfg.n_features)).astype(np.uint8)

    r = prune_exact(cfg, acts)
    r = merge_weighted(cfg, r.actions, r.weights)
    assert r.weights is not None  # the seeded same-parity pair merged
    model = encode(cfg, r.actions, clause_weights=r.weights)
    assert model.weighted
    assert np.array_equal(_engine_sums(engine, model, X),
                          _oracle(cfg, acts, X))


def test_merge_survivor_parity_and_cancelled_group():
    cfg = TMConfig(n_classes=1, n_clauses=6, n_features=3)
    acts = np.zeros((1, 6, 6), bool)
    # group A: slots 0(+), 2(+), 1(-) with weights 3, 2, 1 -> net +4
    for j in (0, 1, 2):
        acts[0, j, 0] = True
    # group B: slots 3(-), 4(+) unit weights -> net 0, zeroed outright
    acts[0, 3, 2] = acts[0, 4, 2] = True
    w = np.ones((1, 6), np.int64)
    w[0, 0], w[0, 2], w[0, 1] = 3, 2, 1
    r = merge_weighted(cfg, acts, w)
    assert r.actions[0, 0].any() and not r.actions[0, 1].any() \
        and not r.actions[0, 2].any()
    assert r.weights[0, 0] == 4
    assert not r.actions[0, 3].any() and not r.actions[0, 4].any()
    X = np.array([[1, 0, 1], [0, 1, 1], [1, 1, 1]], np.uint8)
    assert np.array_equal(_oracle(cfg, r.actions, X, r.weights),
                          _oracle(cfg, acts, X, w))


# ---------------------------------------------------------------------------
# prune_ranked: tolerance-gated lossy tail drop
# ---------------------------------------------------------------------------

def _separable_setup(seed=7, B=200):
    """A model + labelled holdout where labels come from the model itself,
    plus pure-noise clauses a ranked pass should find droppable."""
    rng = np.random.default_rng(seed)
    cfg = TMConfig(n_classes=3, n_clauses=10, n_features=8)
    acts = rng.random((3, 10, 16)) < 0.12
    X = rng.integers(0, 2, (B, 8)).astype(np.uint8)
    y = np.argmax(_oracle(cfg, acts, X), axis=1).astype(np.int32)
    return cfg, acts, X, y


def test_prune_ranked_respects_tolerance():
    cfg, acts, X, y = _separable_setup()
    r = prune_ranked(cfg, acts, X, y, tolerance=0.05)
    assert r.report.baseline_accuracy is not None
    assert (r.report.pruned_accuracy
            >= r.report.baseline_accuracy - 0.05 - 1e-12)
    assert r.report.n_ranked == (r.report.n_clauses_before
                                 - r.report.n_clauses_after)


def test_prune_ranked_tolerance_one_drops_everything():
    cfg, acts, X, y = _separable_setup(seed=8)
    r = prune_ranked(cfg, acts, X, y, tolerance=1.0)
    assert r.report.n_clauses_after == 0
    with pytest.raises(ValueError, match="tolerance"):
        prune_ranked(cfg, acts, X, y, tolerance=-0.1)


def test_policy_chains_and_skips_ranked_without_labels():
    cfg, acts, X, y = _separable_setup(seed=9)
    full = PrunePolicy(tolerance=0.05).apply(cfg, acts, X=X, y=y)
    assert full.report.stages == ("exact", "merge", "ranked")
    assert full.report.n_clauses_after <= full.report.n_clauses_before
    unlabelled = PrunePolicy(tolerance=0.05).apply(cfg, acts, X=X)
    assert unlabelled.report.stages == (
        "exact", "merge", "ranked:skipped-no-labels"
    )
    # the label-free passes are bit-exact, always
    assert np.array_equal(
        _oracle(cfg, unlabelled.actions, X, unlabelled.weights),
        _oracle(cfg, acts, X),
    )


# ---------------------------------------------------------------------------
# weighted clauses end-to-end: encode / decode / wire / every engine
# ---------------------------------------------------------------------------

def test_encode_normalizes_all_ones_weights_to_weightless():
    rng = np.random.default_rng(2)
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=5)
    acts = rng.random((2, 4, 10)) < 0.3
    model = encode(cfg, acts, clause_weights=np.ones((2, 4), np.int64))
    assert not model.weighted
    assert model.n_bytes == encode(cfg, acts).n_bytes


def test_weighted_encode_decode_roundtrip_places_weights_by_slot():
    rng = np.random.default_rng(3)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=6)
    acts = rng.random((3, 6, 12)) < 0.25
    w = rng.integers(1, 6, (3, 6)).astype(np.int64)
    model = encode(cfg, acts, clause_weights=w)
    assert model.weighted and model.n_weights > 0
    dec_acts, dec_w = decode_weights(model)
    X = rng.integers(0, 2, (48, 6)).astype(np.uint8)
    assert np.array_equal(_oracle(cfg, dec_acts, X, dec_w),
                          _oracle(cfg, acts, X, w))
    plan = decode_to_plan(model)
    assert plan.clause_weight is not None
    assert np.array_equal(np.abs(plan.weighted_pol), plan.weights)


def test_clause_weight_range_is_enforced():
    with pytest.raises(ValueError, match=r"\[1, 65535\]"):
        CompressedModel(
            instructions=np.zeros(0, np.uint16), n_classes=1, n_clauses=2,
            n_features=2, clause_weights=np.array([0]),
        )


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_weighted_execution_bit_exact_on_every_engine(engine):
    rng = np.random.default_rng(4)
    cfg = TMConfig(n_classes=4, n_clauses=8, n_features=9)
    acts = rng.random((4, 8, 18)) < 0.2
    w = rng.integers(1, 8, (4, 8)).astype(np.int64)
    model = encode(cfg, acts, clause_weights=w)
    X = rng.integers(0, 2, (32, 9)).astype(np.uint8)
    assert np.array_equal(_engine_sums(engine, model, X),
                          _oracle(cfg, acts, X, w))


def test_weighted_popcount_bitplanes_are_multiply_free():
    """The popcount path executes weights as shifted popcounts: plane b of
    the 3-D selection bank holds exactly the emitting instructions whose
    weight has bit b set, and the banks reconstruct the weights — no
    multiply anywhere in the reduction (left_shift + popcount only)."""
    rng = np.random.default_rng(5)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=8)
    acts = rng.random((3, 6, 16)) < 0.25
    w = rng.integers(1, 7, (3, 6)).astype(np.int64)
    model = encode(cfg, acts, clause_weights=w)
    plan = decode_to_plan(model)

    from repro.kernels.tm_popcount.ops import plan_to_popcount_operands
    i_cap, m_cap = 128, 4
    lit_idx, last, mpos, mneg = plan_to_popcount_operands(
        plan, i_cap, m_cap, weight_planes=plan.weight_planes
    )
    assert mpos.ndim == 3 and mpos.shape[0] == plan.weight_planes
    # plane decomposition reconstructs each emitted clause's weight
    emitting = np.flatnonzero(last == 1)
    wts = np.ones(i_cap, np.int64)
    wts[: plan.n_includes] = plan.weights[plan.clause_id]
    for t in emitting:
        chunk, bit = t // 32, t % 32
        rebuilt = 0
        for b in range(plan.weight_planes):
            sel = any(
                (int(mpos[b, m, chunk]) >> bit) & 1
                or (int(mneg[b, m, chunk]) >> bit) & 1
                for m in range(m_cap)
            )
            rebuilt |= int(sel) << b
        assert rebuilt == wts[t], f"instruction {t}"
    # and the plane depth is validated: a too-shallow bank is refused
    with pytest.raises(ValueError, match="bitplanes"):
        plan_to_popcount_operands(plan, i_cap, m_cap, weight_planes=1)


def test_weighted_popcount_matches_weighted_interp_oracle():
    """popcount (bitplane path) vs interp (weight-memory path): two
    independent weighted realizations must agree bit-for-bit."""
    rng = np.random.default_rng(6)
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=10)
    acts = _messy_actions(rng, cfg)
    w = rng.integers(1, 16, (3, 8)).astype(np.int64)
    model = encode(cfg, acts, clause_weights=w)
    X = rng.integers(0, 2, (64, 10)).astype(np.uint8)
    assert np.array_equal(_engine_sums("popcount", model, X),
                          _engine_sums("interp", model, X))


def test_weightless_mask_packing_unchanged_by_weighted_path():
    """All-ones weights at plane depth 1 reproduce the legacy 2-D banks
    exactly (the weightless program is the weighted one at weight 1)."""
    rng = np.random.default_rng(7)
    last = (rng.random(64) < 0.3).astype(np.int32)
    pol = np.where(rng.random(64) < 0.5, 1, -1).astype(np.int32)
    cls = rng.integers(0, 4, 64).astype(np.int32)
    legacy_pos, legacy_neg = pack_class_masks(last, pol, cls, 4)
    wpos, wneg = pack_class_masks_weighted(
        last, pol, cls, np.ones(64, np.int32), 4, 1
    )
    assert np.array_equal(wpos[0], legacy_pos)
    assert np.array_equal(wneg[0], legacy_neg)


# ---------------------------------------------------------------------------
# capacity: the weight_planes knob + shrink diagnostics
# ---------------------------------------------------------------------------

def test_weight_planes_negotiation_and_shrink():
    rng = np.random.default_rng(8)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=8)
    acts = rng.random((3, 6, 16)) < 0.2
    w = np.full((3, 6), 5, np.int64)  # bit_length 3
    weighted = encode(cfg, acts, clause_weights=w)
    weightless = encode(cfg, acts)

    assert model_requirements(weighted)["weight_planes"] == 3
    assert model_requirements(weightless)["weight_planes"] == 1
    assert "weight_planes" in QUANTA and QUANTA["weight_planes"] == 1
    assert "weight_planes" not in HEADROOM_KNOBS  # model-derived, no slack

    plan = CapacityPlan.for_models([weighted, weightless])
    assert plan.weight_planes == 3
    # a pruned/weightless artifact lets the envelope renegotiate DOWN
    diags = dict(
        (k, (prov, rec)) for k, prov, rec in
        plan.shrink_diagnostics(weightless)
    )
    assert diags["weight_planes"] == (3, 1)
    shrunk = plan.shrink_to(weightless)
    assert shrunk.weight_planes == 1
    assert shrunk.fits(weightless) and not shrunk.fits(weighted)


def test_popcount_validates_weight_planes_knob():
    rng = np.random.default_rng(9)
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=6)
    acts = rng.random((2, 4, 12)) < 0.3
    model = encode(cfg, acts, clause_weights=np.full((2, 4), 9, np.int64))
    plan = dataclasses.replace(
        CapacityPlan.for_models([model]), weight_planes=2
    )
    pop = make_engine("popcount", plan, implementation="xla")
    assert any("weight_planes" in v for v in pop.model_violations(model))
    interp = make_engine("interp", plan)
    assert not interp.model_violations(model)  # interp reads the memory


# ---------------------------------------------------------------------------
# TMProgram: v1 golden fixture + v2 weighted wire
# ---------------------------------------------------------------------------

def _golden_artifact():
    rng = np.random.default_rng(1234)
    cfg = TMConfig(n_classes=4, n_clauses=6, n_features=16)
    acts = rng.random((4, 6, 32)) < 0.15
    model = encode(cfg, acts)
    plan = CapacityPlan.for_models([model], batch_words=2)
    return cfg, acts, TMProgram(capacity=plan, model=model)


def test_v1_golden_fixture_bytes_are_stable():
    """The committed pre-v2 blob: today's serializer must still emit it
    byte-for-byte (weightless models auto-resolve to format v1)."""
    cfg, acts, art = _golden_artifact()
    assert art.format_version == 1
    with open(os.path.join(DATA_DIR, "tmprogram_v1_golden.bin"), "rb") as f:
        golden = f.read()
    assert art.to_bytes() == golden


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_v1_golden_fixture_loads_and_serves_bit_exactly(engine):
    cfg, acts, _ = _golden_artifact()
    with open(os.path.join(DATA_DIR, "tmprogram_v1_golden.bin"), "rb") as f:
        art = TMProgram.from_bytes(f.read())
    assert art.format_version == 1 and not art.model.weighted
    rng = np.random.default_rng(42)
    X = rng.integers(0, 2, (32, cfg.n_features)).astype(np.uint8)
    assert np.array_equal(_engine_sums(engine, art.model, X),
                          _oracle(cfg, acts, X))


def test_v2_weighted_roundtrip_and_weight_crc():
    rng = np.random.default_rng(10)
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=8)
    acts = rng.random((3, 6, 16)) < 0.2
    w = rng.integers(2, 10, (3, 6)).astype(np.int64)
    model = encode(cfg, acts, clause_weights=w)
    art = TMProgram(CapacityPlan.for_models([model]), model)
    assert art.format_version == 2
    blob = art.to_bytes()
    back = TMProgram.from_bytes(blob)
    assert back == art
    assert np.array_equal(back.model.clause_weights, model.clause_weights)
    # flip a bit INSIDE the weight vector (the payload tail): the CRC
    # must catch it exactly like a corrupted instruction
    corrupted = bytearray(blob)
    corrupted[-1] ^= 0x40
    with pytest.raises(ValueError, match="checksum"):
        TMProgram.from_bytes(bytes(corrupted))


def test_v1_refuses_weighted_models():
    rng = np.random.default_rng(11)
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=5)
    acts = rng.random((2, 4, 10)) < 0.3
    model = encode(cfg, acts, clause_weights=np.full((2, 4), 3, np.int64))
    with pytest.raises(ValueError, match="v1 cannot carry"):
        TMProgram(CapacityPlan.for_models([model]), model, format_version=1)


# ---------------------------------------------------------------------------
# satellite 2: zero-clause-class streams through the publication gate
# ---------------------------------------------------------------------------

def test_roundtrip_passes_on_legitimate_zero_clause_class():
    rng = np.random.default_rng(12)
    cfg = TMConfig(n_classes=4, n_clauses=5, n_features=6)
    acts = rng.random((4, 5, 12)) < 0.25
    acts[1] = False  # a pruned-empty middle class: lone boundary EXTEND
    acts[3] = False  # ... and an empty final class
    model = encode(cfg, acts)
    X = rng.integers(0, 2, (32, 6)).astype(np.uint8)
    validate_roundtrip(cfg, acts, model, X)  # must NOT raise
    assert np.array_equal(decode(model), acts)


def test_roundtrip_refuses_misaligned_stream_cleanly():
    """A stream whose class alignment slipped past n_classes is a
    structured publication refusal, never an IndexError."""
    rng = np.random.default_rng(13)
    cfg3 = TMConfig(n_classes=3, n_clauses=4, n_features=5)
    acts3 = rng.random((3, 4, 10)) < 0.3
    acts3[0, 0, 0] = True  # every class non-empty
    acts3[1, 0, 0] = True
    acts3[2, 0, 0] = True
    model3 = encode(cfg3, acts3)
    # lie about the dims: same stream, two declared classes
    bad = CompressedModel(
        instructions=model3.instructions, n_classes=2,
        n_clauses=4, n_features=5,
    )
    cfg2 = TMConfig(n_classes=2, n_clauses=4, n_features=5)
    X = rng.integers(0, 2, (16, 5)).astype(np.uint8)
    with pytest.raises(ValueError, match="refusing to publish") as ei:
        validate_roundtrip(cfg2, acts3[:2], bad, X)
    assert "class alignment" in str(ei.value)


def test_decode_refuses_weight_count_mismatch():
    rng = np.random.default_rng(14)
    cfg = TMConfig(n_classes=2, n_clauses=4, n_features=5)
    acts = rng.random((2, 4, 10)) < 0.3
    model = encode(cfg, acts, clause_weights=np.full((2, 4), 2, np.int64))
    short = CompressedModel(
        instructions=model.instructions, n_classes=2, n_clauses=4,
        n_features=5, clause_weights=model.clause_weights[:-1],
    )
    with pytest.raises(ValueError, match="weight vector"):
        decode_weights(short)


# ---------------------------------------------------------------------------
# recal integration: Compressor(prune=...) and the controller hook
# ---------------------------------------------------------------------------

def test_compressor_runs_prune_policy_and_reports_shrink():
    from repro.recal import Compressor

    rng = np.random.default_rng(15)
    cfg = TMConfig(n_classes=3, n_clauses=8, n_features=6)
    acts = _messy_actions(rng, cfg)
    state = state_from_actions(cfg, acts)

    baseline = Compressor().compress(cfg, state)
    # provision weight planes up front: merge_weighted may turn the
    # weightless model into a (small-)weighted one
    plan = dataclasses.replace(
        CapacityPlan.for_models([baseline.model]), weight_planes=4
    )
    report = Compressor(plan=plan).compress(
        cfg, state, prune=PrunePolicy()
    )
    assert report.prune is not None
    assert report.prune.n_removed >= 1
    assert report.model.n_bytes < baseline.model.n_bytes
    assert report.artifact is not None
    # the dead rows freed instruction depth the envelope can reclaim
    assert any(k == "instruction_capacity" for k, _, _ in report.shrink)


def test_controller_prunes_on_deploy_and_recal():
    from repro.data.pipeline import TMDatasetSpec, booleanized_tm_dataset
    from repro.recal import RecalController, RecalWorker
    from repro.serve_tm import ServeCapacity, TMServer

    import jax

    spec = TMDatasetSpec("prune-test", 10, 3, 4, 20)
    xb, y, booler = booleanized_tm_dataset(spec, 600, seed=0, drift=0.0)
    cfg = TMConfig(
        n_classes=spec.n_classes, n_clauses=spec.n_clauses,
        n_features=booler.n_boolean_features,
    )
    worker = RecalWorker(cfg, key=jax.random.key(11))
    worker.fine_tune_epochs(xb, y, epochs=3, batch=150)
    server = TMServer(
        ServeCapacity(feature_capacity=64, instruction_capacity=8192),
        backend="plan",
    )
    # the rollback margin must absorb the prune tolerance PLUS the tiny
    # fine-tune's own noise, or a legitimate ranked drop reads as a
    # regression and rolls back
    controller = RecalController(
        server, "edge", worker,
        buffer_batches=4, train_batch_size=128, min_buffer_rows=256,
        regression_margin=0.1,
        prune=PrunePolicy(tolerance=0.02),
    )
    controller.deploy()  # no labels: exact+merge only, still publishes
    assert server.registry.get("edge").provenance == "deploy"

    # buffer the full training distribution, so the recal fine-tune holds
    # the model's quality and the post-swap check isolates the prune drop
    for i in range(0, 600, 200):
        preds = controller.observe(
            np.asarray(xb[i:i + 200]), np.asarray(y[i:i + 200])
        )
    assert preds.shape == (200,)
    event = controller.recalibrate(reason="test")
    assert event.prune_stages[0] == "exact"
    assert event.prune_stages[1].startswith("merge")
    assert "ranked" in event.prune_stages[-1]
    assert event.pruned_clauses >= 0
    assert not event.rolled_back
    assert isinstance(event.reclaimable, tuple)
    # the post-swap check bounds the combined fine-tune + ranked-drop
    # cost; the publication gate proved the pruned stream bit-exact
    # against the pruned oracle before the swap
    assert event.holdout_acc_after >= event.holdout_acc_before - 0.1 - 1e-9


# ---------------------------------------------------------------------------
# satellite 1: FleetPool.warnings ring buffer
# ---------------------------------------------------------------------------

def test_fleet_pool_warnings_ring_is_bounded_and_clearable():
    from repro.fleet import FleetPool

    pool = FleetPool(max_warnings=4)
    for i in range(10):
        pool._warn(f"warning {i}")
    assert len(pool.warnings) == 4
    assert list(pool.warnings) == [f"warning {i}" for i in range(6, 10)]
    drained = pool.clear_warnings()
    assert drained == [f"warning {i}" for i in range(6, 10)]
    assert len(pool.warnings) == 0
    with pytest.raises(ValueError, match="max_warnings"):
        FleetPool(max_warnings=0)
