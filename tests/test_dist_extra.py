"""Coverage for repro.dist beyond the seed tests: batch-axis selection on
1-/2-/3-axis meshes, param sharding rules on a degenerate mesh, the sharded
TM executor against the dense oracle, and the dry-run lowering entry point.
"""

from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.dist.tm_sharded as tms
from repro.core import TMConfig, batch_class_sums
from repro.core.compress import decode_to_plan, encode
from repro.dist import sharding as shd


def _mesh_stub(shape, axes):
    """batch_axes only reads axis_names/devices.shape; a stub lets us probe
    multi-axis layouts without 8 host devices."""
    return SimpleNamespace(axis_names=axes, devices=np.empty(shape))


def test_batch_axes_mesh_ranks():
    # 1-axis data mesh
    assert shd.batch_axes(_mesh_stub((4,), ("data",)), 8) == ("data",)
    # 2-axis: model never carries batch
    assert shd.batch_axes(_mesh_stub((4, 2), ("data", "model")), 64) == ("data",)
    # 3-axis multi-pod layout
    m3 = _mesh_stub((2, 2, 2), ("pod", "data", "model"))
    assert shd.batch_axes(m3, 8) == ("pod", "data")
    # batch covers the pod axis but not pod*data -> shard pod only
    assert shd.batch_axes(m3, 2) == ("pod",)
    # indivisible batch stays replicated
    assert shd.batch_axes(m3, 3) is None
    assert shd.batch_axes(_mesh_stub((4, 2), ("data", "model")), 2) is None


def test_hint_noop_without_mesh():
    shd.set_activation_mesh(None)
    x = jnp.ones((4, 8))
    assert shd.hint(x, "batch", None) is x


def test_param_shardings_degenerate_mesh():
    """(1,1) mesh: every leaf gets exactly one sharding and the big matrices
    still carry the model axis in their spec (size-1 axes are free)."""
    from repro.configs.registry import get
    from repro.models.api import abstract_params

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get("starcoder2-7b")
    specs = abstract_params(cfg)
    sh = shd.param_shardings(cfg, mesh, specs)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(specs))
    # embedding: vocab rows model-sharded (padded_vocab % n_model == 0)
    assert sh["embed"].spec[0] == "model"
    # attention + MLP matrices model-sharded somewhere past the stack dim
    for name in ("wq", "wk", "wv", "wo"):
        assert "model" in tuple(sh["layers"]["attn"][name].spec)
    for name in ("w_gate", "w_up", "w_down"):
        assert "model" in tuple(sh["layers"]["mlp"][name].spec)
    # norm scales replicated
    assert tuple(sh["final_norm"].spec) == ()
    # MoE expert stacks shard the expert dim
    moe_cfg = get("moonshot-v1-16b-a3b")
    moe_sh = shd.param_shardings(moe_cfg, mesh, abstract_params(moe_cfg))
    assert moe_sh["layers"]["moe"]["w_gate"].spec[1] == "model"


def test_build_tm_sharded_matches_oracle():
    """Single-device mesh: the sharded executor is bit-exact vs the dense
    oracle on decode_to_plan(encode(...)) output."""
    rng = np.random.default_rng(11)
    tmcfg = TMConfig(n_classes=3, n_clauses=8, n_features=20)
    acts = rng.random((3, 8, 40)) < 0.3
    X = rng.integers(0, 2, (32, 20)).astype(np.uint8)
    state = jnp.where(jnp.asarray(acts), tmcfg.n_states + 1, tmcfg.n_states)
    oracle = np.asarray(batch_class_sums(tmcfg, state, jnp.asarray(X)))
    plan = decode_to_plan(encode(tmcfg, np.asarray(acts)))

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    Lc = int(max(
        (plan.clause_id == c).sum() for c in range(plan.n_clauses_total)
    ))
    cfg = tms.TMShardedConfig(
        name="t", n_classes=3, n_clauses=8, n_features=20, batch=32,
        include_cap=Lc,
    )
    fn, specs = tms.build_tm_sharded(cfg, mesh)
    idx, pol, lits1 = tms.operands_from_plan(cfg, plan, X, mesh)
    for op, spec in zip((idx, pol, lits1), specs):
        assert tuple(op.shape) == tuple(spec.shape)
    with mesh:
        sums = np.asarray(jax.jit(fn)(idx, pol, lits1))
    assert (sums[:, : tmcfg.n_classes] == oracle).all()
    # padded class columns contribute nothing
    assert (sums[:, tmcfg.n_classes:] == 0).all()


def test_operands_capacity_errors():
    rng = np.random.default_rng(0)
    tmcfg = TMConfig(n_classes=2, n_clauses=4, n_features=10)
    acts = rng.random((2, 4, 20)) < 0.5
    plan = decode_to_plan(encode(tmcfg, np.asarray(acts)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = tms.TMShardedConfig(
        name="t", n_classes=2, n_clauses=4, n_features=10, batch=32,
        include_cap=1,  # too small for density 0.5
    )
    X = rng.integers(0, 2, (32, 10)).astype(np.uint8)
    with pytest.raises(ValueError):
        tms.operands_from_plan(cfg, plan, X, mesh)


def test_dryrun_lowers_smoke_cell():
    """launch/dryrun.py imports and lowers a smoke config on a 1x1 mesh
    (the full-mesh compiles are the slow subprocess tests)."""
    from repro.configs.base import ShapeSpec
    from repro.configs.registry import get
    from repro.dist import sharding as shd_mod
    from repro.launch.dryrun import lower_cell

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get("stablelm-3b-smoke")
    try:
        lowered = lower_cell(cfg, ShapeSpec("t", 64, 8, "train"), mesh)
        assert "hlo" in lowered.as_text().lower()
    finally:
        shd_mod.set_activation_mesh(None)
