"""Property test: train -> compress -> decode -> batch_class_sums is
bit-exact for random (classes, clauses, features) shapes.

Unlike tests/test_compress.py (random *action masks*, hypothesis-driven),
these properties run the REAL pipeline the recal subsystem ships through:
TA states produced by actual feedback training steps, encoded, decoded,
and compared against the dense oracle — including the all-excluded-clause
edge cases (untrained states, fully-empty classes, empty clauses inside
trained models).  Seeded random sweep, no hypothesis dependency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig,
    batch_class_sums,
    fit_step,
    include_actions,
    init_state,
    state_from_actions,
)
from repro.core.compress import decode, encode, validate_roundtrip


def _roundtrip_sums_equal(cfg, state, X):
    acts = np.asarray(include_actions(cfg, state))
    model = encode(cfg, acts)
    decoded = decode(model)
    s_dense = batch_class_sums(cfg, state, jnp.asarray(X))
    s_decoded = batch_class_sums(
        cfg, state_from_actions(cfg, decoded), jnp.asarray(X)
    )
    return bool(jnp.array_equal(s_dense, s_decoded)), model


@pytest.mark.parametrize("seed", range(12))
def test_trained_model_roundtrip_bit_exact(seed):
    """Random shape, a few real training steps, then the full round trip."""
    rng = np.random.default_rng(seed)
    M = int(rng.integers(2, 7))
    C = int(rng.integers(1, 9)) * 2
    F = int(rng.integers(2, 48))
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    key = jax.random.key(seed)
    state = init_state(cfg, key)
    for step in range(int(rng.integers(1, 4))):
        xb = jnp.asarray(rng.integers(0, 2, (32, F)).astype(np.uint8))
        yb = jnp.asarray(rng.integers(0, M, 32).astype(np.int32))
        state = fit_step(cfg, state, key, xb, yb, step=step, parallel=True)
    X = rng.integers(0, 2, (32, F)).astype(np.uint8)
    ok, model = _roundtrip_sums_equal(cfg, state, X)
    assert ok, f"roundtrip mismatch for (M={M}, C={C}, F={F})"
    # the publication gate agrees
    validate_roundtrip(cfg, np.asarray(include_actions(cfg, state)), model, X)


def test_all_excluded_state_roundtrip():
    """Untrained state: every TA excludes, every clause is empty.  The
    stream degenerates to one boundary EXTEND per class and inference is
    identically zero on both sides of the round trip."""
    cfg = TMConfig(n_classes=4, n_clauses=6, n_features=9)
    state = init_state(cfg, jax.random.key(0))
    X = np.random.default_rng(0).integers(0, 2, (32, 9)).astype(np.uint8)
    ok, model = _roundtrip_sums_equal(cfg, state, X)
    assert ok
    assert model.n_instructions == cfg.n_classes  # one EXTEND per class
    assert not decode(model).any()
    assert not np.asarray(batch_class_sums(cfg, state, jnp.asarray(X))).any()


@pytest.mark.parametrize("seed", range(6))
def test_sparse_models_with_empty_clauses_and_classes(seed):
    """Action masks where whole clauses AND whole classes are empty (the
    encoder skips them; the decoder must re-align polarity slots)."""
    rng = np.random.default_rng(100 + seed)
    M = int(rng.integers(2, 6))
    C = int(rng.integers(2, 8)) * 2
    F = int(rng.integers(2, 40))
    cfg = TMConfig(n_classes=M, n_clauses=C, n_features=F)
    acts = rng.random((M, C, 2 * F)) < 0.08
    acts[rng.integers(0, M)] = False          # one fully-empty class
    acts[:, rng.integers(0, C), :] = False    # one empty clause everywhere
    state = state_from_actions(cfg, acts)
    X = rng.integers(0, 2, (32, F)).astype(np.uint8)
    ok, _ = _roundtrip_sums_equal(cfg, state, X)
    assert ok
