"""Behaviour tests for the Tsetlin Machine core (paper §2, Fig 2/3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig,
    accuracy,
    batch_class_sums,
    fit,
    include_actions,
    init_state,
    pack_literals,
    packed_class_sums,
    predict,
)


@pytest.fixture(scope="module")
def xor_model():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, size=(1500, 8)).astype(np.uint8)
    y = (X[:, 0] ^ X[:, 1]).astype(np.int32)
    cfg = TMConfig(n_classes=2, n_clauses=20, n_features=8, n_states=100)
    state = init_state(cfg, jax.random.key(0))
    state = fit(cfg, state, jax.random.key(1), jnp.asarray(X), jnp.asarray(y),
                epochs=15, batch=250)
    return cfg, state


def test_xor_convergence(xor_model):
    cfg, state = xor_model
    rng = np.random.default_rng(7)
    Xt = rng.integers(0, 2, size=(512, 8)).astype(np.uint8)
    yt = (Xt[:, 0] ^ Xt[:, 1]).astype(np.int32)
    acc = accuracy(cfg, state, jnp.asarray(Xt), jnp.asarray(yt))
    assert acc > 0.95, f"TM failed to learn XOR: {acc}"


def test_model_is_sparse_after_training(xor_model):
    """The premise of the paper: includes are a small minority."""
    cfg, state = xor_model
    frac = float(include_actions(cfg, state).mean())
    assert frac < 0.5


def test_packed_equals_dense(xor_model):
    cfg, state = xor_model
    rng = np.random.default_rng(3)
    X = rng.integers(0, 2, size=(64, 8)).astype(np.uint8)
    dense = batch_class_sums(cfg, state, jnp.asarray(X))
    packed = packed_class_sums(cfg, state, pack_literals(jnp.asarray(X)))
    assert jnp.array_equal(dense, packed[:64])


def test_predict_shape_and_range(xor_model):
    cfg, state = xor_model
    X = np.zeros((16, 8), np.uint8)
    p = predict(cfg, state, jnp.asarray(X))
    assert p.shape == (16,)
    assert bool(jnp.all((p >= 0) & (p < cfg.n_classes)))


def test_empty_clause_semantics():
    """All-exclude model: inference sums must be exactly zero."""
    cfg = TMConfig(n_classes=3, n_clauses=6, n_features=5)
    state = init_state(cfg, jax.random.key(0))  # all at N -> all exclude
    X = np.ones((4, 5), np.uint8)
    sums = batch_class_sums(cfg, state, jnp.asarray(X))
    assert bool(jnp.all(sums == 0))


def test_parallel_training_learns_xor():
    """Summed-delta batch-parallel trainer (arXiv:2009.04861-style) reaches
    the same XOR accuracy as the online trainer."""
    rng = np.random.default_rng(1)
    X = rng.integers(0, 2, size=(1500, 8)).astype(np.uint8)
    y = (X[:, 0] ^ X[:, 1]).astype(np.int32)
    cfg = TMConfig(n_classes=2, n_clauses=20, n_features=8, n_states=100)
    state = init_state(cfg, jax.random.key(0))
    state = fit(cfg, state, jax.random.key(1), jnp.asarray(X), jnp.asarray(y),
                epochs=15, batch=250, parallel=True)
    Xt = rng.integers(0, 2, size=(512, 8)).astype(np.uint8)
    yt = (Xt[:, 0] ^ Xt[:, 1]).astype(np.int32)
    assert accuracy(cfg, state, jnp.asarray(Xt), jnp.asarray(yt)) > 0.95
